"""Arch -> LROA system-model bridge (DESIGN.md §Arch-applicability)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.core import (EdgeProfile, LROAController, estimate_hyperparams,
                        solve_p2, system_params_for_arch)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_every_arch_schedulable(arch):
    """LROA's Algorithm 2 produces valid decisions for every assigned
    architecture's derived workload — the technique applies to all 10."""
    cfg = ARCHS[arch].config
    params = system_params_for_arch(cfg, EdgeProfile(num_devices=12))
    hp = estimate_hyperparams(params, 0.1, loss_scale=2.0)
    h = jnp.asarray(np.clip(np.random.default_rng(0).exponential(0.1, 12),
                            0.01, 0.5).astype(np.float32))
    dec = solve_p2(params, h, jnp.zeros((12,)), hp.V, hp.lam)
    assert abs(float(dec.q.sum()) - 1.0) < 1e-4
    assert bool(jnp.all(dec.f >= params.f_min - 1e-3))
    assert bool(jnp.all(dec.p <= params.p_max + 1e-9))


def test_moe_uploads_active_only():
    from repro.core.arch_bridge import update_bits
    cfg = ARCHS["grok-1-314b"].config
    bits_active = update_bits(cfg, EdgeProfile())
    bits_full = update_bits(cfg, EdgeProfile(upload_only_active=False))
    assert bits_active < 0.3 * bits_full           # 83.8B active of 315.7B


def test_heavier_arch_costs_more():
    from repro.core.arch_bridge import cycles_per_sample
    p = EdgeProfile()
    c_small = cycles_per_sample(ARCHS["mamba2-130m"].config, p)
    c_big = cycles_per_sample(ARCHS["yi-9b"].config, p)
    assert c_big > 20 * c_small
