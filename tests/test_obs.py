"""Flight recorder contract: span nesting/ordering and sink round-trips,
Chrome-trace export validity, the metrics registry reproducing
``RolloutReport.meta``'s dispatch accounting exactly, the strict
watchdog raising on a forced post-warmup retrace (and staying silent on
the warmed path), the zero-overhead no-sink fast path, ``take``'s
deep-copied meta, and the chunk store's schema/provenance gate."""

import json
import os
import time

import numpy as np
import pytest

from test_arena import N, _mixed_grid, _mixed_k_grid, _setup

from repro.obs import trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.watchdog import RetraceError, Watchdog
from repro.sim import Arena, NpzChunkStore, SweepService


# -- tracer core -----------------------------------------------------------


def test_span_nesting_order_and_parents():
    """Children emit before parents (Chrome-trace style); ids/parents/
    depths describe the live stack; attrs round-trip, including
    mid-span .set()."""
    with trace.installed(trace.MemorySink()) as sink:
        with trace.span("outer", a=1) as outer:
            with trace.span("middle") as mid:
                with trace.span("inner", chunk=3):
                    pass
            outer.set(found=2)
        trace.event("tick", k=8)
    names = [r["name"] for r in sink.records]
    assert names == ["inner", "middle", "outer", "tick"]
    inner, middle, outer, tick = sink.records
    assert inner["parent"] == middle["id"]
    assert middle["parent"] == outer["id"]
    assert outer["parent"] is None
    assert (inner["depth"], middle["depth"], outer["depth"]) == (2, 1, 0)
    assert outer["attrs"] == {"a": 1, "found": 2}
    assert inner["attrs"] == {"chunk": 3}
    assert tick["dur"] == 0.0 and tick["attrs"] == {"k": 8}
    # spans time their bodies: parent interval contains the child's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9


def test_jsonl_sink_round_trip(tmp_path):
    """JsonlSink records read back identically through load_jsonl —
    numpy attr values coerced to plain JSON scalars."""
    path = str(tmp_path / "flight.jsonl")
    with trace.installed(trace.JsonlSink(path, flush_every=1)):
        with trace.span("arena.dispatch", chunk=np.int64(2),
                        k_pad=np.float32(4.0), lanes=[1, 2]):
            pass
    records = trace.load_jsonl(path)
    assert len(records) == 1
    r = records[0]
    assert r["name"] == "arena.dispatch"
    assert r["attrs"] == {"chunk": 2, "k_pad": 4.0, "lanes": [1, 2]}
    assert r["dur"] >= 0.0
    # every line is one complete JSON object (append-only, line-atomic)
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_chrome_trace_export_valid(tmp_path):
    """The exported Chrome-trace file is loadable JSON in the
    traceEvents format: complete "X" events with µs ts/dur, instant
    records as "i" events, plus the process-name metadata event."""
    with trace.installed(trace.MemorySink()) as sink:
        with trace.span("arena.run", lanes=4):
            with trace.span("arena.dispatch", chunk=0):
                pass
        trace.event("watchdog.retrace", retraces=1)
    out = str(tmp_path / "chrome.json")
    trace.export_chrome_trace(list(sink.records), out)
    with open(out) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert events[0]["ph"] == "M"
    complete = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    assert {e["name"] for e in complete} == {"arena.run",
                                             "arena.dispatch"}
    assert [e["name"] for e in instants] == ["watchdog.retrace"]
    for e in complete:
        assert e["dur"] >= 0.0 and "ts" in e and e["pid"] == 0
    disp = next(e for e in complete if e["name"] == "arena.dispatch")
    run = next(e for e in complete if e["name"] == "arena.run")
    assert run["ts"] <= disp["ts"] <= disp["ts"] + disp["dur"] \
        <= run["ts"] + run["dur"] + 1e-3


def test_no_sink_is_noop_singleton_and_cheap():
    """The zero-overhead contract: without a sink, span() returns the
    shared no-op singleton (no allocation, no clock read) and event()
    does nothing — cheap enough to live on hot paths permanently."""
    assert not trace._SINKS
    s1 = trace.span("arena.dispatch", chunk=1, k_pad=8)
    s2 = trace.span("anything.else")
    assert s1 is trace._NOOP and s2 is trace._NOOP
    with s1:
        pass
    assert s1.set(x=1) is s1
    t0 = time.perf_counter()
    for i in range(100_000):
        with trace.span("hot.path", i=i):
            pass
    elapsed = time.perf_counter() - t0
    # ~100ns/call on any modern host; 2s bound = pure-smoke margin
    assert elapsed < 2.0, f"no-sink span path too slow: {elapsed:.3f}s"


# -- metrics registry ------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("arena.dispatches")
    c.inc()
    c.inc(3)
    assert c.value == 4 and reg.get("arena.dispatches") == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    reg.gauge("service.queue_depth").set(7)
    h = reg.histogram("arena.chunk.dispatch_s")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["arena.dispatches"] == 4
    assert snap["service.queue_depth"] == 7.0
    assert snap["arena.chunk.dispatch_s"]["count"] == 4
    assert snap["arena.chunk.dispatch_s"]["p50"] == pytest.approx(0.2)
    assert snap["arena.chunk.dispatch_s"]["sum"] == pytest.approx(1.0)
    assert reg.get("absent", default=None) is None
    assert "arena.dispatches" in reg.names()


def test_registry_reproduces_meta_accounting_mixed_k_auto():
    """On a fresh arena, one auto-planned mixed-K run's cumulative
    registry counters equal the report meta exactly (the registry-as-
    view contract); a second run accumulates additively while meta
    stays per-run; dispatch_accounting still cross-checks."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    arena = Arena(eng, k_mode="auto")
    T = 3
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    m = arena.metrics
    assert m.get("arena.runs") == 1
    assert m.get("arena.dispatches") == rep.meta["dispatches"]
    assert m.get("arena.executables_built") == \
        rep.meta["executables_built"]
    assert m.get("arena.executables_cached") == \
        rep.meta["executables_cached"]
    assert rep.dispatch_accounting()["dispatches"] == \
        rep.meta["dispatches"]
    rep2 = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert m.get("arena.runs") == 2
    assert m.get("arena.dispatches") == \
        rep.meta["dispatches"] + rep2.meta["dispatches"]
    assert m.get("arena.executables_built") == \
        rep.meta["executables_built"] + rep2.meta["executables_built"]
    # the public attributes remain views over the same registry
    assert arena.traces == m.get("arena.traces")
    assert arena.input_cache_hits == m.get("arena.input_cache.hits")
    assert arena.input_cache_misses == m.get("arena.input_cache.misses")


# -- watchdog --------------------------------------------------------------


def test_watchdog_strict_raises_on_forced_retrace():
    """Warm at T rounds, run at T+1: the round-count change retraces the
    warmed executable — a strict watchdog turns that silent latency
    multiplication into RetraceError; the violation record carries the
    retrace count and survives on the watchdog."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    arena = Arena(eng)
    dog = Watchdog(strict=True).attach(arena)
    T = 3
    arena.warmup(params0, sp, bank, grid, T)
    assert dog.armed
    h_all = arena.sample_channels(grid, T, N)
    lr = np.full(T, 0.1, np.float32)
    # warmed same-shape run: no violation
    arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert dog.violations == []
    # forced retrace: different round count = new scan shape
    lr2 = np.full(T + 1, 0.1, np.float32)
    with pytest.raises(RetraceError, match="post-warmup retrace"):
        arena.run(params0, sp, bank, grid, T + 1, lr2)
    assert len(dog.violations) == 1
    assert dog.violations[0]["retraces"] >= 1


def test_watchdog_nonstrict_warns_once_and_advances_baseline():
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    arena = Arena(eng)
    dog = Watchdog(strict=False).attach(arena)
    T = 3
    arena.warmup(params0, sp, bank, grid, T)
    lr2 = np.full(T + 1, 0.1, np.float32)
    with pytest.warns(RuntimeWarning, match="post-warmup retrace"):
        arena.run(params0, sp, bank, grid, T + 1, lr2)
    # the baseline advanced: repeating the (now-cached) shape is clean
    h2 = arena.sample_channels(grid, T + 1, N)
    arena.run(params0, sp, bank, grid, T + 1, lr2, h_all=h2)
    assert len(dog.violations) == 1


# -- the streaming acceptance path -----------------------------------------


def test_streaming_service_jsonl_covers_every_chunk(tmp_path):
    """One warmed streaming SweepService run with a JSONL sink yields a
    Chrome-trace-loadable span file covering plan -> compile ->
    dispatch -> reduce, with one arena.dispatch and one arena.reduce
    span per chunk; the strict watchdog sees zero post-warmup retraces;
    the service/store counters land in the shared registry."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    T, chunk = 6, 2
    arena = Arena(eng, chunk_size=chunk)
    svc = SweepService(arena, params0, sp, bank,
                       checkpoint_dir=str(tmp_path / "ckpt"))
    Watchdog(strict=True).attach(arena)
    log = str(tmp_path / "sweep.jsonl")
    with trace.installed(trace.JsonlSink(log, flush_every=1)):
        svc.warmup(grid, T)
        t = svc.submit(grid, T)
        done = svc.run_pending()             # strict: raises on retrace
    assert done == [t]
    records = trace.load_jsonl(log)
    names = [r["name"] for r in records]
    for phase in ("arena.warmup", "service.batch", "arena.run",
                  "arena.plan", "arena.compile", "arena.dispatch",
                  "arena.reduce", "store.save", "service.reduce"):
        assert phase in names, f"missing span {phase}"
    n_chunks = -(-T // chunk)
    run_spans = [r for r in records if r["name"] == "arena.run"]
    dispatch = [r for r in records if r["name"] == "arena.dispatch"
                and r["ts"] > run_spans[0]["ts"]]
    reduce_ = [r for r in records if r["name"] == "arena.reduce"
               and r["ts"] > run_spans[0]["ts"]]
    assert len(dispatch) == n_chunks
    assert len(reduce_) == n_chunks
    assert sorted(r["attrs"]["chunk"] for r in dispatch) == \
        list(range(n_chunks))
    # chrome-trace loadable
    out = str(tmp_path / "sweep_trace.json")
    trace.export_chrome_trace(records, out)
    with open(out) as f:
        doc = json.load(f)
    assert any(e.get("name") == "arena.dispatch"
               for e in doc["traceEvents"])
    # shared registry: service + store + arena in one namespace
    m = arena.metrics
    assert svc.stats["batches"] == 1 and svc.stats["scenarios"] == 4
    assert svc.stats["coalesced_lanes"] == [4]
    assert m.get("store.saves") == svc.store.saves > 0
    assert m.get("arena.chunk.dispatch_s").count >= n_chunks
    stall = Watchdog.stall_report(m)
    assert set(stall) == {"dispatch", "reduce"}
    assert stall["dispatch"]["count"] >= n_chunks


# -- report meta deep copy -------------------------------------------------


def test_take_deep_copies_meta():
    """Mutating a split report's nested per-bucket counters (or plan)
    must not leak into the parent — and a full-coverage take keeps the
    accounting valid while a true slice clears buckets."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    arena = Arena(eng, k_mode="auto")
    T = 3
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    parent_buckets = json.loads(json.dumps(rep.meta["buckets"]))
    full = rep.take(np.arange(len(grid)))
    assert full.meta["split_from"] == len(grid)
    assert full.dispatch_accounting()["dispatches"] == \
        rep.meta["dispatches"]
    full.meta["buckets"][0]["lanes"].append(999)
    full.meta["buckets"][0]["dispatches"] = 12345
    full.meta["plan"] = None
    assert rep.meta["buckets"] == parent_buckets
    assert rep.meta["plan"] is not None
    rep.dispatch_accounting()                 # parent still consistent
    sub = rep.take(np.array([1, 0]))
    assert sub.meta["buckets"] == []
    sub2 = rep.take(np.array([0, 1]))         # partial, in order: slice
    assert sub2.meta["buckets"] == []


# -- chunk store schema / provenance ---------------------------------------


def _fake_store(tmp_path, **kw):
    def carry_like(s):
        return {"params": {"w": np.zeros((s, 2), np.float32)},
                "queues": np.zeros((s, 3), np.float32),
                "rng": np.zeros((s, 2), np.uint32)}
    return NpzChunkStore(str(tmp_path), carry_like, **kw)


def test_store_manifest_records_schema_and_provenance(tmp_path):
    import repro.sim.service as service_mod
    store = _fake_store(tmp_path)
    carry = {"params": {"w": np.ones((2, 2), np.float32)},
             "queues": np.ones((2, 3), np.float32),
             "rng": np.zeros((2, 2), np.uint32)}
    store.save("tag1", 4, carry, {"loss": np.zeros((2, 4), np.float32)})
    assert store.saves == 1
    with open(tmp_path / "tag1_carry.json") as f:
        md = json.load(f)["metadata"]
    assert md["schema_version"] == \
        service_mod.CHUNK_STORE_SCHEMA_VERSION
    assert md["t"] == 4 and md["s"] == 2
    assert md["host"] and md["jax_version"] and md["grid_digest"] == \
        "tag1"
    assert md["saved_at"].endswith("Z")
    t, restored, metrics = store.load("tag1")
    assert t == 4 and store.loads == 1
    np.testing.assert_array_equal(np.asarray(restored["queues"]),
                                  carry["queues"])


def test_store_refuses_resume_on_schema_mismatch(tmp_path):
    store = _fake_store(tmp_path)
    carry = {"params": {"w": np.ones((2, 2), np.float32)},
             "queues": np.ones((2, 3), np.float32),
             "rng": np.zeros((2, 2), np.uint32)}
    store.save("tag1", 4, carry, {"loss": np.zeros((2, 4), np.float32)})
    # simulate a checkpoint written by an older incompatible build
    mpath = tmp_path / "tag1_carry.json"
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["metadata"]["schema_version"] = 0
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="schema_version 0"):
        store.load("tag1")
    # a manifest with NO version field (pre-provenance file) is refused
    # the same way — missing counts as version 0
    del manifest["metadata"]["schema_version"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="refuses to resume"):
        store.load("tag1")
    assert store.loads == 0


def test_store_counters_share_service_registry(tmp_path):
    """A store built by the service writes store.* into the arena's
    registry; a standalone store gets its own."""
    task, eng, bank, sp, params0 = _setup()
    arena = Arena(eng)
    svc = SweepService(arena, params0, sp, bank,
                       checkpoint_dir=str(tmp_path))
    assert svc.store.metrics is arena.metrics
    assert svc.metrics is arena.metrics
    standalone = _fake_store(tmp_path / "solo")
    assert standalone.metrics is not arena.metrics
