"""Per-architecture smoke tests: REDUCED variant of the same family
(2 layers, d_model<=256, <=4 experts), one forward + one train step on CPU,
asserting output shapes and no NaNs — as required by the assignment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.launch.steps import (build_model, input_specs, make_serve_step,
                                make_train_step)
from repro.configs.shapes import InputShape
from repro.optim import SGD

SMOKE_SHAPE = InputShape("smoke", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = InputShape("smoke-decode", seq_len=48, global_batch=2,
                          kind="decode")


def _materialise(specs, cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32:
            if s.ndim == 0:
                out[name] = jnp.asarray(5, jnp.int32)
            else:
                out[name] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, s.shape), jnp.int32)
        else:
            out[name] = jnp.asarray(rng.normal(0, 0.3, s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    specs = input_specs(arch, SMOKE_SHAPE, cfg)
    batch = _materialise(specs, cfg)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # forward
    if cfg.is_encoder_decoder:
        logits, aux, _ = model.apply(params, batch["tokens"],
                                     frame_embeds=batch["frame_embeds"])
    elif cfg.family == "vlm":
        from repro.models.vlm import mrope_positions
        b, s = batch["tokens"].shape
        logits, aux, _ = model.apply(
            params, batch["tokens"],
            positions_thw=mrope_positions(b, s, cfg.vision_patches),
            vision_embeds=batch["vision_embeds"])
    else:
        logits, aux, _ = model.apply(params, batch["tokens"])
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    # one train step reduces loss-carrying state without NaN
    step = make_train_step(cfg, lr=1e-2, remat=False)
    opt_state = SGD(momentum=0.9).init(params)
    new_params, _, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss NaN"
    # params actually changed
    changed = jax.tree_util.tree_reduce(
        lambda acc, pair: acc or pair,
        jax.tree_util.tree_map(
            lambda a, b: bool(jnp.any(a != b)), params, new_params),
        False)
    assert changed, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, DECODE_SHAPE.seq_len)
    specs = input_specs(arch, DECODE_SHAPE, cfg)
    batch = _materialise(specs, cfg)
    if cfg.is_encoder_decoder:
        batch["enc_states"] = jnp.asarray(
            np.random.default_rng(1).normal(
                0, 0.3, (2, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)

    step = make_serve_step(cfg)
    logits, new_cache = jax.jit(step)(params, cache, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN decode logits"
    # cache was written
    leaves_old = jax.tree_util.tree_leaves(cache)
    leaves_new = jax.tree_util.tree_leaves(new_cache)
    assert any(bool(jnp.any(a != b))
               for a, b in zip(leaves_old, leaves_new)), \
        f"{arch}: decode did not write the cache"


def test_two_decode_steps_consistent_with_prefill():
    """Greedy 2-step decode == teacher-forced full forward (dense smoke)."""
    cfg = get_smoke_config("yi-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 9), 0,
                              cfg.vocab_size)
    full, _, _ = model.apply(params, toks)
    _, _, cache = model.apply(params, toks[:, :8], mode="prefill")
    cache = jax.tree_util.tree_map(
        lambda c: jnp.pad(c, [(0, 0)] * 0 + [(0, 0)] * (c.ndim - 1) + [(0, 0)])
        if False else c, cache)
    # pad caches from 8 -> 9 slots
    ref_cache = model.init_cache(1, 9)
    cache = jax.tree_util.tree_map(
        lambda cp, cf: jnp.pad(cp, [(0, cf.shape[i] - cp.shape[i])
                                    for i in range(cp.ndim)]),
        cache, ref_cache)
    lg, _ = model.decode_step(params, cache, toks[:, 8:9],
                              jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 8]),
                               atol=2e-4)
