"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device (the 512-device
placeholder mesh belongs exclusively to repro.launch.dryrun)."""

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def make_params(n=16, dataset="cifar10", seed=0):
    from repro.core import paper_default_params
    rng = np.random.default_rng(seed)
    return paper_default_params(
        num_devices=n,
        data_sizes=rng.integers(200, 600, n).astype(np.float32),
        dataset=dataset)


def make_channel(n=16, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.clip(rng.exponential(0.1, n), 0.01, 0.5)
                       .astype(np.float32))
