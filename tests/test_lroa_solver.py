"""Unit tests for Algorithm 2: Theorems 2/3 closed forms, the SUM
water-filling q-solver, and the alternating P2 loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_channel, make_params
from repro.core import (ControlDecision, SolverConfig, p22_objective,
                        p2_objective, solve_f, solve_p, solve_p2, solve_q)
from repro.core import system_model as sm
from repro.core.solver import _phi, _waterfill_simplex

N = 12


@pytest.fixture(scope="module")
def setup():
    params = make_params(N)
    h = make_channel(N)
    q = jnp.full((N,), 1.0 / N)
    queues = jnp.abs(make_channel(N, seed=3)) * 1e4
    return params, h, q, queues


def _f_objective(params, q, queues, V, f):
    """The P2.1.1 objective as a function of f (for perturbation tests)."""
    sel = sm.selection_probability(q, params.sample_count)
    e_cmp = sm.compute_energy(params, f)
    t_cmp = sm.compute_time(params, f)
    return jnp.sum(queues * sel * e_cmp + V * q * t_cmp)


def test_theorem2_is_local_min(setup):
    params, h, q, queues = setup
    V = 1e5
    f_star = solve_f(params, q, queues, V)
    base = _f_objective(params, q, queues, V, f_star)
    for eps in (0.99, 1.01):
        f_pert = jnp.clip(f_star * eps, params.f_min, params.f_max)
        assert _f_objective(params, q, queues, V, f_pert) >= base - 1e-3


def test_theorem2_zero_queue_gives_fmax(setup):
    params, h, q, _ = setup
    f_star = solve_f(params, q, jnp.zeros((N,)), 1e5)
    np.testing.assert_allclose(np.asarray(f_star), np.asarray(params.f_max))


def test_phi_monotone():
    x = jnp.linspace(0.0, 50.0, 300)
    phi = _phi(x)
    assert bool(jnp.all(jnp.diff(phi) > 0))
    assert float(phi[0]) == 0.0


def _p_objective(params, q, queues, h, V, p):
    sel = sm.selection_probability(q, params.sample_count)
    t_up = sm.upload_time(params, h, p)
    return jnp.sum((queues * sel * p + V * q) * t_up)


def test_theorem3_is_local_min(setup):
    params, h, q, queues = setup
    V = 1e2
    p_star = solve_p(params, q, queues, h, V)
    base = _p_objective(params, q, queues, h, V, p_star)
    for eps in (0.98, 1.02):
        p_pert = jnp.clip(p_star * eps, params.p_min, params.p_max)
        assert _p_objective(params, q, queues, h, V, p_pert) >= base - 1e-4


def test_waterfill_matches_grid_search():
    rng = np.random.default_rng(0)
    n = 5
    b = jnp.asarray(rng.uniform(0.5, 3.0, n).astype(np.float32))
    a3 = jnp.asarray(rng.uniform(0.01, 0.3, n).astype(np.float32))
    q = _waterfill_simplex(b, a3, 1e-6, 64)
    assert abs(float(q.sum()) - 1.0) < 1e-5
    obj = float(jnp.sum(b * q + a3 / q))
    # random feasible candidates must not beat the waterfilling solution
    for _ in range(300):
        cand = rng.dirichlet(np.ones(n)).astype(np.float32)
        cand = np.clip(cand, 1e-6, 1.0)
        cand /= cand.sum()
        cand_obj = float(np.sum(np.asarray(b) * cand + np.asarray(a3) / cand))
        assert cand_obj >= obj - 1e-3


def test_solve_q_improves_p22(setup):
    params, h, q0, queues = setup
    V, lam = 1e4, 10.0
    f = 0.5 * (params.f_min + params.f_max)
    p = 0.5 * (params.p_min + params.p_max)
    t = sm.round_time(params, h, p, f)
    e = sm.round_energy(params, h, p, f)
    q_star = solve_q(params, t, e, queues, V, lam, q0)
    assert abs(float(q_star.sum()) - 1.0) < 1e-4
    assert bool(jnp.all(q_star > 0))
    obj0 = float(p22_objective(params, q0, t, e, queues, V, lam))
    obj1 = float(p22_objective(params, q_star, t, e, queues, V, lam))
    assert obj1 <= obj0 + 1e-3


def test_solve_p2_beats_naive_decisions(setup):
    params, h, _, queues = setup
    V, lam = 1e4, 10.0
    dec = solve_p2(params, h, queues, V, lam)
    assert abs(float(dec.q.sum()) - 1.0) < 1e-4
    obj_star = float(p2_objective(params, h, dec, queues, V, lam))
    naive = ControlDecision(
        f=params.f_max, p=params.p_max,
        q=jnp.full((N,), 1.0 / N, jnp.float32))
    obj_naive = float(p2_objective(params, h, naive, queues, V, lam))
    assert obj_star <= obj_naive + 1e-3


def test_decisions_respect_boxes(setup):
    params, h, _, queues = setup
    dec = solve_p2(params, h, queues, 1e4, 10.0)
    assert bool(jnp.all(dec.f >= params.f_min - 1e-3))
    assert bool(jnp.all(dec.f <= params.f_max + 1e-3))
    assert bool(jnp.all(dec.p >= params.p_min - 1e-9))
    assert bool(jnp.all(dec.p <= params.p_max + 1e-9))
