"""Property-based tests (hypothesis) on the system's invariants."""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection_probability, update_queues
from repro.core.solver import _phi, _waterfill_simplex
from repro.models.layers import token_nll

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=40,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

finite_f32 = st.floats(min_value=-1e3, max_value=1e3, width=32,
                       allow_nan=False)


@hypothesis.given(
    b=hnp.arrays(np.float32, st.integers(2, 16),
                 elements=st.floats(0.0, 100.0, width=32)),
    a3_scale=st.floats(1e-4, 10.0),
)
def test_waterfill_always_on_simplex(b, a3_scale):
    rng = np.random.default_rng(0)
    a3 = (a3_scale * rng.uniform(0.1, 1.0, b.shape[0])).astype(np.float32)
    q = _waterfill_simplex(jnp.asarray(b), jnp.asarray(a3), 1e-6, 64)
    q = np.asarray(q)
    assert abs(q.sum() - 1.0) < 1e-4
    assert (q > 0).all()
    assert (q <= 1.0 + 1e-6).all()


@hypothesis.given(x=st.floats(0.0, 1e6))
def test_phi_nonnegative_increasing(x):
    val = float(_phi(jnp.asarray(x)))
    assert val >= -1e-6
    assert float(_phi(jnp.asarray(x + 1.0))) >= val


@hypothesis.given(
    q=hnp.arrays(np.float32, st.integers(1, 12),
                 elements=st.floats(0.0, 1.0, width=32)),
    k=st.integers(1, 8),
)
def test_selection_probability_bounds(q, k):
    sel = np.asarray(selection_probability(jnp.asarray(q), k))
    assert (sel >= -1e-6).all() and (sel <= 1.0 + 1e-6).all()
    # monotone in q
    order = np.argsort(q)
    assert (np.diff(sel[order]) >= -1e-6).all()


@hypothesis.given(
    queues=hnp.arrays(np.float32, st.integers(1, 10),
                      elements=st.floats(0.0, 1e6, width=32)),
    inc=hnp.arrays(np.float32, st.integers(1, 10),
                   elements=finite_f32),
)
def test_queue_update_nonnegative(queues, inc):
    n = min(len(queues), len(inc))
    out = np.asarray(update_queues(jnp.asarray(queues[:n]),
                                   jnp.asarray(inc[:n])))
    assert (out >= 0).all()


@hypothesis.given(
    logits=hnp.arrays(np.float32, st.tuples(st.integers(1, 3),
                                            st.integers(1, 4),
                                            st.integers(2, 9)),
                      elements=st.floats(-20, 20, width=32)),
)
def test_token_nll_matches_gather(logits):
    b, s, v = logits.shape
    rng = np.random.default_rng(0)
    labels = rng.integers(0, v, (b, s))
    nll = np.asarray(token_nll(jnp.asarray(logits), jnp.asarray(labels)))
    logp = jax.nn.log_softmax(jnp.asarray(logits), axis=-1)
    expected = -np.take_along_axis(np.asarray(logp), labels[..., None],
                                   axis=-1)[..., 0]
    np.testing.assert_allclose(nll, expected, atol=1e-4, rtol=1e-4)


@hypothesis.given(
    w=hnp.arrays(np.float32, st.integers(2, 10),
                 elements=st.floats(0.015625, 1.0, width=32)),
)
def test_sampling_error_minimised_at_q_eq_w(w):
    """Theorem 1's sampling term sum w^2/q is minimised by q = w."""
    from repro.core import sampling_error_term
    w = w / w.sum()
    base = float(sampling_error_term(jnp.asarray(w), jnp.asarray(w)))
    rng = np.random.default_rng(0)
    for _ in range(10):
        q = rng.dirichlet(np.ones(len(w))).astype(np.float32)
        q = np.clip(q, 1e-4, 1.0)
        q /= q.sum()
        assert float(sampling_error_term(jnp.asarray(w),
                                         jnp.asarray(q))) >= base - 1e-5
