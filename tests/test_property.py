"""Property-based tests on the system's invariants.

Two layers:

* A deterministic, seeded invariant suite over EVERY registered decide
  rule and its selection discipline (the controller zoo contract) —
  plain pytest parameterization, part of tier-1 everywhere.
* The original hypothesis fuzz suite over the solver/queue/model
  primitives — it runs whenever ``hypothesis`` is importable and skips
  cleanly (without hollowing out the zoo suite) where it is not; CI
  installs hypothesis, so the fuzz layer is always exercised there.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (POLICIES, POLICY_IDS, paper_default_params,
                        selection_probability, update_queues)
from repro.core import policy as pol
from repro.core import queues as vq
from repro.core.solver import _phi, _waterfill_simplex
from repro.models.layers import token_nll

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # CI installs hypothesis; local envs may not
    HAVE_HYPOTHESIS = False


# ==========================================================================
# Controller-zoo invariants: every registered decide rule, deterministic
# seeded draws (tier-1 everywhere, no hypothesis dependency)
# ==========================================================================

N = 9
K = 4


def _zoo_params(seed=0):
    sizes = np.random.default_rng(seed).integers(40, 200, N).astype(
        np.float32)
    return paper_default_params(num_devices=N, sample_count=K,
                                data_sizes=sizes)


def _draw(seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.uniform(0.01, 0.5, N).astype(np.float32))
    queues = jnp.asarray(rng.uniform(0.0, 500.0, N).astype(np.float32))
    return h, queues


_V = jnp.full((N,), 80.0, jnp.float32)
_LAM = jnp.full((N,), 0.7, jnp.float32)


@pytest.mark.parametrize("name", POLICIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_decide_rule_respects_boxes_and_simplex(name, seed):
    """Every controller's decision obeys the physical boxes: q is a
    probability distribution, f in [f_min, f_max], p in [p_min, p_max]
    — for any channel/queue state."""
    params = _zoo_params()
    h, queues = _draw(seed)
    dec = pol.decide_by_id(jnp.int32(POLICY_IDS[name]), params, h,
                           queues, _V, _LAM)
    q = np.asarray(dec.q)
    f = np.asarray(dec.f)
    p = np.asarray(dec.p)
    assert np.all(q >= 0.0) and np.isclose(q.sum(), 1.0, atol=1e-5), name
    assert np.all(f >= np.asarray(params.f_min) - 1e-6), name
    assert np.all(f <= np.asarray(params.f_max) + 1e-6), name
    assert np.all(p >= np.asarray(params.p_min) - 1e-6), name
    assert np.all(p <= np.asarray(params.p_max) + 1e-6), name
    assert np.all(np.isfinite(q)) and np.all(np.isfinite(f))
    assert np.all(np.isfinite(p))


@pytest.mark.parametrize("name", POLICIES)
@pytest.mark.parametrize("seed", [0, 3])
def test_virtual_queues_stay_nonnegative_under_every_rule(name, seed):
    """The Lyapunov virtual queues never go negative, whichever
    controller drives the (p, f, q) allocation."""
    params = _zoo_params()
    h, queues = _draw(seed)
    for t in range(5):
        dec = pol.decide_by_id(jnp.int32(POLICY_IDS[name]), params, h,
                               queues, _V, _LAM)
        inc = vq.energy_increment(params, h, dec.p, dec.f, dec.q)
        queues = vq.update_queues(queues, inc)
        assert np.all(np.asarray(queues) >= 0.0), (name, t)
        h, _ = _draw(seed + 10 + t)


@pytest.mark.parametrize("name", POLICIES)
@pytest.mark.parametrize("seed", [0, 1])
def test_selection_fills_exactly_k_valid_slots(name, seed):
    """``select_by_id`` fills exactly k_act slots with valid client ids
    for every controller's selection discipline."""
    params = _zoo_params()
    h, queues = _draw(seed)
    dec = pol.decide_by_id(jnp.int32(POLICY_IDS[name]), params, h,
                           queues, _V, _LAM)
    slots = jnp.arange(K)
    kvec = jnp.full((N,), float(K), jnp.float32)
    sel = np.asarray(pol.select_by_id(
        jnp.int32(POLICY_IDS[name]), params, jnp.int32(seed), h, queues,
        dec.q, jax.random.PRNGKey(seed), slots, kvec))
    assert sel.shape == (K,), name
    assert np.all((sel >= 0) & (sel < N)), name
    if pol.SELECTION_MODES[name] != pol.SELECT_SAMPLED:
        # deterministic disciplines never repeat a client within a round
        assert len(set(sel.tolist())) == K, name


@pytest.mark.parametrize("name", POLICIES)
def test_sampled_selection_puts_no_mass_outside_support(name):
    """Sampled disciplines only ever land on clients with q > 0 — a
    sparse q (channel_aware's top-k mask) must confine every draw to its
    support, and zero-probability (inert) clients get no mass."""
    if pol.SELECTION_MODES[name] != pol.SELECT_SAMPLED:
        pytest.skip("deterministic selection has no sampling mass")
    params = _zoo_params()
    slots = jnp.arange(K)
    kvec = jnp.full((N,), float(K), jnp.float32)
    for seed in range(6):
        h, queues = _draw(seed)
        dec = pol.decide_by_id(jnp.int32(POLICY_IDS[name]), params, h,
                               queues, _V, _LAM)
        q = np.asarray(dec.q)
        support = np.flatnonzero(q > 0.0)
        sel = np.asarray(pol.select_by_id(
            jnp.int32(POLICY_IDS[name]), params, jnp.int32(0), h,
            queues, dec.q, jax.random.PRNGKey(seed), slots, kvec))
        assert np.all(np.isin(sel, support)), (name, seed)


def test_channel_aware_concentrates_on_best_channels():
    """The Shi-style rule puts ALL sampling mass on the top-K channel
    gains, uniformly."""
    params = _zoo_params()
    for seed in range(4):
        h, queues = _draw(seed)
        dec = pol.decide_channel_aware(params, h, queues, _V, _LAM)
        q = np.asarray(dec.q)
        top = np.argsort(-np.asarray(h))[:K]
        np.testing.assert_allclose(q[top], 1.0 / K, rtol=1e-6)
        mask = np.ones(N, bool)
        mask[top] = False
        assert np.all(q[mask] == 0.0)


def test_round_robin_selection_cycles_without_repeats():
    """Round-robin walks the client list deterministically: every window
    of N consecutive slots across rounds covers each client exactly
    once."""
    params = _zoo_params()
    slots = jnp.arange(K)
    kvec = jnp.full((N,), float(K), jnp.float32)
    h, queues = _draw(0)
    q = jnp.full((N,), 1.0 / N, jnp.float32)
    seen = []
    for t in range(N):          # N rounds x K slots = K full cycles
        sel = np.asarray(pol.round_robin_selection(
            params, jnp.int32(t), h, queues, q, jax.random.PRNGKey(0),
            slots, kvec))
        seen.extend(sel.tolist())
    counts = np.bincount(np.asarray(seen), minlength=N)
    assert np.all(counts == K)


def test_selection_prefix_stability_under_padded_k():
    """Padded-K contract at the selection layer: slot i's fill never
    depends on K_max — the first k slots of a K_max-slot fill equal the
    k-slot fill for every discipline (the invariant that lets one padded
    executable serve mixed-K grids)."""
    params = _zoo_params()
    h, queues = _draw(1)
    dec = pol.decide_by_id(jnp.int32(POLICY_IDS["lroa"]), params, h,
                           queues, _V, _LAM)
    key = jax.random.PRNGKey(3)
    for name in POLICIES:
        cid = jnp.int32(POLICY_IDS[name])
        full = np.asarray(pol.select_by_id(
            cid, params, jnp.int32(2), h, queues, dec.q, key,
            jnp.arange(N), jnp.full((N,), float(N), jnp.float32)))
        for k in (1, K):
            kvec = jnp.full((N,), float(k), jnp.float32)
            part = np.asarray(pol.select_by_id(
                cid, params, jnp.int32(2), h, queues, dec.q, key,
                jnp.arange(k), kvec))
            if name == "round_robin":
                # round-robin strides by k_act itself: prefix stability
                # holds per (t, k) pair, not across different k — the
                # padded engine passes the lane's true k in kvec
                expect = (2 * k + np.arange(k)) % N
                np.testing.assert_array_equal(part, expect)
            else:
                np.testing.assert_array_equal(part, full[:k])


# ==========================================================================
# Hypothesis fuzz layer (runs when hypothesis is installed — CI always)
# ==========================================================================

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=40,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.load_profile("ci")

    finite_f32 = st.floats(min_value=-1e3, max_value=1e3, width=32,
                           allow_nan=False)

    @hypothesis.given(
        b=hnp.arrays(np.float32, st.integers(2, 16),
                     elements=st.floats(0.0, 100.0, width=32)),
        a3_scale=st.floats(1e-4, 10.0),
    )
    def test_waterfill_always_on_simplex(b, a3_scale):
        rng = np.random.default_rng(0)
        a3 = (a3_scale * rng.uniform(0.1, 1.0, b.shape[0])).astype(
            np.float32)
        q = _waterfill_simplex(jnp.asarray(b), jnp.asarray(a3), 1e-6, 64)
        q = np.asarray(q)
        assert abs(q.sum() - 1.0) < 1e-4
        assert (q > 0).all()
        assert (q <= 1.0 + 1e-6).all()

    @hypothesis.given(x=st.floats(0.0, 1e6))
    def test_phi_nonnegative_increasing(x):
        val = float(_phi(jnp.asarray(x)))
        assert val >= -1e-6
        assert float(_phi(jnp.asarray(x + 1.0))) >= val

    @hypothesis.given(
        q=hnp.arrays(np.float32, st.integers(1, 12),
                     elements=st.floats(0.0, 1.0, width=32)),
        k=st.integers(1, 8),
    )
    def test_selection_probability_bounds(q, k):
        sel = np.asarray(selection_probability(jnp.asarray(q), k))
        assert (sel >= -1e-6).all() and (sel <= 1.0 + 1e-6).all()
        # monotone in q
        order = np.argsort(q)
        assert (np.diff(sel[order]) >= -1e-6).all()

    @hypothesis.given(
        queues=hnp.arrays(np.float32, st.integers(1, 10),
                          elements=st.floats(0.0, 1e6, width=32)),
        inc=hnp.arrays(np.float32, st.integers(1, 10),
                       elements=finite_f32),
    )
    def test_queue_update_nonnegative(queues, inc):
        n = min(len(queues), len(inc))
        out = np.asarray(update_queues(jnp.asarray(queues[:n]),
                                       jnp.asarray(inc[:n])))
        assert (out >= 0).all()

    @hypothesis.given(
        logits=hnp.arrays(np.float32, st.tuples(st.integers(1, 3),
                                                st.integers(1, 4),
                                                st.integers(2, 9)),
                          elements=st.floats(-20, 20, width=32)),
    )
    def test_token_nll_matches_gather(logits):
        b, s, v = logits.shape
        rng = np.random.default_rng(0)
        labels = rng.integers(0, v, (b, s))
        nll = np.asarray(token_nll(jnp.asarray(logits),
                                   jnp.asarray(labels)))
        logp = jax.nn.log_softmax(jnp.asarray(logits), axis=-1)
        expected = -np.take_along_axis(np.asarray(logp),
                                       labels[..., None], axis=-1)[..., 0]
        np.testing.assert_allclose(nll, expected, atol=1e-4, rtol=1e-4)

    @hypothesis.given(
        w=hnp.arrays(np.float32, st.integers(2, 10),
                     elements=st.floats(0.015625, 1.0, width=32)),
    )
    def test_sampling_error_minimised_at_q_eq_w(w):
        """Theorem 1's sampling term sum w^2/q is minimised by q = w."""
        from repro.core import sampling_error_term
        w = w / w.sum()
        base = float(sampling_error_term(jnp.asarray(w), jnp.asarray(w)))
        rng = np.random.default_rng(0)
        for _ in range(10):
            q = rng.dirichlet(np.ones(len(w))).astype(np.float32)
            q = np.clip(q, 1e-4, 1.0)
            q /= q.sum()
            assert float(sampling_error_term(jnp.asarray(w),
                                             jnp.asarray(q))) >= base - 1e-5
