"""Bucket-ladder bank: tier assignment edge cases, the TieredClientBank
index maps and memory bound, and the round engine's tier loop — a one-tier
ladder is bit-identical to the single-bucket ClientBank, a single-tier
selection is bit-identical to that tier's host-stacked round, and a
multi-tier selection matches the composed per-tier eq.-(4) reference
(tiers the selection misses never run)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (LROAController, estimate_hyperparams,
                        paper_default_params)
from repro.data import synthetic_image_classification
from repro.data.pipeline import assign_tiers, client_bucket_examples
from repro.fl import (ChannelConfig, ChannelProcess, ClientBank,
                      ClientConfig, FederatedTrainer, RoundEngine,
                      TieredClientBank)
from repro.models import MLPTask
from repro.optim import constant

BS = 16


def _client_data(sizes, seed=3):
    total = sum(sizes)
    x, y = synthetic_image_classification(total, (8, 8, 1), num_classes=4,
                                          noise=0.3, seed=seed)
    offs = np.cumsum([0] + list(sizes))
    return [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
            for i in range(len(sizes))]


def _engine(**kw):
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    return task, RoundEngine(task, ClientConfig(local_epochs=2,
                                                batch_size=BS), **kw)


def _assert_trees_bitwise(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_trees_close(a, b, atol=1e-6):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol)


# -- tier assignment -------------------------------------------------------


def test_assign_tiers_all_equal_collapses_to_one_tier():
    tier_of, buckets = assign_tiers([64] * 9, BS)
    np.testing.assert_array_equal(tier_of, 0)
    assert buckets == [64]


def test_assign_tiers_tiny_clients_share_the_one_batch_tier():
    """n < batch_size buckets to exactly one batch (bs rows)."""
    tier_of, buckets = assign_tiers([1, 5, 15, 16, 64], BS)
    assert buckets[0] == BS
    np.testing.assert_array_equal(tier_of, [0, 0, 0, 0, 1])
    assert client_bucket_examples(1, BS) == BS


def test_assign_tiers_ladder_is_per_client_pow2_buckets():
    sizes = [64, 10, 33, 64, 100, 17]
    tier_of, buckets = assign_tiers(sizes, BS)
    assert buckets == [16, 32, 64, 128]
    np.testing.assert_array_equal(tier_of, [2, 0, 2, 2, 3, 1])
    # every client's tier bucket holds all its examples
    for n, t in zip(sizes, tier_of):
        assert buckets[t] >= n


def test_assign_tiers_merges_down_to_max_tiers():
    sizes = [10, 20, 40, 70, 140, 300, 600]   # 7 distinct buckets
    tier_of, buckets = assign_tiers(sizes, BS, max_tiers=3)
    assert len(buckets) == 3
    for n, t in zip(sizes, tier_of):          # merge only moves UP
        assert buckets[t] >= client_bucket_examples(n, BS)
    # max_tiers=1 degenerates to the single global bucket
    tier_of, buckets = assign_tiers(sizes, BS, max_tiers=1)
    assert buckets == [1024] and set(tier_of) == {0}
    with pytest.raises(ValueError):
        assign_tiers(sizes, BS, max_tiers=0)


# -- bank structure / memory bound -----------------------------------------


def test_tiered_bank_maps_views_and_memory_bound():
    sizes = [64, 10, 33, 64, 100, 17]
    cd = _client_data(sizes)
    _, eng = _engine()
    bank = eng.make_bank(cd, tiered="tiered")
    assert isinstance(bank, TieredClientBank) and bank.num_tiers == 4
    np.testing.assert_array_equal(bank.sizes, sizes)
    for i in range(len(sizes)):               # global -> (tier, row) maps
        t, r = bank.tier_of[i], bank.pos_in_tier[i]
        assert bank.tier_members[t][r] == i
        vx, vy = bank.client_view(i)
        np.testing.assert_array_equal(vx, cd[i][0])
        np.testing.assert_array_equal(vy, cd[i][1])
    single = eng.make_bank(cd, tiered="single")
    # the ladder's device rows: sum_t N_t * B_t, strictly below the
    # global bucket's N * B_max and within the per-client pow2 bound
    assert bank.true_examples == single.true_examples == sum(sizes)
    assert bank.padded_examples == sum(
        m.size * b for m, b in zip(bank.tier_members, bank.tier_buckets))
    assert bank.padded_examples < single.padded_examples
    assert bank.padded_examples <= sum(
        client_bucket_examples(n, BS) for n in sizes)


def test_make_bank_modes():
    cd = _client_data([64] * 4)
    _, eng = _engine()
    assert isinstance(eng.make_bank(cd), ClientBank)              # auto
    assert isinstance(eng.make_bank(cd, tiered="tiered"),
                      TieredClientBank)
    skewed = _client_data([64, 10, 100, 64])
    assert isinstance(eng.make_bank(skewed), TieredClientBank)    # auto
    assert isinstance(eng.make_bank(skewed, tiered="single"), ClientBank)
    with pytest.raises(ValueError):
        eng.make_bank(cd, tiered="bogus")


# -- tentpole: one-tier ladder == single-bucket bank, bit for bit ----------


def test_one_tier_ladder_round_and_scan_bitwise_equal_single_bucket():
    cd = _client_data([64] * 6)
    task, eng = _engine()
    single = eng.make_bank(cd, tiered="single")
    ladder = eng.make_bank(cd, tiered="tiered")
    assert ladder.num_tiers == 1
    params = task.init(jax.random.PRNGKey(0))
    sel = np.asarray([0, 2, 5, 1])
    coeffs = np.asarray([.2, .3, .1, .4], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(5), 4)
    p_s, l_s = eng.round_step(params, single, sel, coeffs, .1, rngs)
    p_t, l_t = eng.round_step(params, ladder, sel, coeffs, .1, rngs)
    _assert_trees_bitwise(p_s, p_t)
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_t))
    sp = paper_default_params(num_devices=6, sample_count=4,
                              data_sizes=np.full(6, 64, np.float32))
    h = np.random.default_rng(0).uniform(0.05, 0.4, (4, 6)).astype(
        np.float32)
    lr = np.full(4, .1, np.float32)
    p_s, _, m_s = eng.run_scan(params, sp, single, h, lr,
                               jax.random.PRNGKey(2), policy="uni_d")
    p_t, _, m_t = eng.run_scan(params, sp, ladder, h, lr,
                               jax.random.PRNGKey(2), policy="uni_d")
    _assert_trees_bitwise(p_s, p_t)
    np.testing.assert_array_equal(m_s["loss"], m_t["loss"])


# -- single-tier selection: bitwise vs that tier's host-stacked round ------


def test_selection_within_one_tier_bitwise_equals_tier_stacked_round():
    sizes = [64, 10, 33, 64, 100, 17]
    cd = _client_data(sizes)
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="tiered")
    params = task.init(jax.random.PRNGKey(0))
    sel = np.asarray([0, 2, 3, 0])            # all in the 64-bucket tier
    assert len(np.unique(bank.tier_of[sel])) == 1
    coeffs = np.asarray([.2, .3, .1, .4], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(5), 4)
    p, l = eng.round_step(params, bank, sel, coeffs, .1, rngs)
    tier = bank.tiers[int(bank.tier_of[sel[0]])]
    xs, ys, ns, ne = tier.gather_host(bank.pos_in_tier[sel])
    p_ref, l_ref = eng.round_step_stacked(params, xs, ys, coeffs, .1, rngs,
                                          ns, ne)
    _assert_trees_bitwise(p, p_ref)
    np.testing.assert_array_equal(np.asarray(l), np.asarray(l_ref))


# -- multi-tier selection: composed per-tier eq.-(4) reference -------------


def _compose_reference(eng, bank, params, sel, coeffs, lr, rngs):
    """theta + sum over hit tiers of that tier's masked eq.-(4) update,
    built from per-tier host-stacked rounds — the tier loop's contract."""
    upd, losses = None, np.zeros(len(sel), np.float32)
    for t in np.unique(bank.tier_of[sel]):
        tier = bank.tiers[int(t)]
        mask = bank.tier_of[sel] == t
        pos = np.where(mask, bank.pos_in_tier[sel], 0)
        xs, ys, ns, ne = tier.gather_host(pos)
        p_t, l_t = eng.round_step_stacked(
            params, xs, ys, (coeffs * mask).astype(np.float32), lr, rngs,
            ns, ne)
        u_t = jax.tree_util.tree_map(lambda a, b: a - b, p_t, params)
        upd = (u_t if upd is None else
               jax.tree_util.tree_map(jnp.add, upd, u_t))
        losses = losses + np.asarray(l_t) * mask
    return jax.tree_util.tree_map(jnp.add, params, upd), losses


def test_multi_tier_selection_matches_composed_reference():
    sizes = [64, 10, 33, 64, 100, 17]
    cd = _client_data(sizes)
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="tiered")
    params = task.init(jax.random.PRNGKey(0))
    coeffs = np.asarray([.2, .3, .1, .4], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(5), 4)
    sel = np.asarray([1, 4, 0, 5])            # hits 4 distinct tiers
    assert len(np.unique(bank.tier_of[sel])) == 4
    p, l = eng.round_step(params, bank, sel, coeffs, .1, rngs)
    p_ref, l_ref = _compose_reference(eng, bank, params, sel, coeffs, .1,
                                      rngs)
    _assert_trees_close(p, p_ref)
    np.testing.assert_allclose(np.asarray(l), l_ref, atol=1e-6)


def test_round_with_empty_tier_skips_it_and_matches_reference():
    """A selection that misses a tier entirely must not touch that
    tier's executables — and must still match the composed reference
    over the tiers it does hit."""
    sizes = [64, 10, 33, 64, 100, 17]
    cd = _client_data(sizes)
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="tiered")
    params = task.init(jax.random.PRNGKey(0))
    coeffs = np.asarray([.2, .3, .1, .4], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(5), 4)
    sel = np.asarray([1, 0, 2, 1])            # 16- and 64-bucket tiers only
    hit = tuple(sorted(np.unique(bank.tier_of[sel]).tolist()))
    assert len(hit) == 2 < bank.num_tiers
    p, l = eng.round_step(params, bank, sel, coeffs, .1, rngs)
    (key,) = eng._tiered_fns.keys()           # one executable, hit tiers only
    assert tuple(part[0] for part in key) == hit
    p_ref, l_ref = _compose_reference(eng, bank, params, sel, coeffs, .1,
                                      rngs)
    _assert_trees_close(p, p_ref)
    np.testing.assert_allclose(np.asarray(l), l_ref, atol=1e-6)


def test_tiered_round_accepts_empty_selection_like_single_bucket():
    """An empty selection is a no-op on the single-bucket path (gather of
    zero rows); the tiered path must match instead of crashing."""
    task, eng = _engine()
    bank = eng.make_bank(_client_data([64, 10, 33, 64]), tiered="tiered")
    params = task.init(jax.random.PRNGKey(0))
    empty = np.asarray([], np.int64)
    coeffs = np.asarray([], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(0), 1)[:0]
    p, l = eng.round_step(params, bank, empty, coeffs, .1, rngs)
    _assert_trees_bitwise(p, params)
    assert np.asarray(l).shape == (0,)


def test_tiered_round_rejects_out_of_range_selection():
    _, eng = _engine()
    bank = eng.make_bank(_client_data([64, 10, 33, 64]), tiered="tiered")
    coeffs = np.asarray([1.0], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(0), 1)
    params = MLPTask(input_dim=64, num_classes=4, hidden=32).init(
        jax.random.PRNGKey(0))
    with pytest.raises(IndexError):
        eng.round_step(params, bank, np.asarray([4]), coeffs, 0.1, rngs)


# -- multi-tier scan -------------------------------------------------------


def test_tiered_scan_trains_and_stays_finite():
    sizes = [64, 10, 33, 64, 100, 17, 48, 12]
    cd = _client_data(sizes)
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="tiered")
    assert bank.num_tiers > 1
    sp = paper_default_params(num_devices=len(sizes), sample_count=4,
                              data_sizes=np.asarray(sizes, np.float32))
    rounds = 5
    h = ChannelProcess(len(sizes), ChannelConfig(seed=1)).sample_sequence(
        rounds)
    params0 = task.init(jax.random.PRNGKey(7))
    params, queues, m = eng.run_scan(
        params0, sp, bank, h, np.full(rounds, 0.1, np.float32),
        jax.random.PRNGKey(8), policy="uni_d")
    assert np.all(np.isfinite(m["loss"]))
    assert m["selected"].shape == (rounds, 4)
    assert np.all((m["selected"] >= 0) & (m["selected"] < len(sizes)))
    moved = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(params0)))
    assert moved > 0
    assert m["loss"][-1] < m["loss"][0]


# -- trainer integration ---------------------------------------------------


def _make_trainer(sizes, seed=0, **kw):
    cd = _client_data(list(sizes))
    params = paper_default_params(num_devices=len(sizes), sample_count=4,
                                  data_sizes=np.asarray(sizes, np.float32))
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    return FederatedTrainer(
        task, params, LROAController(params, hp),
        ChannelProcess(len(sizes), ChannelConfig(seed=seed)), cd,
        ClientConfig(local_epochs=2, batch_size=BS), constant(0.1),
        seed=seed, **kw)


def test_trainer_auto_bank_mode_picks_ladder_for_skewed_partitions():
    skewed = [64, 10, 33, 64, 100, 17, 48, 12]
    t = _make_trainer(skewed)
    assert isinstance(t.bank, TieredClientBank)
    recs = [t.run_round(i) for i in range(3)]
    assert all(np.isfinite(r.mean_loss) for r in recs)
    t_uni = _make_trainer([64] * 8)
    assert isinstance(t_uni.bank, ClientBank)
    # explicit override still available
    t_single = _make_trainer(skewed, bank_mode="single")
    assert isinstance(t_single.bank, ClientBank)


def test_tiered_warmup_compiles_tier_executables_without_mutating_state():
    skewed = [64, 10, 33, 64, 100, 17, 48, 12]
    t_cold = _make_trainer(skewed)
    t_warm = _make_trainer(skewed)
    t_warm.warmup()
    # each tier's single-bucket executable + the all-tier loop exist
    assert (len(t_warm.engine._step_fns) == t_warm.bank.num_tiers)
    assert len(t_warm.engine._tiered_fns) >= 1
    recs_cold = [t_cold.run_round(i) for i in range(3)]
    recs_warm = [t_warm.run_round(i) for i in range(3)]
    for a, b in zip(recs_cold, recs_warm):
        assert a.selected == b.selected
        assert a.mean_loss == pytest.approx(b.mean_loss, abs=1e-6)


def test_tiered_sequential_path_matches_divfl_contract():
    """use_engine=False reads every client through the tiered bank's
    client_view — the sequential path must run unchanged on a ladder."""
    skewed = [64, 10, 33, 64, 100, 17, 48, 12]
    t = _make_trainer(skewed, use_engine=False)
    assert isinstance(t.bank, TieredClientBank)
    rec = t.run_round(0)
    assert np.isfinite(rec.mean_loss)
