"""Substrate tests: data partitioning, optimizers, schedules, checkpointing,
convergence bound, DivFL selection."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import BoundConstants, convergence_bound, facility_location_greedy
from repro.data import (dirichlet_partition, partition_stats,
                        synthetic_image_classification, synthetic_lm_tokens,
                        writer_partition)
from repro.optim import SGD, AdamW, apply_updates, clip_by_global_norm
from repro.optim import constant, cosine, paper_step_decay, step_decay


def test_dirichlet_partition_covers_all():
    y = np.random.default_rng(0).integers(0, 10, 5000)
    parts = dirichlet_partition(y, 20, 0.5, seed=1)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(np.unique(all_idx)) == 5000
    stats = partition_stats(parts, y)
    assert stats["mean_tv_distance"] > 0.1        # genuinely non-IID
    assert stats["sizes"].min() >= 8


def test_writer_partition_min_samples():
    y = np.random.default_rng(0).integers(0, 62, 40000)
    parts = writer_partition(y, 30, seed=2)
    assert all(len(p) >= 40 for p in parts)


def test_synthetic_images_learnable_structure():
    x, y = synthetic_image_classification(400, (8, 8, 1), 4, noise=0.1)
    # same-class examples are closer than cross-class on average
    x = x.reshape(400, -1)
    d_same, d_diff = [], []
    for c in range(4):
        xs = x[y == c]
        d_same.append(np.linalg.norm(xs[0] - xs[1]))
        other = x[y != c]
        d_diff.append(np.linalg.norm(xs[0] - other[0]))
    assert np.mean(d_same) < np.mean(d_diff)


def test_lm_tokens_shape_and_range():
    toks = synthetic_lm_tokens(4, 64, 100, seed=0)
    assert toks.shape == (4, 64)
    assert toks.min() >= 0 and toks.max() < 100


def test_sgd_momentum_descends_quadratic():
    opt = SGD(momentum=0.9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params,
                                    jnp.asarray(0.05))
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_descends():
    opt = AdamW()
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params, jnp.asarray(0.05))
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_schedules():
    sch = paper_step_decay(0.1, 100)
    assert abs(float(sch(jnp.asarray(0))) - 0.1) < 1e-7
    assert abs(float(sch(jnp.asarray(60))) - 0.05) < 1e-7
    assert abs(float(sch(jnp.asarray(80))) - 0.025) < 1e-7
    cos = cosine(1.0, 100, warmup_steps=10)
    assert abs(float(cos(jnp.asarray(5))) - 0.5) < 1e-6
    assert float(cos(jnp.asarray(100))) < 1e-6
    assert abs(float(constant(0.3)(jnp.asarray(7))) - 0.3) < 1e-7


def test_checkpoint_roundtrip():
    tree = {"layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                      "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, "step_7", tree, {"round": 7})
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        restored, meta = restore_checkpoint(d, "step_7", like)
        assert meta["round"] == 7
        np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                      np.asarray(tree["layer"]["w"]))
        assert restored["layer"]["b"].dtype == jnp.bfloat16


def test_convergence_bound_monotone_in_q_quality():
    c = BoundConstants(beta=1.0, G=1.0, gamma=1.0, kappa=0.5,
                       f0_minus_fstar=1.0)
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    t = 50
    q_good = jnp.broadcast_to(w, (t, 4))
    q_bad = jnp.full((t, 4), 0.25)
    good = float(convergence_bound(c, 1e-2, 2, 2, t, w, q_good))
    bad = float(convergence_bound(c, 1e-2, 2, 2, t, w, q_bad))
    assert good <= bad


def test_facility_location_greedy_prefers_diversity():
    # two tight clusters; k=2 must pick one from each
    sim = np.asarray([
        [1.0, 0.9, 0.0, 0.0],
        [0.9, 1.0, 0.0, 0.0],
        [0.0, 0.0, 1.0, 0.9],
        [0.0, 0.0, 0.9, 1.0]])
    sel = set(facility_location_greedy(sim, 2).tolist())
    assert len(sel & {0, 1}) == 1 and len(sel & {2, 3}) == 1
