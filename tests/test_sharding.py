"""Sharding-rule tests: divisibility on the production mesh shape for every
assigned architecture (no 512-device runtime needed — pure spec logic), plus
a real 1x1-mesh jit of a smoke config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.dist.sharding import (batch_spec, cache_spec, param_spec)
from repro.launch import steps as steps_lib


class FakeMesh:
    """Duck-typed stand-in exposing .shape / .axis_names (param_spec only
    reads those) so the 16x16 production rules are testable on CPU."""

    def __init__(self, shape, names):
        self.shape = dict(zip(names, shape))
        self.axis_names = tuple(names)


PROD = FakeMesh((16, 16), ("data", "model"))
PROD_MP = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _check_divisible(path, shape, spec, mesh):
    assert len(spec) == len(shape), (path, shape, spec)
    for dim, axis in zip(shape, spec):
        size = _axis_size(mesh, axis)
        assert dim % size == 0, (
            f"{path}: dim {dim} not divisible by {axis} ({size})")


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [PROD, PROD_MP], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = steps_lib.dryrun_config(ARCHS[arch].config)
    shapes = steps_lib.param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        spec = param_spec(pstr, tuple(leaf.shape), mesh)
        _check_divisible(pstr, leaf.shape, tuple(spec), mesh)


def _norm(entry):
    """PartitionSpec collapses 1-tuples to bare names; normalise both."""
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def test_batch_spec_fallbacks():
    spec = batch_spec(256, PROD, extra_dims=1)
    assert _norm(tuple(spec)[0]) == ("data",)
    # batch 1: nothing shardable
    spec1 = batch_spec(1, PROD, extra_dims=2)
    assert all(_norm(e) == () for e in tuple(spec1))
    # multi-pod batch 32 = 2*16
    spec2 = batch_spec(32, PROD_MP, extra_dims=0)
    assert _norm(tuple(spec2)[0]) == ("pod", "data")


def test_cache_spec_batch1_long_context():
    # [G, B=1, S, kv, hd]: falls back to sequence/data + heads/model
    spec = tuple(cache_spec((23, 1, 524288, 16, 128), PROD))
    assert _norm(spec[2]) == ("data",)
    assert _norm(spec[3]) == ("model",)
    # normal decode batch: batch over fsdp
    spec2 = tuple(cache_spec((23, 128, 32768, 16, 128), PROD))
    assert _norm(spec2[1]) == ("data",)


def test_smoke_train_step_on_real_mesh():
    """jit with explicit NamedShardings on a real 1x1 mesh (CPU)."""
    from repro.dist.sharding import params_shardings, batch_sharding
    cfg = get_smoke_config("gemma-2b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = steps_lib.make_train_step(cfg, lr=1e-2, remat=False)
    model = steps_lib.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import SGD
    opt_state = SGD(momentum=0.9).init(params)
    param_sh = params_shardings(jax.eval_shape(lambda: params), mesh)
    opt_sh = params_shardings(jax.eval_shape(lambda: opt_state), mesh)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    batch_sh = {k: batch_sharding(2, mesh, v.ndim - 1)
                for k, v in batch.items()}
    with mesh:
        jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh))
        new_params, _, metrics = jitted(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
