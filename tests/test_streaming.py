"""Streaming arena + sweep service: the chunked pipelined ``Arena.run``
must reproduce the one-shot monolithic scan bitwise on the model
trajectory at every chunking (mixed-K, tiered banks, every k_mode, eval
curves crossing chunk boundaries), a mid-rollout checkpoint must
kill/resume bit-identically into a FRESH arena/service, repeated warmed
submissions must trace nothing and upload nothing, and the shared bench
record must survive a ``bench_round_engine`` re-record with foreign
sections intact."""

import os

import jax
import numpy as np
import pytest

from test_arena import (BITWISE_METRICS, N, TOL, _client_data, _mixed_grid,
                        _mixed_k_grid, _setup, _test_set)

from repro.sim import (Arena, EvalBank, NpzChunkStore, RolloutReport,
                       ScenarioGrid, SweepService, concat_chunk_metrics)


def _assert_model_bitwise(rep_a, rep_b):
    """Model trajectory (params + loss/selected/wall_time) bitwise; the
    control-plane diagnostics to f32 resolution (same contract as the
    arena-vs-run_scan lane tests — XLA fuses the Algorithm-2 elementwise
    chains shape-dependently)."""
    for a, b in zip(jax.tree_util.tree_leaves(rep_a.params),
                    jax.tree_util.tree_leaves(rep_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in rep_a.metrics:
        if name in BITWISE_METRICS or name.startswith("test_"):
            np.testing.assert_array_equal(rep_a.metrics[name],
                                          rep_b.metrics[name], err_msg=name)
        else:
            np.testing.assert_allclose(rep_a.metrics[name],
                                       rep_b.metrics[name], err_msg=name,
                                       **TOL)
    np.testing.assert_allclose(rep_a.queues, rep_b.queues, **TOL)


# -- tentpole: chunked pipeline == one-shot scan ---------------------------


def test_chunked_matches_monolithic_every_chunking():
    """chunk in {1, 3, T-1, T}: same executable family, ceil(T/chunk)
    dispatches, model trajectory bitwise — including the ragged tail.

    Bitwise equality for length-1 segments (chunk=1, chunk=T-1's tail)
    holds at these test shapes but is only guaranteed for segments of
    length >= 2: XLA unrolls a trip-count-1 scan and may re-fuse the
    unrolled body's large-shape reductions (1 ulp at paper scale — see
    the streaming section of docs/architecture.md)."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    T = 6
    lr = np.linspace(0.1, 0.05, T).astype(np.float32)
    arena = Arena(eng)
    h_all = arena.sample_channels(grid, T, N)
    rep_mono = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep_mono.meta["dispatches"] == 1
    for chunk in (1, 3, 5, 6):
        rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                        chunk_size=chunk)
        assert rep.meta["dispatches"] == -(-T // chunk), chunk
        assert rep.meta["chunk_size"] == chunk
        _assert_model_bitwise(rep_mono, rep)
    # the whole chunked family shares ONE extra executable (the resume
    # program) on top of the monolithic one
    assert len(arena._fns) == 2


def test_chunked_eval_every_crossing_chunk_boundaries():
    """eval_every=3 with chunk=4 over T=8: in-scan evals fire at rounds
    0/3/6 — round 3 and 6 land inside resume segments and round 4's
    step-curve value was carried ACROSS the boundary from round 3's eval
    — the chunked test_* columns must still equal the monolithic curves
    bitwise, as must the batched final evaluation."""
    task, eng, bank, sp, params0 = _setup()
    eb = EvalBank(task, *_test_set())
    grid = _mixed_grid(s=4)
    T, chunk = 8, 4
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng)
    h_all = arena.sample_channels(grid, T, N)
    rep_mono = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                         eval_bank=eb, eval_every=3)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                    eval_bank=eb, eval_every=3, chunk_size=chunk)
    assert rep.metrics["test_accuracy"].shape == (4, T)
    _assert_model_bitwise(rep_mono, rep)
    for name in rep_mono.final_metrics:
        np.testing.assert_array_equal(rep_mono.final_metrics[name],
                                      rep.final_metrics[name])


@pytest.mark.parametrize("k_mode", ["pad", "group", "auto"])
def test_chunked_mixed_k_every_mode(k_mode):
    """A mixed-K grid chunked under each dispatch mode reproduces that
    mode's monolithic run bitwise; per-bucket dispatch counters stay
    additive (bucket dispatches now count chunks)."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 4
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng, k_mode=k_mode)
    h_all = arena.sample_channels(grid, T, N)
    rep_mono = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                    chunk_size=3)
    _assert_model_bitwise(rep_mono, rep)
    acc = rep.dispatch_accounting()
    assert acc["dispatches"] == rep.meta["dispatches"]
    if k_mode != "auto":        # auto replans between runs
        assert rep.meta["dispatches"] == 2 * rep_mono.meta["dispatches"]


def test_chunked_tiered_bank_matches_monolithic():
    """Tiered-ladder scan bodies (selection-conditioned lax.cond ->
    select under vmap) chunk cleanly: both sides run the same batched
    per-round graph, so even the tiered trajectory stays bitwise."""
    task, eng, bank, sp, params0 = _setup(
        sizes=[32, 32, 64, 64, 128, 128], bank_mode="tiered")
    grid = _mixed_grid(s=4)
    T = 5
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng)
    h_all = arena.sample_channels(grid, T, N)
    rep_mono = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                    chunk_size=2)
    _assert_model_bitwise(rep_mono, rep)


def test_chunked_warmup_zero_retrace_and_cached_inputs():
    """A fresh arena warmed at a chunking (start + resume shapes, AOT
    where supported) runs that chunking with ZERO new traces, and
    steady-state repeats re-upload nothing (lane/channel/lr caches)."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    T = 6
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng, chunk_size=4)
    stats = arena.warmup(params0, sp, bank, grid, T, lr)
    assert stats["executables_built"] == 2      # start + resume
    traces0 = arena.traces
    misses0 = arena.input_cache_misses
    for _ in range(2):
        rep = arena.run(params0, sp, bank, grid, T, lr)
        assert rep.meta["dispatches"] == 2
    assert arena.traces == traces0
    assert arena.input_cache_misses == misses0
    assert arena.input_cache_hits > 0


# -- the sweep service ------------------------------------------------------


def test_service_coalesces_compatible_submissions_and_splits_back():
    """Two 2-lane submissions with the same (T, lr) coalesce into ONE
    4-lane batched execution whose split-back reports reproduce the
    direct 4-lane run lane for lane; an incompatible submission (other
    T) stays queued for its own batch."""
    task, eng, bank, sp, params0 = _setup()
    g4 = _mixed_grid(s=4)
    T = 4
    lr = np.full(T, 0.1, np.float32)
    arena_ref = Arena(eng)
    h4 = arena_ref.sample_channels(g4, T, N)
    rep_ref = arena_ref.run(params0, sp, bank, g4, T, lr)

    svc = SweepService(Arena(eng, chunk_size=2), params0, sp, bank,
                       max_lanes=8)
    ta = svc.submit(g4.take(np.array([0, 1])), T, lr)
    tb = svc.submit(g4.take(np.array([2, 3])), T, lr)
    tc = svc.submit(g4.take(np.array([0, 1])), T + 1,
                    np.full(T + 1, 0.1, np.float32))
    done = svc.process_once()
    assert sorted(done) == [ta, tb]
    assert svc.pending() == 1               # the T+1 submission waits
    assert svc.stats["coalesced_lanes"] == [4]
    for ticket, idx in ((ta, [0, 1]), (tb, [2, 3])):
        rep = svc.result(ticket)
        assert rep.meta["split_from"] == 4
        assert len(rep.grid) == 2
        for i, s in enumerate(idx):
            for a, b in zip(
                    jax.tree_util.tree_leaves(rep_ref.scenario_params(s)),
                    jax.tree_util.tree_leaves(rep.scenario_params(i))):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            for name in BITWISE_METRICS:
                np.testing.assert_array_equal(rep_ref.metrics[name][s],
                                              rep.metrics[name][i])
    assert svc.run_pending() == [tc]
    assert svc.result(tc).num_scenarios == 2


def test_service_steady_state_zero_retrace():
    """After one warmup, repeated same-shape submissions through the
    service trace nothing and miss no input cache."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    T = 4
    lr = np.full(T, 0.1, np.float32)
    svc = SweepService(Arena(eng, chunk_size=2), params0, sp, bank,
                       max_lanes=4)
    svc.warmup(grid, T, lr)
    tr0 = svc.arena.traces
    miss0 = svc.arena.input_cache_misses
    for _ in range(2):
        t = svc.submit(grid, T, lr)
        svc.run_pending()
        svc.result(t)
    assert svc.arena.traces == tr0
    assert svc.arena.input_cache_misses == miss0


# -- satellite: checkpoint kill/resume bitwise ------------------------------


class _Kill(Exception):
    pass


def _killing_store(store, after: int):
    """Wrap a store's ``save`` to raise after the ``after``-th save —
    the mid-rollout process-death simulation."""
    orig, calls = store.save, {"n": 0}

    def save(tag, t_next, carry, metrics):
        orig(tag, t_next, carry, metrics)
        calls["n"] += 1
        if calls["n"] >= after:
            raise _Kill()
    store.save = save


def _kill_and_resume(eng, bank, sp, params0, grid, T, lr, tmp_path,
                     eval_bank=None, eval_every=None):
    """Run through a service that dies at the first chunk checkpoint,
    then resume in a FRESH arena + service over the same directory;
    returns the resumed report plus the resuming service."""
    ckdir = str(tmp_path)
    svc = SweepService(Arena(eng, chunk_size=2), params0, sp, bank,
                       eval_bank=eval_bank, eval_every=eval_every,
                       checkpoint_dir=ckdir, max_lanes=len(grid))
    _killing_store(svc.store, after=1)
    svc.submit(grid, T, lr)
    with pytest.raises(_Kill):
        svc.run_pending()
    assert any(f.endswith(".npz") for f in os.listdir(ckdir))
    svc2 = SweepService(Arena(eng, chunk_size=2), params0, sp, bank,
                        eval_bank=eval_bank, eval_every=eval_every,
                        checkpoint_dir=ckdir, max_lanes=len(grid))
    ticket = svc2.submit(grid, T, lr)
    svc2.run_pending()
    rep = svc2.result(ticket)
    assert svc2.store.loads == 1
    assert os.listdir(ckdir) == []          # finish() removed the pair
    # the resume covered only the remaining segments
    assert rep.meta["dispatches"] < -(-T // 2)
    return rep, svc2


def test_checkpoint_kill_resume_bitwise_mixed_k(tmp_path):
    """A service killed at the first chunk boundary of a padded mixed-K
    grid (with in-scan eval) resumes in a fresh process and finishes
    bit-identically to the uninterrupted run."""
    task, eng, bank, sp, params0 = _setup()
    eb = EvalBank(task, *_test_set())
    grid = _mixed_k_grid()
    T = 6
    lr = np.full(T, 0.1, np.float32)
    rep_ref = Arena(eng).run(params0, sp, bank, grid, T, lr,
                             eval_bank=eb, eval_every=2)
    rep, _ = _kill_and_resume(eng, bank, sp, params0, grid, T, lr,
                              tmp_path, eval_bank=eb, eval_every=2)
    _assert_model_bitwise(rep_ref, rep)
    for name in rep_ref.final_metrics:
        np.testing.assert_array_equal(rep_ref.final_metrics[name],
                                      rep.final_metrics[name])


def test_checkpoint_kill_resume_bitwise_tiered_bank(tmp_path):
    """Same kill/resume contract on a tiered-ladder bank."""
    task, eng, bank, sp, params0 = _setup(
        sizes=[32, 32, 64, 64, 128, 128], bank_mode="tiered")
    grid = _mixed_grid(s=4)
    T = 6
    lr = np.full(T, 0.1, np.float32)
    rep_ref = Arena(eng).run(params0, sp, bank, grid, T, lr)
    rep, _ = _kill_and_resume(eng, bank, sp, params0, grid, T, lr,
                              tmp_path)
    _assert_model_bitwise(rep_ref, rep)


def test_chunk_store_trims_metrics_ahead_of_carry(tmp_path):
    """A crash BETWEEN the metrics save and the carry save leaves
    metrics one checkpoint ahead — load must trim the prefix back to the
    carry's committed round horizon."""
    like = lambda s: {"params": {"w": np.zeros((s, 3), np.float32)},
                      "queues": np.zeros((s, N), np.float32),
                      "rng": np.zeros((s, 2), np.uint32)}
    store = NpzChunkStore(str(tmp_path), like)
    carry = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
             "queues": np.ones((2, N), np.float32),
             "rng": np.arange(4, dtype=np.uint32).reshape(2, 2)}
    m4 = {"loss": np.arange(8, dtype=np.float32).reshape(2, 4)}
    store.save("chunk_x", 4, carry, m4)
    # simulate the torn pair: newer metrics (t=6) land, carry save dies
    from repro.checkpoint import save_checkpoint
    save_checkpoint(str(tmp_path), "chunk_x_metrics",
                    {"loss": np.zeros((2, 6), np.float32)},
                    metadata={"t": 6, "s": 2})
    t, got_carry, metrics = store.load("chunk_x")
    assert t == 4
    assert metrics["loss"].shape == (2, 4)
    np.testing.assert_array_equal(got_carry["rng"], carry["rng"])
    np.testing.assert_array_equal(got_carry["params"]["w"],
                                  carry["params"]["w"])
    store.finish("chunk_x")
    assert store.load("chunk_x") is None


# -- satellite: report plumbing --------------------------------------------


def test_concat_chunk_metrics_and_report_take():
    chunks = [{"loss": np.ones((2, 3)), "sel": np.zeros((2, 3, 4))},
              {"loss": 2 * np.ones((2, 2)), "sel": np.ones((2, 2, 4))}]
    out = concat_chunk_metrics(chunks)
    assert out["loss"].shape == (2, 5)
    assert out["sel"].shape == (2, 5, 4)
    np.testing.assert_array_equal(out["loss"][:, :3], 1.0)
    np.testing.assert_array_equal(out["loss"][:, 3:], 2.0)
    one = concat_chunk_metrics(chunks[:1])
    np.testing.assert_array_equal(one["loss"], chunks[0]["loss"])
    with pytest.raises(ValueError):
        concat_chunk_metrics([])
    with pytest.raises(ValueError):
        concat_chunk_metrics([{"a": np.ones((1, 1))},
                              {"b": np.ones((1, 1))}])

    grid = _mixed_grid(s=4)
    rep = RolloutReport(
        grid=grid, num_rounds=3,
        params={"w": np.arange(8.0).reshape(4, 2)},
        queues=np.arange(4 * N, dtype=np.float32).reshape(4, N),
        metrics={"loss": np.arange(12.0).reshape(4, 3)},
        meta={"k_mode": "pad", "buckets": [1]},
        final_metrics={"test_accuracy": np.arange(4.0)})
    sub = rep.take(np.array([2, 0]))
    assert len(sub.grid) == 2
    np.testing.assert_array_equal(np.asarray(sub.params["w"]),
                                  rep.params["w"][[2, 0]])
    np.testing.assert_array_equal(sub.metrics["loss"],
                                  rep.metrics["loss"][[2, 0]])
    np.testing.assert_array_equal(sub.final_metrics["test_accuracy"],
                                  [2.0, 0.0])
    assert sub.meta["split_from"] == 4 and sub.meta["buckets"] == []
    assert int(sub.grid.seed[0]) == int(grid.seed[2])


# -- satellite: shared bench record preservation ----------------------------


def test_bench_record_preserves_foreign_sections():
    from benchmarks.bench_round_engine import preserve_foreign_sections
    prev = {"arena": {"S4": 1}, "future_section": {"x": 2},
            "scan_rounds_per_sec": 99.0}
    result = {"scan_rounds_per_sec": 123.0, "skewed": {}}
    out = preserve_foreign_sections(result, prev)
    assert out["arena"] == {"S4": 1}              # known foreign section
    assert out["future_section"] == {"x": 2}      # UNKNOWN foreign section
    assert out["scan_rounds_per_sec"] == 123.0    # own keys win
    assert out["skewed"] == {}
