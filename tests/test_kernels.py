"""Per-kernel allclose sweeps: Pallas kernel bodies (interpret mode on CPU)
vs the pure-jnp oracles in repro.kernels.ref, over shapes x dtypes x mask
configurations — as required by the assignment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.fl_aggregate import fl_aggregate_tpu
from repro.kernels.ssd_scan import ssd_chunk_tpu

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, Sq, Sk, D)
    (1, 2, 2, 33, 33, 16),     # MHA, ragged seq
    (2, 4, 2, 64, 64, 32),     # GQA
    (1, 8, 1, 48, 80, 64),     # MQA, Sq != Sk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 16, 0.0), (False, 0, 0.0), (True, 0, 20.0),
])
def test_flash_attention_sweep(shape, dtype, causal, window, softcap):
    b, h, hkv, sq, sk, d = shape
    rng = jax.random.PRNGKey(hash((shape, str(dtype))) % (2 ** 31))
    q = jax.random.normal(jax.random.fold_in(rng, 1), (b, h, sq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, hkv, sk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, hkv, sk, d), dtype)
    out = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=16, block_kv=16,
                              interpret=True)
    expected = ref.mha_reference(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dims", [
    # (B, S, nh, hd, N, chunk)
    (1, 32, 2, 8, 4, 8),
    (2, 64, 3, 16, 8, 16),
    (1, 48, 1, 32, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_sweep(dims, dtype):
    b, s, nh, hd, n, chunk = dims
    rng = jax.random.PRNGKey(hash((dims, str(dtype))) % (2 ** 31))
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, nh, hd), dtype)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(rng, 2), (b, s, nh))).astype(dtype)
    a_log = jnp.log(jnp.linspace(1.0, 8.0, nh)).astype(dtype)
    b_in = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n), dtype)
    c_in = jax.random.normal(jax.random.fold_in(rng, 4), (b, s, n), dtype)
    y, states = ssd_chunk_tpu(x, dt, a_log, b_in, c_in, chunk=chunk,
                              interpret=True)
    for bi in range(b):
        for c in range(s // chunk):
            sl = slice(c * chunk, (c + 1) * chunk)
            yr, sr = ref.ssd_chunk_reference(x[bi, sl], dt[bi, sl], a_log,
                                             b_in[bi, sl], c_in[bi, sl])
            np.testing.assert_allclose(
                np.asarray(y[bi, sl], np.float32),
                np.asarray(yr, np.float32), atol=5 * _tol(dtype),
                rtol=5 * _tol(dtype))
            np.testing.assert_allclose(
                np.asarray(states[bi, c], np.float32),
                np.asarray(sr, np.float32), atol=5 * _tol(dtype),
                rtol=5 * _tol(dtype))


@pytest.mark.parametrize("n,k,block", [(1000, 2, 256), (4096, 6, 512),
                                       (333, 1, 128),
                                       (65_537, 3, 65_536),  # default block,
                                                             # non-aligned N
                                       (129, 1, 256)])       # K=1, N < block
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fl_aggregate_sweep(n, k, block, dtype):
    rng = jax.random.PRNGKey(n * 7 + k)
    theta = jax.random.normal(jax.random.fold_in(rng, 1), (n,), dtype)
    deltas = jax.random.normal(jax.random.fold_in(rng, 2), (k, n), dtype)
    coeffs = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(rng, 3), (k,)))
    out = fl_aggregate_tpu(theta, deltas, coeffs, block=block,
                           interpret=True)
    expected = ref.aggregate_reference(theta, deltas, coeffs)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_fl_aggregate_pytree_adapter_interpret():
    """The ravel adapter + Pallas kernel body (interpret mode) serve a real
    nested params pytree and agree with the per-leaf stacked reduction."""
    from repro.fl import ParamRavel, aggregate_stacked
    from repro.kernels.fl_aggregate import fl_aggregate_tpu
    key = jax.random.PRNGKey(5)
    params = {"layer": {"w": jax.random.normal(key, (13, 7)),
                        "b": jnp.zeros((7,))},
              "head": jax.random.normal(jax.random.fold_in(key, 1), (7, 3))}
    k = 3
    deltas = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 2),
                                    (k,) + p.shape), params)
    coeffs = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3),
                                              (k,)))
    adapter = ParamRavel(params)
    out_vec = fl_aggregate_tpu(adapter.ravel(params),
                               adapter.ravel_stacked(deltas), coeffs,
                               block=64, interpret=True)
    out = adapter.unravel(out_vec)
    expected = aggregate_stacked(params, deltas, coeffs)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(expected)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_jnp_scan_matches_kernel():
    """The XLA fallback (models.flash) and the Pallas kernel agree."""
    from repro.models.flash import FlashConfig, flash_attention
    rng = jax.random.PRNGKey(0)
    b, h, hkv, s, d = 2, 4, 2, 65, 32
    q = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, hkv, d))
    cfg = FlashConfig(block_q=16, block_kv=16, causal=True, window=0,
                      softcap=0.0, scale=d ** -0.5)
    out_scan = flash_attention(q, k, v, cfg)            # [B,S,H,D]
    out_kernel = flash_attention_tpu(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=16, block_kv=16,
        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_kernel),
                               atol=2e-5, rtol=2e-5)
