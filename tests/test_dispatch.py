"""Dispatch planner unit surface: the cost-model lane bucketing behind
``Arena(k_mode='auto')`` — degenerate pad/group plans, deterministic
signature bucketing, the cold-vs-steady horizon split, cache-aware
replanning, footprint handling, and the CostModel calibrations.  Pure
host-side tests (no rollouts); the arena-level bitwise equivalence of
executed plans lives in ``test_arena.py``."""

import json
import math

import numpy as np
import pytest

from repro.sim import CostModel, DispatchBucket, DispatchPlan
from repro.sim import lane_footprints, plan_dispatch

# one bank tier, 128 bucket rows per slot per round — the scale the
# arena's _tier_work feeds for a 64-example bs-16 2-epoch bank
WORK = {0: 128.0}


def _skewed_ks():
    # ten tiny-K lanes + two huge-K lanes: the padding-waste poster child
    return np.array([2] * 10 + [16, 16])


# -- plan containers ---------------------------------------------------------


def test_padded_and_grouped_degenerate_plans():
    ks = np.array([2, 4, 2, 4, 3, 3])
    pad = DispatchPlan.padded(ks)
    assert pad.num_buckets == 1
    assert pad.buckets[0] == DispatchBucket(lanes=tuple(range(6)), k_pad=4)
    grp = DispatchPlan.grouped(ks)
    assert [b.k_pad for b in grp.buckets] == [2, 3, 4]
    assert grp.buckets[0].lanes == (0, 2)
    assert grp.buckets[1].lanes == (4, 5)
    assert grp.buckets[2].lanes == (1, 3)
    assert grp.k_max == 4


def test_permutation_round_trip_interleaved():
    """inverse_permutation restores grid order for any stitched array —
    the exact index algebra Arena._run_plan uses."""
    ks = np.array([4, 2, 4, 2, 8, 2])
    plan = DispatchPlan.grouped(ks)
    perm = plan.permutation()
    inv = plan.inverse_permutation()
    lane_values = np.arange(len(ks)) * 10
    stitched = lane_values[perm]          # device order (bucket concat)
    np.testing.assert_array_equal(stitched[inv], lane_values)
    np.testing.assert_array_equal(perm[inv[np.arange(len(ks))]],
                                  np.arange(len(ks)))
    bucket_of = plan.bucket_of()
    for j, b in enumerate(plan.buckets):
        assert all(bucket_of[i] == j for i in b.lanes)


def test_plan_validates_partition_and_bucket_shapes():
    with pytest.raises(ValueError, match="partition"):
        DispatchPlan(buckets=(DispatchBucket(lanes=(0, 1), k_pad=2),),
                     num_lanes=3)
    with pytest.raises(ValueError, match="partition"):
        DispatchPlan(buckets=(DispatchBucket(lanes=(0, 0), k_pad=2),),
                     num_lanes=2)
    with pytest.raises(ValueError, match="at least one lane"):
        DispatchBucket(lanes=(), k_pad=2)
    with pytest.raises(ValueError, match="k_pad"):
        DispatchBucket(lanes=(0,), k_pad=0)
    with pytest.raises(ValueError, match="tier subset"):
        DispatchBucket(lanes=(0,), k_pad=1, tiers=())


# -- the planner -------------------------------------------------------------


def test_uniform_grid_plans_exactly_one_bucket():
    """No spurious splits: a uniform-K single-footprint grid is one
    signature, hence one bucket at EVERY horizon — the CI guard's
    no-regression half."""
    for runs in (1.0, 10.0, math.inf):
        plan = plan_dispatch(np.array([8] * 12), rounds=5, tier_work=WORK,
                             runs=runs)
        assert plan.num_buckets == 1
        assert plan.buckets[0].k_pad == 8


def test_skewed_grid_splits_at_steady_state_merges_cold():
    """The horizon split that reconciles the bench's two wins: at
    runs=inf the padded-slot waste of dragging ten K=2 lanes to K=16
    dwarfs a dispatch, so the planner splits; at runs=1 a fresh compile
    dwarfs everything, so it collapses to the single padded executable
    (== the pad degenerate plan, the cold-workflow win)."""
    steady = plan_dispatch(_skewed_ks(), rounds=5, tier_work=WORK,
                           runs=math.inf)
    assert steady.num_buckets > 1
    assert [b.k_pad for b in steady.buckets] == [2, 16]
    assert steady.buckets[0].lanes == tuple(range(10))
    cold = plan_dispatch(_skewed_ks(), rounds=5, tier_work=WORK, runs=1.0)
    assert cold.num_buckets == 1
    assert cold.describe() == DispatchPlan.padded(
        _skewed_ks(), tiers=(0,)).describe()


def test_max_executables_one_is_always_the_padded_plan():
    for runs in (1.0, math.inf):
        plan = plan_dispatch(_skewed_ks(), rounds=5, tier_work=WORK,
                             max_executables=1, runs=runs)
        assert plan.num_buckets == 1
        assert plan.buckets[0].k_pad == 16
        assert plan.buckets[0].lanes == tuple(range(12))


def test_max_executables_caps_signature_count():
    ks = np.array([2, 4, 8, 16] * 3)
    full = plan_dispatch(ks, rounds=5, tier_work=WORK, runs=math.inf,
                         max_executables=8)
    assert full.num_buckets == 4          # one per distinct K
    capped = plan_dispatch(ks, rounds=5, tier_work=WORK, runs=math.inf,
                           max_executables=2)
    assert capped.num_buckets == 2
    # merges only ever RAISE k_pad: every lane still fits its bucket
    for b in capped.buckets:
        assert all(ks[i] <= b.k_pad for i in b.lanes)


def test_cached_buckets_steer_the_cold_replan():
    """Post-warmup behaviour: with the steady plan's executables marked
    cached, a one-run-horizon replan must snap to them instead of
    collapsing to an (uncompiled) padded merge — the is_cached hook is
    how a warmed arena keeps its steady split."""
    ks = _skewed_ks()
    steady = plan_dispatch(ks, rounds=5, tier_work=WORK, runs=math.inf)
    assert steady.num_buckets == 2
    warmed = {(b.k_pad, b.tiers) for b in steady.buckets}
    replan = plan_dispatch(
        ks, rounds=5, tier_work=WORK, runs=1.0,
        is_cached=lambda b: (b.k_pad, b.tiers) in warmed)
    assert {(b.k_pad, b.tiers) for b in replan.buckets} == warmed
    # and with NOTHING cached the same horizon still collapses
    cold = plan_dispatch(ks, rounds=5, tier_work=WORK, runs=1.0,
                         is_cached=lambda b: False)
    assert cold.num_buckets == 1


def test_footprints_bucket_tier_subsets_and_merge_unions():
    """Same-K lanes with different tier footprints are different
    signatures (each bucket compiles only the tiers its lanes can hit);
    under an executable cap the merge takes the footprint UNION and the
    larger K — the bitwise-safe widening direction."""
    ks = np.array([4, 4, 4, 4, 8, 8])
    fps = [(0,), (0,), (0, 2), (0, 2), (1,), (1,)]
    work = {0: 32.0, 1: 64.0, 2: 1024.0}
    plan = plan_dispatch(ks, rounds=5, tier_work=work, footprints=fps,
                         runs=math.inf, max_executables=8)
    assert {(b.k_pad, b.tiers) for b in plan.buckets} == {
        (4, (0,)), (4, (0, 2)), (8, (1,))}
    capped = plan_dispatch(ks, rounds=5, tier_work=work, footprints=fps,
                           runs=math.inf, max_executables=2)
    assert capped.num_buckets == 2
    for b in capped.buckets:
        for i in b.lanes:
            assert ks[i] <= b.k_pad
            assert set(fps[i]) <= set(b.tiers)
    # the expensive tier-2 body should not be merged onto the K=8 lanes
    # that never touch it while a cheaper merge exists
    heavy = next(b for b in capped.buckets if 2 in b.tiers)
    assert all(i in (0, 1, 2, 3) for i in heavy.lanes)


def test_planner_is_deterministic():
    ks = np.array([3, 7, 3, 7, 5, 5, 9])
    fps = None
    a = plan_dispatch(ks, rounds=4, tier_work=WORK, footprints=fps,
                      runs=math.inf)
    b = plan_dispatch(ks, rounds=4, tier_work=WORK, footprints=fps,
                      runs=math.inf)
    assert a.describe() == b.describe()


def test_planner_input_validation():
    with pytest.raises(ValueError, match="non-empty"):
        plan_dispatch(np.array([]), rounds=3)
    with pytest.raises(ValueError, match="max_executables"):
        plan_dispatch(np.array([2, 4]), rounds=3, max_executables=0)
    with pytest.raises(ValueError, match="footprints"):
        plan_dispatch(np.array([2, 4]), rounds=3, tier_work=WORK,
                      footprints=[(0,)])
    with pytest.raises(ValueError, match="unknown tiers"):
        plan_dispatch(np.array([2, 4]), rounds=3, tier_work=WORK,
                      footprints=[(0,), (0, 7)])
    with pytest.raises(ValueError, match="empty"):
        plan_dispatch(np.array([2, 4]), rounds=3, tier_work=WORK,
                      footprints=[(0,), ()])


# -- footprint replay --------------------------------------------------------


def test_lane_footprints_ignore_padding_and_sort_tiers():
    tier_of = np.array([0, 0, 1, 1, 2, 2])
    selected = np.array([
        [[5, 0, -1], [4, 1, -1]],        # lane 0: tiers {0, 2}
        [[2, 2, 3], [3, 2, 2]],          # lane 1: tier {1} only
    ])
    assert lane_footprints(selected, tier_of) == [(0, 2), (1,)]


# -- cost model --------------------------------------------------------------


def test_cost_model_prices_and_validation():
    cm = CostModel(unit_cost=1e-5, compile_cost=2.0, dispatch_cost=1e-3)
    lane = cm.lane_seconds(rounds=10, k_pad=4, tier_work=100.0)
    assert lane == pytest.approx(1e-5 * 10 * 4 * 100.0)
    # amortisation: infinite horizon drops compile entirely; cached
    # buckets never pay it
    cold = cm.bucket_seconds(3, 10, 4, 100.0, cached=False, runs=1.0)
    steady = cm.bucket_seconds(3, 10, 4, 100.0, cached=False,
                               runs=math.inf)
    cached = cm.bucket_seconds(3, 10, 4, 100.0, cached=True, runs=1.0)
    assert cold == pytest.approx(2.0 + 1e-3 + 3 * lane)
    assert steady == pytest.approx(1e-3 + 3 * lane)
    assert cached == pytest.approx(steady)
    with pytest.raises(ValueError, match="unit_cost"):
        CostModel(unit_cost=-1.0)


def test_cost_model_from_bench_json(tmp_path):
    rec = {
        "config": {"examples_per_client": 64},
        "arena": {"mixed_k": {
            "S": 12, "rounds": 5, "K_values": [4, 8, 16],
            "grouped_rounds_per_sec": 200.0,
            "grouped_cold_seconds": 15.3,
            "grouped_executables": 3,
        }},
    }
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(rec))
    cm = CostModel.from_bench_json(str(path))
    steady_s = 12 * 5 / 200.0
    row_units = 5 * 64 * 4 * (4 + 8 + 16)
    assert cm.unit_cost == pytest.approx(steady_s / row_units)
    assert cm.compile_cost == pytest.approx((15.3 - steady_s) / 3)
    # missing / malformed records fall back to the defaults
    assert CostModel.from_bench_json(
        str(tmp_path / "nope.json")) == CostModel()
    (tmp_path / "bad.json").write_text("{}")
    assert CostModel.from_bench_json(str(tmp_path / "bad.json")) == \
        CostModel()
