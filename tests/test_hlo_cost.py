"""Loop-aware HLO cost analyzer: exactness on known graphs (including the
nested-scan case XLA's own cost_analysis undercounts)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze


def _compiled(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_plain_matmul():
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((128, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 32), jnp.float32))
    res = analyze(c.as_text())
    assert abs(res["flops"] - 2 * 128 * 64 * 32) / (2 * 128 * 64 * 32) < 0.05


def test_scan_multiplies_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    res = analyze(c.as_text())
    expected = 10 * 2 * 64 ** 3
    assert abs(res["flops"] - expected) / expected < 0.05
    # XLA's own counter misses the x10 — that is the whole point
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):
        xla = xla[0]
    assert res["flops"] > 5 * float(xla.get("flops", 0.0))


def test_nested_scan():
    def f(x, w):
        def body(c, _):
            return c @ w, None

        def outer(c, _):
            y, _ = jax.lax.scan(body, c, None, length=5)
            return y, None

        z, _ = jax.lax.scan(outer, x, None, length=3)
        return z

    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    res = analyze(c.as_text())
    expected = 15 * 2 * 64 ** 3
    assert abs(res["flops"] - expected) / expected < 0.05


def test_einsum_batched():
    def f(q, k):
        return jnp.einsum("bhsd,bhtd->bhst", q, k)

    c = _compiled(f, jax.ShapeDtypeStruct((2, 3, 16, 8), jnp.float32),
                  jax.ShapeDtypeStruct((2, 3, 16, 8), jnp.float32))
    res = analyze(c.as_text())
    expected = 2 * 2 * 3 * 16 * 16 * 8
    assert abs(res["flops"] - expected) / expected < 0.1


def test_bytes_positive_and_sane():
    c = _compiled(lambda a: a + 1.0,
                  jax.ShapeDtypeStruct((1024,), jnp.float32))
    res = analyze(c.as_text())
    assert res["bytes"] >= 2 * 1024 * 4 * 0.9
