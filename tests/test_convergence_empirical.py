"""Theorem 1 — empirical check: FedAvg-with-sampling's averaged gradient
norm stays below the evaluated RHS of (18) on a problem with known
constants (quadratics: F_n(x) = 0.5||x - c_n||^2 is 1-smooth; gradients
bounded on the iterate region)."""

import numpy as np

from repro.core import BoundConstants, convergence_bound
from repro.fl.server import aggregation_weights, sample_clients


def run_fedavg(q_fn, rounds=60, n=8, k=2, epochs=2, eta=0.05, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (n, 4))
    w = rng.dirichlet(np.ones(n) * 5)
    x = np.zeros(4)
    grad_sq, qs = [], []
    for t in range(rounds):
        gbar = (w[:, None] * (x[None, :] - centers)).sum(0)
        grad_sq.append(float(np.sum(gbar ** 2)))
        q = q_fn(t, w)
        qs.append(q)
        sel = sample_clients(rng, q, k)
        coeffs = aggregation_weights(sel, q, w, k)
        delta = np.zeros(4)
        for c, i in zip(coeffs, sel):
            xi = x.copy()
            for _ in range(epochs):
                xi = xi - eta * (xi - centers[i])
            delta += c * (xi - x)
        x = x + delta
    return np.asarray(grad_sq), np.asarray(qs), centers, w


def test_grad_norm_below_theorem1_bound():
    rng = np.random.default_rng(1)

    def q_uniform(t, w):
        return np.full(len(w), 1.0 / len(w))

    grad_sq, qs, centers, w = run_fedavg(q_uniform)
    # constants: beta = 1 (quadratic), G bounds ||grad F_n|| on the region
    # reached by the iterates (||x - c_n|| <= ||c_n|| + max travel)
    G = float(np.max(np.linalg.norm(centers, axis=1))) + 2.0
    # dissimilarity: sum w ||g_n||^2 <= gamma^2 ||gbar||^2 + kappa^2 with
    # gamma = 1 and kappa^2 = max_t sum w ||x - c_n||^2 (bounded by spread)
    kappa = float(np.sqrt(np.max(
        (w * np.linalg.norm(centers, axis=1) ** 2).sum()) * 4 + 4))
    c = BoundConstants(beta=1.0, G=G, gamma=1.0, kappa=kappa,
                       f0_minus_fstar=float(
                           0.5 * (w * (centers ** 2).sum(1)).sum()))
    import jax.numpy as jnp
    bound = float(convergence_bound(c, 0.05, 2, 2, len(grad_sq),
                                    jnp.asarray(w, jnp.float32),
                                    jnp.asarray(qs, jnp.float32)))
    mean_grad = float(grad_sq.mean())
    assert mean_grad <= bound, (mean_grad, bound)
    # and the bound is not vacuous by more than a few orders of magnitude
    assert bound < 1e6


def test_importance_sampling_no_worse_than_uniform():
    """Sampling q proportional to w (Theorem 1 optimum of the q-term)
    converges at least as well as uniform on average."""
    def q_uniform(t, w):
        return np.full(len(w), 1.0 / len(w))

    def q_weighted(t, w):
        return w / w.sum()

    tail_u, tail_w = [], []
    for seed in range(5):
        gu, *_ = run_fedavg(q_uniform, seed=seed)
        gw, *_ = run_fedavg(q_weighted, seed=seed)
        tail_u.append(gu[-10:].mean())
        tail_w.append(gw[-10:].mean())
    assert np.mean(tail_w) <= np.mean(tail_u) * 1.5
