"""Docs drift guard, wired into tier-1 so broken links or stale
benchmark commands in README/docs fail locally, not just in the CI docs
job (which runs the same tools/check_docs.py)."""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_links_and_commands_resolve():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "check_docs: OK" in out.stdout
