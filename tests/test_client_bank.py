"""ClientBank data plane: bank-gathered rounds are bit-identical to the
PR-1 host-stacked path, zero client data crosses the host boundary after
bank construction, the mesh-sharded round matches single-device, and the
sharded/partial aggregation primitives match their references."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic_image_classification
from repro.data.pipeline import (bucket_examples, stack_client_arrays)
from repro.fl import (ChannelConfig, ChannelProcess, ClientBank,
                      ClientConfig, RoundEngine, aggregate_fused,
                      aggregate_stacked, ParamRavel)
from repro.models import MLPTask

BS = 16


def _client_data(sizes, seed=3):
    total = sum(sizes)
    x, y = synthetic_image_classification(total, (8, 8, 1), num_classes=4,
                                          noise=0.3, seed=seed)
    offs = np.cumsum([0] + list(sizes))
    return [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
            for i in range(len(sizes))]


def _engine_and_bank(sizes, **engine_kw):
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    eng = RoundEngine(task, ClientConfig(local_epochs=2, batch_size=BS),
                      **engine_kw)
    # this file pins the single-global-bucket ClientBank contract; the
    # bucket-ladder bank has its own suite (tests/test_tiered_bank.py)
    bank = eng.make_bank(_client_data(sizes), tiered="single")
    params = task.init(jax.random.PRNGKey(0))
    return eng, bank, params


def _round_args(k, seed=5):
    rng = np.random.default_rng(seed)
    selected = rng.integers(0, 6, k)
    coeffs = rng.dirichlet(np.ones(k)).astype(np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(seed), k)
    return selected, coeffs, rngs


def _assert_trees_bitwise(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- bank construction -----------------------------------------------------


def test_stack_client_arrays_contract():
    sizes = [40, 17, 64]
    cd = [(np.arange(n, dtype=np.float32)[:, None] + 100.0 * j,
           np.full(n, j)) for j, n in enumerate(sizes)]
    xs, ys, steps, n_ex = stack_client_arrays(cd, BS)
    b = bucket_examples(sizes, BS)
    assert xs.shape == (3, b, 1) and ys.shape == (3, b)
    assert b >= max(sizes)
    for j, n in enumerate(sizes):
        np.testing.assert_array_equal(xs[j, :, 0],
                                      (np.arange(b) % n) + 100.0 * j)
    np.testing.assert_array_equal(steps, [max(n // BS, 1) for n in sizes])
    np.testing.assert_array_equal(n_ex, sizes)


def test_bank_uniform_flag_and_device_args():
    eng, bank, _ = _engine_and_bank([64] * 6)
    assert bank.uniform and bank.bucket_examples == 64
    xs, ys, ns, ne = bank.device_args()
    assert ns is None and ne is None            # cheap unmasked trace
    assert isinstance(xs, jax.Array)
    eng, bank, _ = _engine_and_bank([64, 10, 33, 64, 100, 17])
    assert not bank.uniform
    _, _, ns, ne = bank.device_args()
    assert ns.shape == ne.shape == (6,)


# -- tentpole: bank path == PR-1 host-stacked path, bit for bit ------------


@pytest.mark.parametrize("sizes", [
    [64] * 6,                        # n_i == B everywhere: unmasked trace
    [64, 10, 33, 64, 100, 17],       # ragged incl. n < bs: masked trace
], ids=["uniform", "padded"])
def test_bank_round_matches_host_stacked_bitwise(sizes):
    eng, bank, params = _engine_and_bank(sizes)
    selected, coeffs, rngs = _round_args(k=4)
    p_bank, l_bank = eng.round_step(params, bank, selected, coeffs, 0.1,
                                    rngs)
    xs, ys, ns, ne = bank.gather_host(selected)
    p_host, l_host = eng.round_step_stacked(params, xs, ys, coeffs, 0.1,
                                            rngs, ns, ne)
    _assert_trees_bitwise(p_bank, p_host)
    np.testing.assert_array_equal(np.asarray(l_bank), np.asarray(l_host))


def test_bank_masked_trace_matches_unmasked_host_trace_bitwise():
    """A ragged bank always gathers with masks, but a selection of only
    exact-fill clients takes the UNMASKED trace on the host path — the
    shared epoch-permutation keys must make the two traces bit-identical."""
    sizes = [128, 10, 33, 64]        # bucket = 128 -> client 0 fills it
    eng, bank, params = _engine_and_bank(sizes)
    assert not bank.uniform and bank.bucket_examples == 128
    selected = np.asarray([0, 0])
    coeffs = np.asarray([0.5, 0.5], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(2), 2)
    p_bank, l_bank = eng.round_step(params, bank, selected, coeffs, 0.1,
                                    rngs)
    xs, ys, ns, ne = bank.gather_host(selected)
    assert ns is None and ne is None             # host takes unmasked trace
    p_host, l_host = eng.round_step_stacked(params, xs, ys, coeffs, 0.1,
                                            rngs)
    _assert_trees_bitwise(p_bank, p_host)
    np.testing.assert_array_equal(np.asarray(l_bank), np.asarray(l_host))


# -- acceptance: zero per-round host->device transfers of client data ------


def test_round_step_reads_no_host_data_after_bank_construction():
    """Numpy inputs touch the engine only at bank construction: corrupting
    the source arrays (and the bank's host mirror) after construction must
    not change any round — every round reads the device-resident stacks."""
    sizes = [64, 10, 33, 64, 100, 17]
    cd = _client_data(sizes)
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    eng = RoundEngine(task, ClientConfig(local_epochs=2, batch_size=BS))
    bank_ctl = eng.make_bank([(x.copy(), y.copy()) for x, y in cd],
                             tiered="single")
    bank = eng.make_bank(cd, tiered="single")
    assert isinstance(bank.xs, jax.Array)
    for x, y in cd:                      # scribble over the source data
        x[:] = np.nan
        y[:] = -1
    params = task.init(jax.random.PRNGKey(0))
    selected, coeffs, rngs = _round_args(k=4)
    p_ctl, l_ctl = eng.round_step(params, bank_ctl, selected, coeffs, 0.1,
                                  rngs)
    p, l = eng.round_step(params, bank, selected, coeffs, 0.1, rngs)
    assert np.all(np.isfinite(np.asarray(l)))
    _assert_trees_bitwise(p, p_ctl)
    np.testing.assert_array_equal(np.asarray(l), np.asarray(l_ctl))
    # the sequential-path view is the bank's private copy, also immune to
    # caller mutation...
    vx, _ = bank.client_view(0)
    assert np.all(np.isfinite(vx))
    # ...and no tiled [N, B, ...] host mirror is retained on the hot path
    # (gather_host builds one lazily for tests/benches only)
    assert bank._tiled is None
    bank.gather_host(selected)
    assert bank._tiled is not None


def test_round_step_rejects_out_of_range_selection():
    """jnp.take clips inside the jit, so the engine must keep the host
    path's IndexError for a selection outside the bank."""
    eng, bank, params = _engine_and_bank([64] * 4)
    coeffs = np.asarray([1.0], np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(0), 1)
    with pytest.raises(IndexError):
        eng.round_step(params, bank, np.asarray([4]), coeffs, 0.1, rngs)
    with pytest.raises(IndexError):
        eng.round_step(params, bank, np.asarray([-1]), coeffs, 0.1, rngs)


# -- mesh sharding: 2-device CPU == single device --------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=2")
    import numpy as np, jax
    from repro.core import paper_default_params
    from repro.data import synthetic_image_classification
    from repro.fl import ClientConfig, RoundEngine
    from repro.launch.mesh import make_fl_mesh
    from repro.models import MLPTask

    assert jax.device_count() == 2, jax.devices()
    for sizes in ([64] * 8, [64, 10, 33, 64, 100, 17, 48, 64]):
        total = sum(sizes)
        x, y = synthetic_image_classification(total, (8, 8, 1), 4,
                                              noise=0.3, seed=3)
        offs = np.cumsum([0] + list(sizes))
        cd = [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
              for i in range(len(sizes))]
        task = MLPTask(input_dim=64, num_classes=4, hidden=32)
        cfg = ClientConfig(local_epochs=2, batch_size=16)
        eng_s = RoundEngine(task, cfg, mesh=make_fl_mesh())
        eng_1 = RoundEngine(task, cfg)
        bank_s = eng_s.make_bank(cd, tiered="single")
        bank_1 = eng_1.make_bank(cd, tiered="single")
        assert "data" in str(bank_s.xs.sharding)
        params = task.init(jax.random.PRNGKey(0))
        sel = np.asarray([0, 2, 5, 7])
        coeffs = np.asarray([.2, .3, .1, .4], np.float32)
        rngs = jax.random.split(jax.random.PRNGKey(5), 4)
        p_s, l_s = eng_s.round_step(params, bank_s, sel, coeffs, .1, rngs)
        p_1, l_1 = eng_1.round_step(params, bank_1, sel, coeffs, .1, rngs)
        for a, b in zip(jax.tree_util.tree_leaves(p_s),
                        jax.tree_util.tree_leaves(p_1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_1),
                                   atol=1e-6)
        sp = paper_default_params(num_devices=len(sizes), sample_count=4,
                                  data_sizes=np.asarray(sizes, np.float32))
        h = np.random.default_rng(0).uniform(
            0.05, 0.4, (3, len(sizes))).astype(np.float32)
        lr = np.full(3, .1, np.float32)
        _, _, m_s = eng_s.run_scan(params, sp, bank_s, h, lr,
                                   jax.random.PRNGKey(1), policy="uni_d")
        _, _, m_1 = eng_1.run_scan(params, sp, bank_1, h, lr,
                                   jax.random.PRNGKey(1), policy="uni_d")
        np.testing.assert_allclose(m_s["loss"], m_1["loss"], atol=1e-6)
        # the tiered bank's tier loop must ride the same shard_map:
        # mesh-sharded multi-tier round == single-device multi-tier round
        tb_s = eng_s.make_bank(cd, tiered="tiered")
        tb_1 = eng_1.make_bank(cd, tiered="tiered")
        if tb_1.num_tiers > 1:
            sel_m = np.asarray([1, 4, 0, 5])   # spans several tiers
            assert len(np.unique(tb_1.tier_of[sel_m])) > 1
            p_s, l_s = eng_s.round_step(params, tb_s, sel_m, coeffs, .1,
                                        rngs)
            p_1, l_1 = eng_1.round_step(params, tb_1, sel_m, coeffs, .1,
                                        rngs)
            for a, b in zip(jax.tree_util.tree_leaves(p_s),
                            jax.tree_util.tree_leaves(p_1)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)
            np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_1),
                                       atol=1e-6)
            _, _, mt_s = eng_s.run_scan(params, sp, tb_s, h, lr,
                                        jax.random.PRNGKey(1),
                                        policy="uni_d")
            _, _, mt_1 = eng_1.run_scan(params, sp, tb_1, h, lr,
                                        jax.random.PRNGKey(1),
                                        policy="uni_d")
            np.testing.assert_allclose(mt_s["loss"], mt_1["loss"],
                                       atol=1e-6)
    print("SHARDED-OK")
""")


def test_sharded_round_matches_single_device(tmp_path):
    """shard_map over a 2-device CPU ('data',) mesh (forced host devices
    in a subprocess — the parent's jax is already initialised with one)
    must reproduce the single-device round and scan."""
    script = tmp_path / "shard_check.py"
    script.write_text(_SHARD_SCRIPT)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED-OK" in out.stdout


# -- sharded / partial aggregation primitives ------------------------------


def test_fl_delta_reduce_matches_reference():
    from repro.kernels import fl_delta_reduce
    rng = np.random.default_rng(0)
    deltas = rng.normal(size=(5, 257)).astype(np.float32)
    coeffs = rng.dirichlet(np.ones(5)).astype(np.float32)
    out = fl_delta_reduce(jnp.asarray(deltas), jnp.asarray(coeffs))
    np.testing.assert_allclose(np.asarray(out), coeffs @ deltas, atol=1e-6)


def test_aggregate_fused_leaf_chunked_off_tpu_matches_ravelled():
    """Off-TPU, aggregate_fused dispatches leaf-chunked (per-leaf
    tensordot, no ravel/concat) — same math as the ravelled kernel path
    (forced interpret)."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (9, 5)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (5,))}
    deltas = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 2),
                                    (3,) + p.shape), params)
    coeffs = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)
    out_auto = aggregate_fused(params, deltas, coeffs)          # leaf path
    out_kernel = aggregate_fused(params, deltas, coeffs,
                                 impl="pallas")                 # ravelled
    out_ref = aggregate_stacked(params, deltas, coeffs)
    for a, b, c in zip(jax.tree_util.tree_leaves(out_auto),
                       jax.tree_util.tree_leaves(out_kernel),
                       jax.tree_util.tree_leaves(out_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)
        np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=1e-6)


def test_aggregate_fused_psum_single_shard_matches_unsharded():
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.fl import aggregate_fused_psum
    mesh = jax.make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(3)
    params = {"w": jax.random.normal(key, (4, 3))}
    deltas = {"w": jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 3))}
    coeffs = jnp.asarray([0.7, 0.3], jnp.float32)
    body = partial(aggregate_fused_psum, axis_name="data")
    out = shard_map(body, mesh=mesh,
                    in_specs=(P(), P("data"), P("data")),
                    out_specs=P(), check_rep=False)(params, deltas, coeffs)
    expected = aggregate_fused(params, deltas, coeffs)
    _assert_trees_bitwise(out, expected)


# -- vectorised channel process --------------------------------------------


def test_channel_sample_vectorised_in_range_and_deterministic():
    cfg = ChannelConfig(seed=7)
    a = ChannelProcess(32, cfg).sample()
    b = ChannelProcess(32, cfg).sample()
    np.testing.assert_array_equal(a, b)
    assert a.shape == (32,) and a.dtype == np.float32
    assert np.all(a >= cfg.min_gain) and np.all(a <= cfg.max_gain)


def test_channel_sample_sequence_matches_chunking_and_range():
    cfg = ChannelConfig(seed=1)
    h = ChannelProcess(12, cfg).sample_sequence(300, max_block=128)
    assert h.shape == (300, 12)
    assert np.all(h >= cfg.min_gain) and np.all(h <= cfg.max_gain)
    # truncated-exponential mean sits between the bounds, near mean_gain
    assert 0.05 < h.mean() < 0.2
    # empty rollout edge case
    assert ChannelProcess(12, cfg).sample_sequence(0).shape == (0, 12)


def test_channel_sample_jax_device_resident():
    cfg = ChannelConfig(seed=0)
    proc = ChannelProcess(16, cfg)
    h_seq = proc.sample_jax(jax.random.PRNGKey(0), 20)
    assert isinstance(h_seq, jax.Array)
    assert h_seq.shape == (20, 16) and h_seq.dtype == jnp.float32
    h = np.asarray(h_seq)
    assert np.all(h >= cfg.min_gain) and np.all(h <= cfg.max_gain)
    h1 = proc.sample_jax(jax.random.PRNGKey(1))
    assert h1.shape == (16,)
    # T=0 is an empty sequence, not one phantom round
    assert proc.sample_jax(jax.random.PRNGKey(2), 0).shape == (0, 16)
