"""Round-engine behaviour: the fused vmapped-K path reproduces the seed
sequential path, the ravel adapter round-trips real pytrees, run_round works
standalone (the ``_records`` regression), and the --smoke bench mode stays
green so the perf paths can't silently rot."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LROAController, UniformDynamicController,
                        estimate_hyperparams, paper_default_params)
from repro.data import synthetic_image_classification
from repro.fl import (ChannelConfig, ChannelProcess, ClientConfig,
                      FederatedTrainer, ParamRavel, RoundEngine, aggregate,
                      aggregate_fused, aggregate_stacked, bucket_num_batches,
                      pad_client_data)
from repro.models import MLPTask
from repro.optim import constant

N_DEVICES = 8
PER_CLIENT = 64          # 64 = 4 batches of 16 -> power-of-two bucket, no pad


def _make_trainer(use_engine, controller_cls=LROAController, seed=0,
                  client_sizes=None, batch_size=16, with_test=False,
                  **trainer_kw):
    sizes = (np.full(N_DEVICES, PER_CLIENT, np.int64)
             if client_sizes is None else np.asarray(client_sizes))
    total = int(sizes.sum())
    x, y = synthetic_image_classification(total + 100, (8, 8, 1),
                                          num_classes=4, noise=0.3, seed=3)
    offs = np.cumsum(np.concatenate([[0], sizes]))
    client_data = [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
                   for i in range(len(sizes))]
    params = paper_default_params(num_devices=len(sizes),
                                  data_sizes=sizes.astype(np.float32))
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    test = (x[total:], y[total:]) if with_test else None
    return FederatedTrainer(
        task, params, controller_cls(params, hp),
        ChannelProcess(len(sizes), ChannelConfig(seed=seed)), client_data,
        ClientConfig(local_epochs=2, batch_size=batch_size), constant(0.1),
        test_data=test, eval_every=100, seed=seed, use_engine=use_engine,
        **trainer_kw)


# -- tentpole: fused path == sequential seed path -------------------------

def test_engine_matches_sequential_e2e():
    """Same seed, equal-size clients (zero padding): the fused vmapped round
    must reproduce the sequential per-client path up to f32 reduction
    order."""
    t_fast = _make_trainer(use_engine=True)
    t_slow = _make_trainer(use_engine=False)
    r_fast = t_fast.run(4)
    r_slow = t_slow.run(4)
    for a, b in zip(r_fast.records, r_slow.records):
        assert a.selected == b.selected
        assert a.mean_loss == pytest.approx(b.mean_loss, abs=1e-5)
    for p, q in zip(jax.tree_util.tree_leaves(r_fast.params),
                    jax.tree_util.tree_leaves(r_slow.params)):
        np.testing.assert_allclose(np.asarray(p), np.asarray(q), atol=2e-5)


def test_engine_handles_ragged_and_tiny_clients():
    """Unequal sizes (incl. n < batch_size) go through the tiling/bucketing
    contract; the fused path must train without recompiling per client —
    the single-bucket bank (bank_mode='single'; the default now builds a
    bucket ladder here, covered by tests/test_tiered_bank.py) means
    exactly ONE step executable."""
    sizes = [10, 33, 64, 100, 17, 48, 80, 12]
    trainer = _make_trainer(use_engine=True, client_sizes=sizes,
                            bank_mode="single")
    recs = [trainer.run_round(t) for t in range(3)]
    assert all(np.isfinite(r.mean_loss) for r in recs)
    assert len(trainer.engine._step_fns) == 1
    # the global bucket is a power-of-two number of batches
    s = trainer.bank.steps_per_epoch
    assert s & (s - 1) == 0


def test_run_scan_full_rollout():
    trainer = _make_trainer(use_engine=True)
    eng, bank = trainer.engine, trainer.bank
    assert bank.xs.shape[0] == N_DEVICES
    assert bank.num_steps.shape == bank.num_examples.shape == (N_DEVICES,)
    rounds = 5
    chan = ChannelProcess(N_DEVICES, ChannelConfig(seed=1))
    h_seq = chan.sample_sequence(rounds)
    hp = trainer.controller.hp
    params0 = trainer.task.init(jax.random.PRNGKey(7))
    params, queues, m = eng.run_scan(
        params0, trainer.params, bank, h_seq,
        np.full(rounds, 0.1, np.float32), jax.random.PRNGKey(8),
        policy="lroa", V=hp.V, lam=hp.lam)
    assert m["loss"].shape == (rounds,)
    assert m["selected"].shape == (rounds, trainer.params.sample_count)
    assert np.all(np.isfinite(m["loss"]))
    assert np.all(m["wall_time"] > 0)
    # training happened: params moved and loss trended down
    moved = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(params0)))
    assert moved > 0
    assert m["loss"][-1] < m["loss"][0]


def test_warmup_compiles_all_buckets_without_mutating_state():
    """warmup() must pre-build every executable the run can hit (the
    single-bucket bank -> exactly one; tiered warmup is covered in
    tests/test_tiered_bank.py) while leaving the trainer's RNG streams,
    params, channel, and controller untouched, so a warmed run reproduces
    an unwarmed one exactly."""
    sizes = [10, 33, 64, 100, 17, 48, 80, 12]
    t_cold = _make_trainer(use_engine=True, client_sizes=sizes,
                           bank_mode="single")
    t_warm = _make_trainer(use_engine=True, client_sizes=sizes,
                           bank_mode="single")
    t_warm.warmup()

    def traces():
        return sum(f._cache_size()
                   for f in t_warm.engine._step_fns.values())
    n_compiled, n_traces = len(t_warm.engine._step_fns), traces()
    assert n_compiled == 1   # one global bucket -> one executable
    recs_cold = [t_cold.run_round(t) for t in range(3)]
    recs_warm = [t_warm.run_round(t) for t in range(3)]
    # the measured rounds built no new executables and no new traces...
    assert len(t_warm.engine._step_fns) == n_compiled
    assert traces() == n_traces
    # ...and warmup changed nothing observable
    for a, b in zip(recs_cold, recs_warm):
        assert a.selected == b.selected
        assert a.mean_loss == pytest.approx(b.mean_loss, abs=1e-6)


def test_run_scan_uni_d_policy():
    """The uni_d branch of the fused scan (uniform q, dynamic f/p) must
    trace and produce sane decisions, not just the lroa default."""
    trainer = _make_trainer(use_engine=True)
    eng = trainer.engine
    rounds = 3
    chan = ChannelProcess(N_DEVICES, ChannelConfig(seed=2))
    h_seq = chan.sample_sequence(rounds)
    params, queues, m = eng.run_scan(
        trainer.task.init(jax.random.PRNGKey(3)), trainer.params,
        trainer.bank, h_seq, np.full(rounds, 0.1, np.float32),
        jax.random.PRNGKey(4), policy="uni_d")
    assert np.all(np.isfinite(m["loss"]))
    np.testing.assert_allclose(m["q_min"], 1.0 / N_DEVICES, rtol=1e-6)
    np.testing.assert_allclose(m["q_max"], 1.0 / N_DEVICES, rtol=1e-6)


# -- satellite: _records regression ---------------------------------------

def test_run_round_standalone():
    """run_round must work without run() (seed bug: _records only existed
    after run())."""
    trainer = _make_trainer(use_engine=True,
                            controller_cls=UniformDynamicController)
    rec = trainer.run_round(0)
    assert rec.round == 0 and rec.wall_time > 0
    assert trainer._records == [rec]


def test_evaluate_uses_device_cached_test_set():
    trainer = _make_trainer(use_engine=True, with_test=True)
    assert isinstance(trainer.test_data[0], jax.Array)
    acc = trainer.evaluate()
    assert 0.0 <= acc <= 1.0


# -- satellite: legacy aggregate shares the stacked fast path -------------

def _random_tree(key):
    def leaf(i, shape):
        return jax.random.normal(jax.random.fold_in(key, i), shape)
    return {"w1": leaf(0, (9, 5)), "b1": leaf(1, (5,)),
            "nested": {"w2": leaf(2, (5, 3)), "b2": leaf(3, (3,))}}


def test_aggregate_legacy_matches_stacked_and_fused():
    key = jax.random.PRNGKey(0)
    k = 5
    params = _random_tree(key)
    deltas = [_random_tree(jax.random.fold_in(key, 10 + i)) for i in range(k)]
    coeffs = np.asarray([0.3, 0.1, 0.25, 0.2, 0.15], np.float32)
    out_legacy = aggregate(params, deltas, coeffs)
    stacked = jax.tree_util.tree_map(lambda *ds: jnp.stack(ds), *deltas)
    out_stacked = aggregate_stacked(params, stacked, jnp.asarray(coeffs))
    out_fused = aggregate_fused(params, stacked, jnp.asarray(coeffs))
    for a, b, c in zip(jax.tree_util.tree_leaves(out_legacy),
                       jax.tree_util.tree_leaves(out_stacked),
                       jax.tree_util.tree_leaves(out_fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)


# -- satellite: ravel adapter ---------------------------------------------

def test_param_ravel_roundtrip_nested_mixed_dtypes():
    template = {
        "emb": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
        "blocks": [
            {"w": jnp.ones((2, 3, 2), jnp.float32),
             "b": jnp.zeros((2,), jnp.float32)},
            {"w": jnp.full((5,), 2.0, jnp.bfloat16),
             "b": jnp.asarray(7.0, jnp.float32)},   # 0-d leaf
        ],
    }
    adapter = ParamRavel(template)
    vec = adapter.ravel(template)
    assert vec.shape == (adapter.total,) == (12 + 12 + 2 + 5 + 1,)
    back = adapter.unravel(vec)
    assert (jax.tree_util.tree_structure(back) ==
            jax.tree_util.tree_structure(template))
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(template)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_param_ravel_stacked():
    template = {"w": jnp.zeros((3, 2)), "b": jnp.zeros((4,))}
    adapter = ParamRavel(template)
    stacked = {"w": jnp.arange(12, dtype=jnp.float32).reshape(2, 3, 2),
               "b": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}
    flat = adapter.ravel_stacked(stacked)
    assert flat.shape == (2, 10)
    # leaf order follows tree_flatten (dict keys sorted: "b" before "w")
    np.testing.assert_allclose(np.asarray(flat[0]),
                               [0, 1, 2, 3, 0, 1, 2, 3, 4, 5])
    np.testing.assert_allclose(np.asarray(flat[1]),
                               [4, 5, 6, 7, 6, 7, 8, 9, 10, 11])


# -- bucketing contract ----------------------------------------------------

def _sgd_setup(n_examples):
    task = MLPTask(input_dim=16, num_classes=3, hidden=8)
    params = task.init(jax.random.PRNGKey(0))
    x = np.random.default_rng(0).normal(
        size=(n_examples, 4, 4, 1)).astype(np.float32)
    y = np.random.default_rng(1).integers(0, 3, n_examples)
    return task, params, x, y


def test_num_steps_full_bucket_is_inert():
    """num_steps == steps_per_epoch must reproduce the unmasked path
    bitwise."""
    from repro.fl.client import batched_local_sgd
    task, params, x, y = _sgd_setup(32)
    cfg = ClientConfig(local_epochs=2, batch_size=8)
    xs, ys = x[None], y[None]
    rngs = jax.random.PRNGKey(3)[None]
    d_plain, l_plain = batched_local_sgd(task.loss_fn, params, xs, ys,
                                         jnp.float32(0.1), rngs, cfg, 4)
    d_mask, l_mask = batched_local_sgd(task.loss_fn, params, xs, ys,
                                       jnp.float32(0.1), rngs, cfg, 4,
                                       num_steps=jnp.asarray([4]))
    for a, b in zip(jax.tree_util.tree_leaves(d_plain),
                    jax.tree_util.tree_leaves(d_mask)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(l_plain), np.asarray(l_mask))


def test_num_steps_masks_to_true_step_count():
    """A padded client in a large bucket takes exactly its own number of
    SGD steps: masking steps 2..4 of a 4-step bucket equals running a
    1-step epoch on the same permuted stream."""
    from repro.fl.client import _local_sgd_body, batched_local_sgd
    task, params, x, y = _sgd_setup(32)
    cfg = ClientConfig(local_epochs=2, batch_size=8)
    rng = jax.random.PRNGKey(9)
    d_mask, l_mask = batched_local_sgd(task.loss_fn, params, x[None],
                                       y[None], jnp.float32(0.1),
                                       rng[None], cfg, 4,
                                       num_steps=jnp.asarray([1]))
    # reference: same data/rng, epochs truncated to 1 step (the first
    # batch of each epoch's permutation is identical by construction)
    p_ref, l_ref = _local_sgd_body(task.loss_fn, params, jnp.asarray(x),
                                   jnp.asarray(y), jnp.float32(0.1), rng,
                                   cfg, 1)
    d_ref = jax.tree_util.tree_map(lambda a, b: a - b, p_ref, params)
    for a, b in zip(jax.tree_util.tree_leaves(d_mask),
                    jax.tree_util.tree_leaves(d_ref)):
        np.testing.assert_allclose(np.asarray(a)[0], np.asarray(b),
                                   atol=1e-6)
    assert float(l_mask[0]) == pytest.approx(float(l_ref), abs=1e-6)

def test_bucket_contains_every_example_when_not_batch_divisible():
    """Regression: n=40, bs=16 has floor(n/bs)=2 already a power of two, so
    the bucket used to be 32 < n and the last 8 examples never trained on
    the fused path.  The global bank bucket must hold >= max_i n_i rows
    (ceil-based sizing), the tiled stream every example, and the applied
    step count must stay the floor-based Algorithm-1 count."""
    from repro.data.pipeline import bucket_examples
    task = MLPTask(input_dim=16, num_classes=3, hidden=8)
    eng = RoundEngine(task, ClientConfig(local_epochs=1, batch_size=16))
    assert bucket_examples([40], 16) >= 40
    sizes = [40, 33, 17, 64]
    rng = np.random.default_rng(0)
    client_data = [(np.arange(n, dtype=np.float32)[:, None] + 1000 * j,
                    rng.integers(0, 3, n))
                   for j, n in enumerate(sizes)]
    bank = eng.make_bank(client_data, tiered="single")
    b = bank.bucket_examples
    assert b >= max(sizes)
    xs = np.asarray(bank.xs)
    for j, n in enumerate(sizes):
        # cyclic tiling: row r of the bucket is example r mod n, so every
        # original example appears in the padded stream
        np.testing.assert_array_equal(xs[j][:, 0],
                                      (np.arange(b) % n) + 1000 * j)
    np.testing.assert_array_equal(np.asarray(bank.num_steps),
                                  [max(n // 16, 1) for n in sizes])
    np.testing.assert_array_equal(np.asarray(bank.num_examples), sizes)
    # the host gather view serves the same tiled rows as the device bank
    sx, _, ns, ne = bank.gather_host(np.asarray([2]))
    assert sx.shape[1] == b
    np.testing.assert_array_equal(sx[0][:, 0], (np.arange(b) % 17) + 2000)
    np.testing.assert_array_equal(ns, [1])
    np.testing.assert_array_equal(ne, [17])
    # and the true-example view recovers exactly the original client data
    vx, vy = bank.client_view(2)
    np.testing.assert_array_equal(vx, client_data[2][0])
    np.testing.assert_array_equal(vy, client_data[2][1])


def test_padded_sampling_draws_each_real_example_at_most_once():
    """A padded client's epoch must sample without replacement from its
    true examples only: padded duplicate rows are never drawn, and no
    example appears twice within an epoch — matching the sequential
    path's statistics (no inclusion bias toward low-index examples)."""
    from repro.fl.client import batched_local_sgd
    B, n, bs = 64, 40, 16
    x = np.eye(B, dtype=np.float32)        # row j = one-hot(j): gradient
    y = np.zeros(B, np.int32)              # counts how often j is drawn
    cfg = ClientConfig(local_epochs=1, batch_size=bs, momentum=0.0)
    params = jnp.zeros((B,))

    def loss_fn(p, batch):
        return jnp.sum(p * batch["x"]) / bs

    deltas, _ = batched_local_sgd(
        loss_fn, params, x[None], y[None], jnp.float32(1.0),
        jax.random.PRNGKey(0)[None], cfg, B // bs,
        num_steps=jnp.asarray([n // bs]), num_examples=jnp.asarray([n]))
    counts = -np.asarray(deltas[0]) * bs   # lr=1: delta_j = -count_j / bs
    np.testing.assert_array_equal(counts[n:], 0.0)   # no padded rows
    assert set(np.unique(np.round(counts, 5))) <= {0.0, 1.0}  # no repeats
    assert counts.sum() == (n // bs) * bs  # exactly num_steps full batches

    # tiny-client corner (n < bs): the single applied batch must fill up
    # with the first bs - n padded rows — by the tiling contract, the
    # exact deterministic duplicate multiset the sequential path produces
    # when local_update tiles n up to one full batch
    tiny = 10
    deltas, _ = batched_local_sgd(
        loss_fn, params, x[None], y[None], jnp.float32(1.0),
        jax.random.PRNGKey(1)[None], cfg, B // bs,
        num_steps=jnp.asarray([1]), num_examples=jnp.asarray([tiny]))
    counts = -np.asarray(deltas[0]) * bs
    # rows 0..tiny-1 are the real examples (drawn once each); rows
    # tiny..bs-1 are the first padded duplicates — in a tiled stream they
    # hold examples 0..bs-tiny-1, giving sequential counts [2]*6 + [1]*4
    np.testing.assert_array_equal(counts[:bs],
                                  [1.0] * tiny + [1.0] * (bs - tiny))
    np.testing.assert_array_equal(counts[bs:], 0.0)


def test_bucket_num_batches_power_of_two():
    assert [bucket_num_batches(s) for s in (1, 2, 3, 4, 5, 9)] == \
        [1, 2, 4, 4, 8, 16]


def test_pad_client_data_tiles_cyclically():
    x = np.arange(6).reshape(3, 2)
    y = np.asarray([0, 1, 2])
    px, py = pad_client_data(x, y, 8)
    assert px.shape == (8, 2) and py.shape == (8,)
    np.testing.assert_array_equal(py, [0, 1, 2, 0, 1, 2, 0, 1])
    same_x, same_y = pad_client_data(x, y, 3)
    assert same_x is x and same_y is y


# -- CI guard: --smoke bench ----------------------------------------------

def test_bench_smoke(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)
    from benchmarks.run import main
    # only roofline is skipped (it needs dry-run dumps): the smoke-mode
    # branches of every other section must stay exercisable
    main(["--smoke", "--skip", "roofline"])
    out = capsys.readouterr().out
    assert "kernels/fl_aggregate" in out
    assert "round_engine/fused" in out
    assert "round_engine/bank_resident" in out
    assert "round_engine/host_restacked" in out
    assert "round_engine/skewed_tiered_bank" in out
    assert "round_engine/skewed_single_bucket" in out
    # tier-aware scan skipping: the skewed scan rows for both bank modes
    assert "round_engine/skewed_scan_single" in out
    assert "round_engine/skewed_scan_tiered" in out
    assert "latency_saving_vs_uni_d" in out     # convergence section
    assert "lambda_sweep" in out and "k_sweep" in out
    assert "v_sweep" in out and "heterogeneity_sweep" in out
    # ScenarioArena section: batched vs host-looped rollout grids
    assert "arena_sweep/batched" in out
    assert "arena_sweep/host_looped" in out
    # smoke mode writes its own artifact so the tracked full-scale
    # BENCH_round_engine.json is never clobbered by tiny-shape numbers
    bench = json.loads(
        (tmp_path / "BENCH_round_engine.smoke.json").read_text())
    assert bench["engine_rounds_per_sec"] > 0
    assert bench["speedup_scan_vs_seq"] > 0
    assert bench["speedup_bank_vs_host_restacked"] > 0
    # the skewed section records the ladder's padding/memory win
    skew = bench["skewed"]
    assert skew["padded_examples_tiered"] <= skew["padded_examples_single"]
    assert skew["padded_examples_tiered"] >= skew["true_examples"]
    assert skew["tiered_rounds_per_sec"] > 0
    assert skew["tiered_scan_rounds_per_sec"] > 0
    assert skew["single_scan_rounds_per_sec"] > 0
    # the arena section lands in the same tracked record
    arena = bench["arena"]
    assert arena["K"] > 0 and arena["N"] > 0
    for key, section in arena.items():
        if key.startswith("S"):
            assert section["batched_rounds_per_sec"] > 0
            assert section["host_looped_rounds_per_sec"] > 0
    # shape-adaptive dispatch: the auto rows, the tiered skewed-arena
    # row, and the planner's split/no-split guard all ran
    assert "arena_sweep/mixed_k_auto" in out
    assert "arena_sweep/skewed_auto" in out
    assert "arena_sweep/planner_guard" in out
    mk = arena["mixed_k"]
    assert mk["auto_cold_dispatches"] == 1        # cold collapse to pad
    assert mk["auto_rounds_per_sec"] > 0
    assert len(mk["auto_steady_plan"]) == mk["auto_steady_dispatches"]
    assert arena["skewed"]["auto_rounds_per_sec"] > 0
    # million-client data plane: the int8 pooled-bank scale section —
    # churn under a STRICT watchdog (the section itself asserts zero
    # retraces and the bytes-reduction floor; reaching the record at all
    # means those contracts held)
    assert "round_engine/scale_pooled_int8" in out
    assert "round_engine/scale_hierarchical" in out
    assert "round_engine/scale_churn" in out
    scale = bench["scale"]
    assert scale["storage"] == "int8"
    assert scale["pooled_rounds_per_sec"] > 0
    assert scale["hierarchical_rounds_per_sec"] > 0
    assert scale["watchdog_retraces"] == 0
    assert scale["pool_scatter_retraces"] == 0
    assert (scale["bytes_per_client_int8_pooled"]
            < scale["bytes_per_client_fp32_oneshot"])
    assert scale["bytes_reduction"] >= 2.5
    assert scale["quant_guard_max_param_dev"] <= scale["quant_guard_tol"]
