"""System-model equation tests (eqs. 5-17) against hand-computed values."""

import jax.numpy as jnp
import numpy as np

from conftest import make_channel, make_params
from repro.core import system_model as sm


def test_uplink_rate_shannon():
    params = make_params(4)
    h = jnp.asarray([0.1, 0.1, 0.2, 0.05])
    p = jnp.asarray([0.1, 0.05, 0.1, 0.1])
    # B_n = 1 MHz / K=2 = 500 kHz
    expected = 5e5 * np.log2(1 + np.asarray(h) * np.asarray(p) / 0.01)
    np.testing.assert_allclose(np.asarray(sm.uplink_rate(params, h, p)),
                               expected, rtol=1e-6)


def test_upload_time_and_energy_consistent():
    params = make_params(4)
    h = make_channel(4)
    p = jnp.full((4,), 0.05)
    t_up = sm.upload_time(params, h, p)
    e_com = sm.comm_energy(params, h, p)
    np.testing.assert_allclose(np.asarray(e_com),
                               np.asarray(p * t_up), rtol=1e-6)


def test_compute_time_and_energy():
    params = make_params(3)
    f = jnp.asarray([1e9, 1.5e9, 2e9])
    cycles = (params.local_epochs * np.asarray(params.cycles_per_sample)
              * np.asarray(params.data_sizes))
    np.testing.assert_allclose(np.asarray(sm.compute_time(params, f)),
                               cycles / np.asarray(f), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sm.compute_energy(params, f)),
        0.5 * np.asarray(params.capacitance) * cycles * np.asarray(f) ** 2,
        rtol=1e-6)


def test_selection_probability():
    q = jnp.asarray([0.0, 0.5, 1.0])
    sel = sm.selection_probability(q, 2)
    np.testing.assert_allclose(np.asarray(sel), [0.0, 0.75, 1.0], atol=1e-7)


def test_latency_surrogate_bounds_expectation():
    """E[max] >= surrogate-under-q for uniform q equals mean; basic sanity."""
    params = make_params(8)
    h = make_channel(8)
    f = 0.5 * (params.f_min + params.f_max)
    p = 0.5 * (params.p_min + params.p_max)
    t = sm.round_time(params, h, p, f)
    q = jnp.full((8,), 1 / 8)
    surrogate = float(sm.expected_round_latency(q, t))
    assert surrogate <= float(jnp.max(t)) + 1e-6
    assert surrogate >= float(jnp.min(t)) - 1e-6


def test_weights_sum_to_one():
    params = make_params(9)
    w = np.asarray(params.data_weights)
    assert abs(w.sum() - 1.0) < 1e-6
    assert (w > 0).all()
