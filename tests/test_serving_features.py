"""Serving-path features added in §Perf: int8 quantised KV caches and the
scanned block-pattern suffix (recurrentgemma layout)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.config import ModelConfig
from repro.models.transformer import TransformerLM


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 32)) * 3.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 5, 3)
    err = jnp.abs(dequantize_kv(q, s) - x)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(jnp.max(err / jnp.maximum(amax, 1e-8))) <= 1.0 / 127 + 1e-6


def test_int8_kv_decode_matches_full_forward():
    cfg = ModelConfig(name="q8", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61,
                      block_pattern=("local", "global"), window_size=8,
                      quantized_kv=True)
    m = TransformerLM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    s = 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, 61)
    full, _, _ = m.apply(params, toks)
    cache = m.init_cache(2, s)
    assert cache["b1"]["k"].dtype == jnp.int8
    assert cache["b0"]["k"].dtype != jnp.int8        # local ring stays bf16/f32
    step = jax.jit(m.decode_step)
    worst = 0.0
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t:t + 1],
                         jnp.asarray(t, jnp.int32))
        worst = max(worst, float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert worst < 0.05, worst                        # int8 serving tolerance


def test_int8_prefill_handoff():
    cfg = ModelConfig(name="q8b", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61,
                      quantized_kv=True)
    m = TransformerLM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 13), 0, 61)
    full, _, _ = m.apply(params, toks)
    _, _, pc = m.apply(params, toks[:, :12], mode="prefill")
    ref = m.init_cache(1, 13)
    pc = jax.tree_util.tree_map(
        lambda cp, cf: jnp.pad(cp, [(0, cf.shape[i] - cp.shape[i])
                                    for i in range(cp.ndim)]), pc, ref)
    lg, _ = m.decode_step(params, pc, toks[:, 12:13],
                          jnp.asarray(12, jnp.int32))
    assert float(jnp.abs(lg[:, 0] - full[:, 12]).max()) < 0.05


def test_block_pattern_suffix_consistency():
    cfg = ModelConfig(name="sfx", family="hybrid", num_layers=5, d_model=64,
                      num_heads=4, num_kv_heads=1, d_ff=96, vocab_size=61,
                      block_pattern=("recurrent", "local"), window_size=8,
                      block_pattern_suffix=("recurrent",))
    assert cfg.num_groups == 2
    assert len(cfg.all_blocks) == 5
    m = TransformerLM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert "suffix_blocks" in params
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s + 1), 0, 61)
    full, _, _ = m.apply(params, toks)
    assert not bool(jnp.isnan(full).any())
    _, _, pc = m.apply(params, toks[:, :s], mode="prefill")
    ref = m.init_cache(2, s + 1)
    pc = jax.tree_util.tree_map(
        lambda cp, cf: jnp.pad(cp, [(0, cf.shape[i] - cp.shape[i])
                                    for i in range(cp.ndim)]), pc, ref)
    lg, _ = m.decode_step(params, pc, toks[:, s:s + 1],
                          jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, s]),
                               atol=5e-5)


def test_recurrentgemma_config_uses_suffix():
    from repro.configs import get_config
    cfg = get_config("recurrentgemma-2b")
    assert cfg.block_pattern == ("recurrent", "recurrent", "local")
    assert cfg.block_pattern_suffix == ("recurrent", "recurrent")
    assert cfg.num_groups == 8
    assert len(cfg.all_blocks) == 26


def test_flash_decode_quantized_matches_unquantized():
    from repro.models.flash import flash_decode
    rng = jax.random.PRNGKey(0)
    b, s, nq, nkv, d = 2, 64, 4, 2, 16
    q = jax.random.normal(jax.random.fold_in(rng, 1), (b, 1, nq, d))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, nkv, d))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, nkv, d))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    out_f = flash_decode(q, k, v, scale=d ** -0.5,
                         cache_index=jnp.asarray(40), block_kv=16)
    out_q = flash_decode(q, kq, vq, scale=d ** -0.5,
                         cache_index=jnp.asarray(40), block_kv=16,
                         k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_f),
                               atol=0.05)


def test_vocab_padding_exact_loss():
    """Padded vocab (shardability) leaves logits on real slots and the
    training loss bit-identical; pad slots are masked to -inf."""
    import dataclasses
    cfg = ModelConfig(name="v", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=53)
    cfgp = dataclasses.replace(cfg, vocab_pad_multiple=16)     # 53 -> 64
    assert cfgp.padded_vocab == 64
    m, mp = TransformerLM(cfg), TransformerLM(cfgp)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 53)
    params = m.init(jax.random.PRNGKey(0))
    pp = dict(mp.init(jax.random.PRNGKey(0)))
    pp["embed"] = pp["embed"].at[:53].set(params["embed"]).at[53:].set(0.0)
    pp["blocks"] = params["blocks"]
    pp["final_norm"] = params["final_norm"]
    l1, _, _ = m.apply(params, toks)
    l2, _, _ = mp.apply(pp, toks)
    np.testing.assert_allclose(np.asarray(l2[..., :53]), np.asarray(l1),
                               atol=1e-5)
    assert float(l2[..., 53:].max()) < -1e29
    loss1 = float(m.loss(params, {"tokens": toks, "labels": toks}))
    loss2 = float(mp.loss(pp, {"tokens": toks, "labels": toks}))
    assert abs(loss1 - loss2) < 1e-6
