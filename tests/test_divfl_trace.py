"""In-trace DivFL: the K-step ``lax.fori_loop`` facility-location greedy
(``repro.core.policy.facility_location_select``) is the bitwise twin of
the host greedy (``repro.core.baselines.facility_location_greedy``) — on
gradient-sketch similarities and on the shared channel-feature gram —
and the host ``DivFLController`` picks the exact subsets the arena's
traced selection emits on shared channel draws."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paper_default_params
from repro.core import policy as pol
from repro.core.baselines import DivFLController, facility_location_greedy

N = 10


def _params(n=N, k=4, seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(40, 200, n).astype(np.float32)
    return paper_default_params(num_devices=n, sample_count=k,
                                data_sizes=sizes)


def _gradient_sketch_similarity(n, dim, seed):
    """Row-normalised gram of random gradient sketches — the similarity
    DivFL's reference implementation greedily reduces."""
    g = np.random.default_rng(seed).normal(size=(n, dim)).astype(np.float32)
    gn = g / np.maximum(np.linalg.norm(g, axis=1, keepdims=True), 1e-12)
    return (gn @ gn.T).astype(np.float32)


def _greedy_min_margin(sim, k):
    """Smallest argmax winner-vs-runner-up gap along the host greedy's
    chain.  The host sums with numpy (pairwise) and the traced loop with
    XLA's reduce — different f32 association orders — so the bitwise
    selection contract is only meaningful when every step's margin
    clears that reduce-order noise (a few ulps); steps inside the noise
    band are genuine ties that the two summation orders may break
    differently."""
    n = sim.shape[0]
    best = np.full((n,), -np.inf, sim.dtype)
    chosen: list = []
    margin = np.inf
    for _ in range(k):
        gains = np.maximum(best[:, None], sim).sum(axis=0)
        gains[chosen] = -np.inf
        order = np.argsort(gains)[::-1]
        if len(order) > 1 and np.isfinite(gains[order[1]]):
            margin = min(margin, float(gains[order[0]] - gains[order[1]]))
        j = int(order[0])
        chosen.append(j)
        best = np.maximum(best, sim[:, j])
    return margin


def test_fori_loop_greedy_bitwise_matches_host_greedy_on_sketches():
    """The traced greedy and the host greedy walk the SAME argmax chain
    on shared gradient-sketch similarity matrices — selections identical
    element for element, every prefix length, on every instance whose
    margins exceed reduce-order noise."""
    checked = 0
    for seed in range(10):
        sim = _gradient_sketch_similarity(N, 16, seed)
        for k in (1, 3, 4, N):
            if _greedy_min_margin(sim, k) < 1e-5:
                continue
            host = facility_location_greedy(sim, k)
            traced = jax.jit(pol.facility_location_select,
                             static_argnums=1)(jnp.asarray(sim), k)
            np.testing.assert_array_equal(np.asarray(traced),
                                          np.asarray(host))
            checked += 1
    assert checked >= 12        # the filter must not hollow the test out


def test_fori_loop_greedy_matches_host_on_channel_feature_gram():
    """Same bitwise contract on the (data_weight, gain) feature gram the
    arena actually traces."""
    params = _params()
    checked = 0
    for seed in range(8):
        h = np.random.default_rng(100 + seed).uniform(
            0.02, 0.4, N).astype(np.float32)
        sim = np.asarray(pol.divfl_similarity(
            pol.divfl_features(params, jnp.asarray(h))))
        if _greedy_min_margin(sim, params.sample_count) < 1e-5:
            continue
        host = facility_location_greedy(sim, params.sample_count)
        traced = jax.jit(pol.facility_location_select, static_argnums=1)(
            jnp.asarray(sim), params.sample_count)
        np.testing.assert_array_equal(np.asarray(traced),
                                      np.asarray(host))
        checked += 1
    assert checked >= 4


def test_greedy_prefix_stability_under_padded_k():
    """Padded-K contract: the first k entries of a K_max-slot greedy are
    exactly the k-slot greedy (step i reads only steps < i), so a padded
    lane's active prefix is the true-K selection."""
    sim = _gradient_sketch_similarity(N, 8, 42)
    full = np.asarray(pol.facility_location_select(jnp.asarray(sim), N))
    for k in range(1, N):
        np.testing.assert_array_equal(
            np.asarray(pol.facility_location_select(jnp.asarray(sim), k)),
            full[:k])


def test_greedy_selects_distinct_clients():
    for seed in range(4):
        sim = _gradient_sketch_similarity(N, 4, seed)
        sel = np.asarray(pol.facility_location_select(jnp.asarray(sim), N))
        assert sorted(sel.tolist()) == list(range(N))


def test_host_controller_channel_path_matches_traced_selection():
    """``DivFLController.select(h)`` (no observed updates yet) and the
    traced ``divfl_selection`` pick the identical subset on shared
    channel draws — the contract that keeps host replays of arena DivFL
    lanes valid."""
    params = _params()
    ctrl = DivFLController(params)
    slots = jnp.arange(params.sample_count)
    kvec = jnp.full((N,), float(params.sample_count), jnp.float32)
    checked = 0
    for seed in range(8):
        h = jnp.asarray(np.random.default_rng(200 + seed).uniform(
            0.02, 0.4, N).astype(np.float32))
        sim = np.asarray(pol.divfl_similarity(
            pol.divfl_features(params, h)))
        if _greedy_min_margin(sim, params.sample_count) < 1e-5:
            continue
        host = ctrl.select(h)
        traced = pol.divfl_selection(
            params, jnp.int32(0), h, jnp.zeros((N,), jnp.float32),
            jnp.full((N,), 1.0 / N, jnp.float32), jax.random.PRNGKey(0),
            slots, kvec)
        np.testing.assert_array_equal(np.asarray(traced),
                                      np.asarray(host))
        checked += 1
    assert checked >= 4


def test_host_controller_observed_updates_take_precedence():
    """Once the sequential path records local-update sketches, the host
    controller reduces THEIR similarity (the reference semantics), not
    the channel features."""
    params = _params()
    ctrl = DivFLController(params)
    g = np.random.default_rng(7).normal(size=(N, 12)).astype(np.float32)
    ctrl.observe_updates(np.arange(N), g)
    h = jnp.asarray(np.full(N, 0.1, np.float32))
    got = ctrl.select(h)
    gn = g / np.maximum(np.linalg.norm(g, axis=1, keepdims=True), 1e-12)
    want = facility_location_greedy(gn @ gn.T, params.sample_count)
    np.testing.assert_array_equal(got, want)
    # and with neither updates nor gains: the deterministic fallback
    assert np.array_equal(DivFLController(params).select(),
                          np.arange(params.sample_count) % N)
