"""ScenarioArena: the scenario-batched sweep engine reproduces individual
``run_scan`` rollouts lane for lane (model trajectory bitwise, control
diagnostics to f32 resolution), including mixed-controller grids, tiered
banks, mixed sampling counts, and a 2-device CPU scenario-sharded
subprocess case; plus the controller-as-data dispatch, grid construction,
report reducers, tier-skipping cond, and the pure-jax hyper-parameter
estimates the arena derives per scenario."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (POLICIES, POLICY_IDS, decide_by_id,
                        estimate_hyperparams, estimate_hyperparams_arrays,
                        paper_default_params)
from repro.core import policy as pol
from repro.data import synthetic_image_classification
from repro.fl import ClientConfig, RoundEngine
from repro.models import MLPTask
from repro.sim import (Arena, CostModel, EvalBank, RolloutReport,
                       ScenarioGrid, aot_cache_warmup_supported,
                       derive_hyperparams, scenario_keys)

N = 6
BS = 16
# the model trajectory must match bitwise; the queue/energy diagnostics
# come out of Algorithm 2's bisection solver, whose elementwise chains
# XLA fuses shape-dependently — those agree to f32 resolution instead
BITWISE_METRICS = ("loss", "selected", "wall_time")
TOL = dict(rtol=1e-5, atol=1e-4)


def _client_data(sizes, seed=3):
    total = sum(sizes)
    x, y = synthetic_image_classification(total, (8, 8, 1), num_classes=4,
                                          noise=0.3, seed=seed)
    offs = np.cumsum([0] + list(sizes))
    return [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
            for i in range(len(sizes))]


def _setup(sizes=None, bank_mode="single"):
    sizes = [64] * N if sizes is None else sizes
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    eng = RoundEngine(task, ClientConfig(local_epochs=2, batch_size=BS))
    bank = eng.make_bank(_client_data(sizes), tiered=bank_mode)
    sp = paper_default_params(num_devices=len(sizes), sample_count=4,
                              data_sizes=np.asarray(sizes, np.float32))
    params0 = task.init(jax.random.PRNGKey(0))
    return task, eng, bank, sp, params0


def _mixed_grid(s=8, k=4):
    """Mixed-controller, mixed-(V, lam, budget, channel) grid of S lanes."""
    ctrl = [POLICIES[i % len(POLICIES)] for i in range(s)]
    return ScenarioGrid.create(
        controllers=ctrl, seeds=np.arange(s),
        V=np.linspace(10.0, 1e4, s).astype(np.float32),
        lam=np.linspace(0.1, 5.0, s).astype(np.float32),
        energy_scale=([1.0, 2.0, 0.5, 1.0] * ((s + 3) // 4))[:s],
        mean_gain=([0.1, 0.2, 0.05, 0.1] * ((s + 3) // 4))[:s],
        sample_count=k)


def _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr, s,
                         model_bitwise=True, drop_all=None):
    """Arena lane ``s`` == the individual run_scan reproduction of it.

    ``model_bitwise=False`` relaxes the model trajectory to tight
    allclose — the tiered scan's per-tier ``lax.cond`` lowers as a real
    branch in the unbatched program but as a both-branches select under
    the arena's vmap, so tiered lanes agree to f32 resolution instead of
    bitwise."""
    _, roll_keys = scenario_keys(grid)
    sp_s = grid.scenario_system_params(sp, s)
    p, q, m = eng.run_scan(params0, sp_s, bank, np.asarray(h_all[s]), lr,
                           roll_keys[s], policy=grid.controller_names()[s],
                           V=float(grid.V[s]), lam=float(grid.lam[s]),
                           drop_seq=(None if drop_all is None
                                     else np.asarray(drop_all[s])))
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(rep.scenario_params(s))):
        if model_bitwise:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
    k = int(grid.sample_count[s])
    for name in BITWISE_METRICS:
        got = rep.metrics[name][s]
        if name == "selected":
            got = got[..., :k]       # strip mixed-K right-padding
        if model_bitwise or name == "selected":
            np.testing.assert_array_equal(m[name], got)
        else:
            np.testing.assert_allclose(m[name], got, **TOL)
    for name in m:
        if name in BITWISE_METRICS:
            continue
        np.testing.assert_allclose(m[name], rep.metrics[name][s], **TOL)
    np.testing.assert_allclose(np.asarray(q), rep.queues[s], **TOL)


# -- tentpole: S-lane arena == S individual run_scan rollouts --------------


def test_arena_mixed_controller_grid_matches_individual_rollouts():
    """An S=8 mixed-controller (lroa/uni_d/uni_s), mixed-hyperparameter
    grid runs as ONE vmapped program whose every lane reproduces the
    fixed-policy run_scan rollout of that scenario."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=8)
    arena = Arena(eng)
    T = 4
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, N)
    assert h_all.shape == (8, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert isinstance(rep, RolloutReport)
    assert rep.metrics["loss"].shape == (8, T)
    assert rep.metrics["selected"].shape == (8, T, 4)
    # exactly one executable compiled for the whole mixed grid
    assert len(arena._fns) == 1
    for s in range(8):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s)


def test_arena_map_mode_lanes_match_individual_rollouts():
    """batch='map' lays lanes out as lax.map iterations (per-lane solver
    trip counts, no vmap lockstep) — the model trajectory must still be
    bitwise against the individual run_scan reproductions."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_grid(s=4)
    arena = Arena(eng, batch="map")
    T = 4
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s)
    with pytest.raises(ValueError, match="batch mode"):
        Arena(eng, batch="bogus")


def test_scenario_keys_vectorised_matches_per_seed_host_loop():
    """The jitted/vmapped key derivation must be bitwise identical to
    building PRNGKey(seed) and splitting per scenario on the host — the
    reproducibility contract individual run_scan replays rely on."""
    grid = ScenarioGrid.create(controllers=["lroa"] * 4,
                               seeds=[0, 1, 7, 123456], V=1.0, lam=1.0)
    chan, roll = scenario_keys(grid)
    for s, seed in enumerate(grid.seed):
        root = jax.random.PRNGKey(int(seed))
        ck, rk = jax.random.split(root)
        np.testing.assert_array_equal(np.asarray(chan[s]), np.asarray(ck))
        np.testing.assert_array_equal(np.asarray(roll[s]), np.asarray(rk))


def test_arena_tiered_bank_lanes_match_individual_tiered_scans():
    """The arena rides the tiered scan plan (per-tier lax.cond inside the
    vmapped body) — every lane must still reproduce the individual
    tiered run_scan."""
    sizes = [64, 10, 33, 64, 100, 17]
    task, eng, bank, sp, params0 = _setup(sizes, bank_mode="tiered")
    assert bank.num_tiers > 1
    grid = ScenarioGrid.create(controllers=["lroa", "uni_d", "uni_s",
                                            "lroa"],
                               seeds=[3, 4, 5, 6], V=200.0, lam=1.0,
                               sample_count=4)
    arena = Arena(eng)
    T = 3
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, len(sizes))
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s, model_bitwise=False)


def _mixed_k_grid():
    return ScenarioGrid.create(controllers=["lroa", "uni_d", "lroa",
                                            "uni_s", "uni_d", "lroa"],
                               seeds=[0, 1, 2, 3, 4, 5], V=100.0, lam=0.5,
                               sample_count=[2, 4, 2, 4, 3, 3])


def test_arena_mixed_sample_counts_group_by_k():
    """The legacy grouped path (k_mode='group'): one jitted program per
    distinct K, lanes scattered back into grid order (selected
    right-padded with -1), and the per-group compile/dispatch counts
    reported in the report metadata."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    arena = Arena(eng, k_mode="group")
    T = 3
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert len(arena._fns) == 3                      # one program per K
    assert rep.meta["k_mode"] == "group"
    assert rep.meta["k_groups"] == [2, 3, 4]
    assert rep.meta["dispatches"] == 3
    assert rep.meta["executables_built"] == 3
    assert rep.metrics["selected"].shape == (6, T, 4)
    assert np.all(rep.metrics["selected"][0, :, 2:] == -1)   # K=2 lanes
    assert np.all(rep.metrics["selected"][1, :, 2:] >= 0)    # K=4 lanes
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s)


# -- tentpole: padded-K dispatch fusion ------------------------------------


def test_padded_mixed_k_single_program_bitwise_vs_groups():
    """A mixed-K grid (3 distinct K values) under the default
    k_mode='pad' runs as ONE compiled executable whose padded lanes
    (k_active < K_max) are bitwise-equal — params / loss / selected /
    wall_time on the leaf-chunked path — to the per-K groups they
    replace, and to the individual run_scan reproductions."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 3
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng)
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep.meta["k_mode"] == "pad"
    assert rep.meta["dispatches"] == 1
    assert rep.meta["executables_built"] == 1
    assert len(arena._fns) == 1                  # ONE padded executable
    # output layout matches the grouped convention: [S, T, K_max], -1 pad
    assert rep.metrics["selected"].shape == (6, T, 4)
    assert np.all(rep.metrics["selected"][0, :, 2:] == -1)
    # bitwise vs the per-K grouped execution of the SAME grid
    grouped = Arena(eng, k_mode="group")
    rep_g = grouped.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(rep_g.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in BITWISE_METRICS:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_g.metrics[name])
    for name in rep.metrics:
        if name not in BITWISE_METRICS:
            np.testing.assert_allclose(rep.metrics[name],
                                       rep_g.metrics[name], **TOL)
    # ...and vs the individual fixed-policy run_scan rollouts
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s)


def test_padded_mixed_k_map_mode_bitwise():
    """batch='map' lanes of a padded mixed-K grid (sequential traces, no
    vmap lockstep) keep the bitwise padded-lane contract."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid().take(np.arange(4))
    T = 3
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng, batch="map")
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert len(arena._fns) == 1
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s)


def test_padded_mixed_k_tiered_bank_lanes():
    """A tiered-bank mixed-K padded grid still reproduces the individual
    tiered run_scan per lane (f32 resolution — the per-tier lax.cond
    lowers as select under vmap)."""
    sizes = [64, 10, 33, 64, 100, 17]
    task, eng, bank, sp, params0 = _setup(sizes, bank_mode="tiered")
    assert bank.num_tiers > 1
    grid = ScenarioGrid.create(controllers=["lroa", "uni_d", "uni_s",
                                            "lroa"],
                               seeds=[3, 4, 5, 6], V=200.0, lam=1.0,
                               sample_count=[2, 4, 3, 4])
    arena = Arena(eng)
    T = 3
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, len(sizes))
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert len(arena._fns) == 1
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s, model_bitwise=False)


# -- tentpole: controller zoo x non-stationary channels --------------------


def test_zoo_grid_stationary_and_markov_single_run_lane_replay():
    """The headline grid: ALL registered controllers (in-trace DivFL and
    round-robin included) x {stationary, Gilbert-Elliott} channel modes
    runs as ONE ``Arena.run``, every lane bitwise-reproducing its
    fixed-policy ``run_scan`` reference, and the report reduces to the
    Sec.-VII-style trade-off table with one point per
    (controller, channel-mode) configuration."""
    task, eng, bank, sp, params0 = _setup()
    grid = ScenarioGrid.product(
        controllers=tuple(POLICIES), seeds=(0,), V=(100.0,), lam=(0.5,),
        sample_count=(4,), chan_mode=("iid", "markov"), p_gb=(0.2,),
        p_bg=(0.5,), num_devices=N)
    s_total = 2 * len(POLICIES)
    assert len(grid) == s_total and len(POLICIES) >= 6
    arena = Arena(eng)
    T = 3
    lr = np.full(T, 0.1, np.float32)
    h_all = arena.sample_channels(grid, T, N)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert len(arena._fns) == 1          # one executable, whole zoo
    for s in range(s_total):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all,
                             lr, s)
    table = rep.tradeoff_table()
    assert len(table) == s_total
    assert ({(r["controller"], r["chan_mode"]) for r in table}
            == {(c, m) for c in POLICIES for m in ("iid", "markov")})


def test_zoo_grid_auto_mode_plans_single_dispatch():
    """Satellite guard: the mixed 6+-controller grid under
    ``k_mode='auto'`` executes as ONE planned dispatch bucket."""
    task, eng, bank, sp, params0 = _setup()
    grid = ScenarioGrid.product(
        controllers=tuple(POLICIES), seeds=(0,), V=(100.0,), lam=(0.5,),
        sample_count=(4,), chan_mode=("iid", "markov"), p_gb=(0.2,),
        p_bg=(0.5,), num_devices=N)
    arena = Arena(eng, k_mode="auto")
    T = 3
    rep = arena.run(params0, sp, bank, grid, T,
                    np.full(T, 0.1, np.float32))
    acct = rep.dispatch_accounting()
    assert acct["buckets"] == 1
    assert acct["dispatches"] == 1
    assert acct["lanes_covered"] == len(grid)


def test_dropout_lanes_match_run_scan_and_leave_clean_lanes_bitwise():
    """Per-client dropout lanes replay bitwise against ``run_scan`` with
    the same ``drop_seq``; a zero-dropout lane in the SAME grid stays
    bitwise equal to the historical no-dropout executable's trajectory
    (satellite: adding the dropout axis must not move clean lanes)."""
    task, eng, bank, sp, params0 = _setup()
    T = 4
    lr = np.full(T, 0.1, np.float32)
    grid = ScenarioGrid.create(
        controllers=["lroa", "uni_d", "channel_aware", "divfl"],
        seeds=[0, 1, 2, 3], V=100.0, lam=0.5, sample_count=4,
        dropout=[0.0, 0.4, 0.4, 0.4])
    arena = Arena(eng)
    h_all = arena.sample_channels(grid, T, N)
    drop_all = arena.sample_dropout(grid, T, N)
    # lane 0 has dropout 0.0: its mask column is all-ones
    assert np.all(np.asarray(drop_all[0]) == 1.0)
    assert np.any(np.asarray(drop_all[1:]) == 0.0)
    rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all,
                             lr, s, drop_all=drop_all)
    # the clean lane vs the historical no-dropout executable (a grid
    # whose dropout column is all zero skips the mask entirely): model
    # trajectory and selections stay bitwise; the loss column crosses
    # two executables whose reduce XLA fuses differently, so it agrees
    # to f32 resolution instead
    clean = grid.take(np.asarray([0]))
    rep0 = Arena(eng).run(params0, sp, bank, clean, T, lr,
                          h_all=h_all[:1])
    for a, b in zip(jax.tree_util.tree_leaves(rep.scenario_params(0)),
                    jax.tree_util.tree_leaves(rep0.scenario_params(0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(rep.metrics["selected"][0],
                                  rep0.metrics["selected"][0])
    np.testing.assert_array_equal(rep.metrics["wall_time"][0],
                                  rep0.metrics["wall_time"][0])
    np.testing.assert_allclose(rep.metrics["loss"][0],
                               rep0.metrics["loss"][0], rtol=1e-6)


# -- controller-as-data dispatch -------------------------------------------


def test_decide_by_id_matches_named_policies():
    sp = paper_default_params(num_devices=N, sample_count=3,
                              data_sizes=np.full(N, 64, np.float32))
    h = jnp.asarray(np.random.default_rng(0).uniform(0.02, 0.4, N)
                    .astype(np.float32))
    queues = jnp.asarray(np.random.default_rng(1).uniform(0, 300, N)
                         .astype(np.float32))
    v = jnp.full((N,), 50.0, jnp.float32)
    lam = jnp.full((N,), 0.7, jnp.float32)
    for name, fn in zip(POLICIES, pol.DECIDE_FNS):
        # jit the direct rule too: the switch is bitwise-faithful to the
        # COMPILED branch (what every arena/run_scan trace executes);
        # eager mode dispatches op-by-op and XLA's fused division chains
        # (cost_effective's q normalisation) drift 1 ulp from that
        direct = jax.jit(fn)(sp, h, queues, v, lam)
        switched = jax.jit(decide_by_id)(jnp.int32(POLICY_IDS[name]), sp,
                                         h, queues, v, lam)
        for a, b in zip(direct, switched):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(fn(sp, h, queues, v, lam), switched):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


def test_controllers_are_thin_wrappers_over_policy_fns():
    """The stateful classes and the pure rules must make identical
    decisions — the wrapper refactor cannot fork the math."""
    from repro.core import (LROAController, UniformDynamicController,
                            UniformStaticController)
    sp = paper_default_params(num_devices=N, sample_count=3,
                              data_sizes=np.full(N, 64, np.float32))
    hp = estimate_hyperparams(sp, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    h = jnp.asarray(np.random.default_rng(2).uniform(0.02, 0.4, N)
                    .astype(np.float32))
    for cls, fn, (v, lam) in [
            (LROAController, pol.decide_lroa, (hp.V, hp.lam)),
            (UniformDynamicController, pol.decide_uni_d, (hp.V, hp.lam)),
            (UniformStaticController, pol.decide_uni_s, (0.0, 0.0))]:
        ctrl = cls(sp, hp)
        ctrl.queues = jnp.asarray(
            np.random.default_rng(3).uniform(0, 300, N).astype(np.float32))
        got = ctrl.decide(h)
        want = fn(sp, h, ctrl.queues, jnp.float32(v), jnp.float32(lam))
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_run_scan_uni_s_policy():
    """uni_s joins the scan-traceable policies (static resources)."""
    task, eng, bank, sp, params0 = _setup()
    T = 3
    h = np.random.default_rng(0).uniform(0.05, 0.4, (T, N)).astype(
        np.float32)
    params, queues, m = eng.run_scan(params0, sp, bank, h,
                                     np.full(T, 0.1, np.float32),
                                     jax.random.PRNGKey(1),
                                     policy="uni_s")
    assert np.all(np.isfinite(m["loss"]))
    np.testing.assert_allclose(m["q_min"], 1.0 / N, rtol=1e-6)
    with pytest.raises(ValueError, match="scan-traceable"):
        eng.run_scan(params0, sp, bank, h, np.full(T, 0.1, np.float32),
                     jax.random.PRNGKey(1), policy="bogus")


def test_run_scan_accepts_every_registered_policy():
    """Every controller in the zoo — in-trace DivFL and round-robin
    included — is a fixed-policy run_scan citizen."""
    task, eng, bank, sp, params0 = _setup()
    T = 3
    h = np.random.default_rng(5).uniform(0.05, 0.4, (T, N)).astype(
        np.float32)
    lr = np.full(T, 0.1, np.float32)
    for policy in POLICIES:
        params, queues, m = eng.run_scan(params0, sp, bank, h, lr,
                                         jax.random.PRNGKey(2),
                                         policy=policy, V=50.0, lam=0.5)
        assert np.all(np.isfinite(m["loss"])), policy
        sel = np.asarray(m["selected"])
        assert sel.shape == (T, sp.sample_count)
        assert np.all((sel >= 0) & (sel < N)), policy


# -- grid construction ------------------------------------------------------


def test_grid_product_and_validation():
    grid = ScenarioGrid.product(controllers=("lroa", "uni_d"),
                                seeds=(0, 1, 2), V=(10.0, 100.0),
                                lam=(0.5,))
    assert len(grid) == 12
    assert set(grid.controller_names()) == {"lroa", "uni_d"}
    sub = grid.take(np.asarray([0, 5]))
    assert len(sub) == 2
    # DivFL is a first-class lane now (in-trace facility-location greedy)
    gd = ScenarioGrid.create(controllers=["divfl"], seeds=[0], V=1.0,
                             lam=1.0)
    assert gd.controller_names() == ["divfl"]
    with pytest.raises(ValueError, match="unknown controller"):
        ScenarioGrid.create(controllers=["bogus"], seeds=[0], V=1.0,
                            lam=1.0)
    with pytest.raises(ValueError, match="out of range"):
        ScenarioGrid.create(controllers=[7], seeds=[0], V=1.0, lam=1.0)
    # PRNGKey truncates to 32 bits: wider seeds would silently alias lanes
    with pytest.raises(ValueError, match="uint32"):
        ScenarioGrid.create(controllers=["lroa"], seeds=[2 ** 32 + 1],
                            V=1.0, lam=1.0)


def test_arena_rejects_meshed_engine():
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    eng = RoundEngine(task, ClientConfig(local_epochs=2, batch_size=BS),
                      mesh=jax.make_mesh((1,), ("data",)))
    with pytest.raises(ValueError, match="without a mesh"):
        Arena(eng)


# -- report reducers --------------------------------------------------------


def test_report_reducers_and_tradeoff_table():
    task, eng, bank, sp, params0 = _setup()
    grid = ScenarioGrid.product(controllers=("lroa", "uni_d"),
                                seeds=(0, 1), V=(100.0,), lam=(0.5,),
                                sample_count=(4,))
    arena = Arena(eng)
    T = 3
    lr = np.full(T, 0.1, np.float32)
    rep = arena.run(params0, sp, bank, grid, T, lr)
    s = len(grid)
    assert rep.latency_curve().shape == (s, T)
    assert np.all(np.diff(rep.latency_curve(), axis=1) > 0)
    np.testing.assert_allclose(rep.total_latency(),
                               rep.latency_curve()[:, -1], rtol=1e-6)
    counts = rep.selection_counts(N)
    assert counts.shape == (s, N)
    assert np.all(counts.sum(axis=1) == T * 4)
    table = rep.tradeoff_table()
    # 2 controllers x 1 (V, lam) config, each aggregating 2 seeds
    assert len(table) == 2
    assert all(row["num_seeds"] == 2 for row in table)
    assert {row["controller"] for row in table} == {"lroa", "uni_d"}
    rows = rep.summary()
    assert len(rows) == s and rows[0]["total_latency"] > 0


# -- tier-aware scan skipping ----------------------------------------------


def test_tier_loop_cond_skip_matches_unconditional():
    """The selection-conditioned lax.cond wrapper around each tier's body
    (the scan path's skip) must reproduce the unconditional tier loop."""
    sizes = [64, 10, 33, 64, 100, 17]
    task, eng, bank, sp, params0 = _setup(sizes, bank_mode="tiered")
    round_fn, data, _ = eng._scan_plan(bank)
    sel = np.asarray([1, 4, 0, 5])           # hits several tiers
    assert len(np.unique(bank.tier_of[sel])) > 1
    coeffs = jnp.asarray([.2, .3, .1, .4], jnp.float32)
    rngs = jax.random.split(jax.random.PRNGKey(5), 4)

    from repro.fl.round_engine import _tier_parts
    parts_key = tuple((t, tier.steps_per_epoch,
                       tier.device_args()[2] is not None)
                      for t, tier in enumerate(bank.tiers))
    bufs = tuple(tier.device_args() for tier in bank.tiers)
    tier_sel = jnp.asarray(bank.tier_of[sel], jnp.int32)
    pos_sel = jnp.asarray(bank.pos_in_tier[sel], jnp.int32)

    def run(cond_skip):
        fn = jax.jit(lambda p: eng._tier_loop_round(
            p, _tier_parts(parts_key, bufs), tier_sel, pos_sel, coeffs,
            jnp.float32(0.1), rngs, cond_skip=cond_skip))
        return fn(params0)

    p_cond, l_cond = run(True)
    p_ref, l_ref = run(False)
    for a, b in zip(jax.tree_util.tree_leaves(p_cond),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-7)
    np.testing.assert_allclose(np.asarray(l_cond), np.asarray(l_ref),
                               atol=1e-7)


# -- on-device batched evaluation ------------------------------------------


def _test_set(n=48, seed=11):
    return synthetic_image_classification(n, (8, 8, 1), num_classes=4,
                                          noise=0.3, seed=seed)


def test_on_device_eval_matches_host_metrics_per_lane():
    """EvalBank's batched final evaluation and the in-scan eval columns
    must match per-lane host-side task.metrics to f32 resolution, on a
    padded mixed-K grid, without touching the model trajectory."""
    task, eng, bank, sp, params0 = _setup()
    xte, yte = _test_set()
    eb = EvalBank(task, xte, yte)
    grid = _mixed_k_grid()
    T = 4
    lr = np.full(T, 0.1, np.float32)
    arena = Arena(eng)
    h_all = arena.sample_channels(grid, T, N)
    rep_plain = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    arena_ev = Arena(eng)
    rep = arena_ev.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                       eval_bank=eb, eval_every=2)
    # evaluation only READS params: trajectory identical to the plain run
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(rep_plain.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in rep_plain.metrics:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_plain.metrics[name])
    assert rep.metrics["test_accuracy"].shape == (len(grid), T)
    assert rep.metrics["test_loss"].shape == (len(grid), T)
    xte_d, yte_d = jnp.asarray(xte), jnp.asarray(yte)
    for s in range(len(grid)):
        host = task.metrics(rep.scenario_params(s),
                            {"x": xte_d, "y": yte_d})
        # final batched eval == host per-lane eval
        assert rep.final_metrics["test_accuracy"][s] == pytest.approx(
            float(host["accuracy"]), abs=1e-5)
        assert rep.final_metrics["test_loss"][s] == pytest.approx(
            float(host["loss"]), rel=1e-5)
        # T=4, eval_every=2: the last column evaluated the final params
        assert rep.metrics["test_accuracy"][s, -1] == pytest.approx(
            float(host["accuracy"]), abs=1e-5)
        assert rep.metrics["test_loss"][s, -1] == pytest.approx(
            float(host["loss"]), rel=1e-5)
        # off-rounds hold the previous evaluation (step curve)
        np.testing.assert_array_equal(rep.metrics["test_accuracy"][s, 2],
                                      rep.metrics["test_accuracy"][s, 1])
    # reducers surface the accuracy half of the trade-off
    assert rep.accuracy_curve().shape == (len(grid), T)
    np.testing.assert_array_equal(rep.final_accuracy(),
                                  rep.final_metrics["test_accuracy"])
    table = rep.tradeoff_table()
    assert all("test_accuracy" in row for row in table)
    with pytest.raises(KeyError, match="eval_bank"):
        rep_plain.accuracy_curve()
    with pytest.raises(ValueError, match="eval_bank"):
        arena.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                  eval_every=2)


# -- warmup / executable cache ---------------------------------------------


def test_arena_warmup_then_run_zero_new_traces():
    """Arena.warmup compiles the padded executable; subsequent same-shape
    runs (different V/lam/seeds — the iterate-on-V workflow) must perform
    ZERO new scan-body traces."""
    task, eng, bank, sp, params0 = _setup()
    xte, yte = _test_set()
    eb = EvalBank(task, xte, yte)
    grid = _mixed_k_grid()
    T = 3
    arena = Arena(eng)
    stats = arena.warmup(params0, sp, bank, grid, T, eval_bank=eb,
                         eval_every=2)
    assert stats["executables_built"] == 1
    assert stats["traces"] >= 1
    traces0 = arena.traces
    # same shapes, different values: new V/lam, new seeds, real lr
    import dataclasses as dc
    grid2 = dc.replace(grid, V=grid.V * 3.0, lam=grid.lam + 0.5,
                       seed=grid.seed + 100)
    lr = np.full(T, 0.1, np.float32)
    rep = arena.run(params0, sp, bank, grid2, T, lr, eval_bank=eb,
                    eval_every=2)
    rep2 = arena.run(params0, sp, bank, grid2, T, lr * 0.5, eval_bank=eb,
                     eval_every=2)
    assert arena.traces == traces0          # zero new traces after warmup
    assert rep.meta["executables_built"] == 0
    assert rep2.meta["executables_built"] == 0
    assert np.all(np.isfinite(rep.metrics["loss"]))


# -- shape-adaptive dispatch (k_mode='auto') --------------------------------


# compile amortisation zeroed out: the planner splits by signature even
# on a cold arena, so one run exercises the full multi-bucket path
_SPLIT_CM = CostModel(compile_cost=0.0)


def test_auto_mixed_k_multi_bucket_bitwise_vs_pad_and_group():
    """k_mode='auto' forced into its signature-split plan on a K-skewed
    interleaved grid: three buckets, lanes permuted in and out, every
    lane bitwise-equal (model trajectory, leaf-chunked path) to the
    padded and grouped executions and to its run_scan replay — the cost
    model decides speed, never results."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 3
    lr = np.full(T, 0.1, np.float32)
    auto = Arena(eng, k_mode="auto", cost_model=_SPLIT_CM)
    h_all = auto.sample_channels(grid, T, N)
    rep = auto.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep.meta["k_mode"] == "auto"
    assert rep.meta["dispatches"] == 3          # one bucket per distinct K
    assert rep.meta["executables_built"] == 3
    assert [b["k_pad"] for b in rep.meta["plan"]] == [2, 3, 4]
    # grid-interleaved K: buckets are non-contiguous lane sets
    assert rep.meta["plan"][0]["lanes"] == [0, 2]
    # the grouped execution of the SAME grid is bitwise identical in
    # every output (the buckets ARE the per-K groups here)
    grouped = Arena(eng, k_mode="group")
    rep_g = grouped.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(rep_g.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in rep.metrics:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_g.metrics[name])
    np.testing.assert_array_equal(rep.queues, rep_g.queues)
    # ...and the padded execution matches on the model trajectory
    pad = Arena(eng)
    rep_p = pad.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(rep_p.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in BITWISE_METRICS:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_p.metrics[name])
    # ...and the individual fixed-policy run_scan replays, lane by lane
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s)


def test_auto_cold_run_collapses_to_the_padded_plan():
    """With real compile prices and nothing cached, a one-shot auto run
    plans exactly the padded single bucket — the cold-workflow
    degenerate case, same executable cache key as k_mode='pad'."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 3
    lr = np.full(T, 0.1, np.float32)
    auto = Arena(eng, k_mode="auto")          # tracked cost calibration
    h_all = auto.sample_channels(grid, T, N)
    rep = auto.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep.meta["dispatches"] == 1
    assert rep.meta["executables_built"] == 1
    assert rep.meta["plan"][0]["k_pad"] == 4
    pad = Arena(eng)
    rep_p = pad.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert set(auto._fns) == set(pad._fns)    # the SAME executable key
    for name in rep.metrics:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_p.metrics[name])


def test_auto_max_executables_one_is_the_pad_degenerate_case():
    """A forced max_executables=1 plan is the padded plan whatever the
    prices say — results and executable cache key identical to
    k_mode='pad'."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 3
    lr = np.full(T, 0.1, np.float32)
    auto = Arena(eng, k_mode="auto", cost_model=_SPLIT_CM,
                 max_executables=1)
    h_all = auto.sample_channels(grid, T, N)
    rep = auto.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep.meta["dispatches"] == 1
    assert len(auto._fns) == 1
    pad = Arena(eng)
    rep_p = pad.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert set(auto._fns) == set(pad._fns)
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(rep_p.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in rep.metrics:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_p.metrics[name])
    with pytest.raises(ValueError, match="max_executables"):
        Arena(eng, k_mode="auto", max_executables=0)


def test_auto_lane_permutation_round_trip_with_eval_columns():
    """Eval rides the buckets: in-scan test_* columns and the final
    batched evaluation re-stitch to grid order through the lane
    permutation — accuracy_curve() and the report reducers read exactly
    like the padded run's."""
    task, eng, bank, sp, params0 = _setup()
    xte, yte = _test_set()
    eb = EvalBank(task, xte, yte)
    grid = _mixed_k_grid()
    T = 4
    lr = np.full(T, 0.1, np.float32)
    auto = Arena(eng, k_mode="auto", cost_model=_SPLIT_CM)
    h_all = auto.sample_channels(grid, T, N)
    rep = auto.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                   eval_bank=eb, eval_every=2)
    assert rep.meta["dispatches"] == 3
    pad = Arena(eng)
    rep_p = pad.run(params0, sp, bank, grid, T, lr, h_all=h_all,
                    eval_bank=eb, eval_every=2)
    # grid order round-trips: the model-trajectory columns are bitwise,
    # the eval columns (different vmap widths) f32-tight
    for name in BITWISE_METRICS:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep_p.metrics[name])
    np.testing.assert_allclose(rep.accuracy_curve(),
                               rep_p.accuracy_curve(), **TOL)
    np.testing.assert_allclose(rep.final_accuracy(),
                               rep_p.final_accuracy(), **TOL)
    # reducers see grid coordinates in grid order
    rows = rep.summary()
    assert [r["sample_count"] for r in rows] == \
        grid.sample_count.tolist()
    assert [r["controller"] for r in rows] == grid.controller_names()


def test_auto_tiered_bank_static_tier_subsets_match_pad_lanes():
    """Multi-tier bank + K-skewed grid: the control-plane probe's
    footprints bound each bucket to the tiers its lanes actually draw,
    at least one bucket compiles a REDUCED ladder (the recovered
    scan-skip), and every lane still matches the padded full-ladder
    execution and its run_scan replay to f32 resolution."""
    sizes = [64, 10, 33, 64, 100, 17]
    task, eng, bank, sp, params0 = _setup(sizes, bank_mode="tiered")
    assert bank.num_tiers > 1
    grid = ScenarioGrid.create(
        controllers=["lroa", "uni_d", "uni_s", "lroa", "uni_d", "lroa"],
        seeds=[3, 4, 5, 6, 7, 8], V=200.0, lam=1.0,
        sample_count=[2, 4, 2, 4, 3, 3])
    T = 3
    lr = np.full(T, 0.1, np.float32)
    auto = Arena(eng, k_mode="auto", cost_model=_SPLIT_CM,
                 max_executables=6)
    h_all = auto.sample_channels(grid, T, len(sizes))
    rep = auto.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep.meta["dispatches"] > 1
    # every bucket's static tier subset covers exactly the union of its
    # lanes' REALIZED tier draws (probe == execution selections)
    tier_of = np.asarray(bank.tier_of)
    for b in rep.meta["plan"]:
        realized = set()
        for s in b["lanes"]:
            sel = rep.metrics["selected"][s]
            realized |= set(tier_of[sel[sel >= 0]].tolist())
        assert sorted(realized) == b["tiers"]
    # the scan-skip is actually exercised: some bucket dropped a tier
    assert any(len(b["tiers"]) < bank.num_tiers
               for b in rep.meta["plan"])
    # lanes match the padded full-ladder run (dropped tiers contribute
    # exact zeros) and the individual tiered run_scan replays
    pad = Arena(eng)
    rep_p = pad.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    np.testing.assert_array_equal(rep.metrics["selected"],
                                  rep_p.metrics["selected"])
    for a, b in zip(jax.tree_util.tree_leaves(rep.params),
                    jax.tree_util.tree_leaves(rep_p.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)
    for s in range(len(grid)):
        _assert_lane_matches(rep, eng, bank, sp, params0, grid, h_all, lr,
                             s, model_bitwise=False)


def test_auto_warmup_warms_every_steady_bucket():
    """Arena.warmup under 'auto' plans at the steady-state horizon,
    warms EVERY bucket of that plan (AOT-lowered where supported, one
    discarded execution otherwise), and subsequent runs re-pick the
    cached buckets through the cache-aware cost model: zero new
    compiles, zero new traces."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 3
    lr = np.full(T, 0.1, np.float32)
    auto = Arena(eng, k_mode="auto")
    h_all = auto.sample_channels(grid, T, N)
    stats = auto.warmup(params0, sp, bank, grid, T, h_all=h_all)
    assert stats["aot"] == aot_cache_warmup_supported()
    assert len(stats["plan"]) == 3        # steady split, not the cold pad
    assert stats["executables_built"] == 3
    assert len(auto._fns) == 3
    traces0 = auto.traces
    rep = auto.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep.meta["dispatches"] == 3    # snapped to the warmed buckets
    assert rep.meta["executables_built"] == 0
    assert auto.traces == traces0         # zero new traces after warmup
    acc = rep.dispatch_accounting()
    assert acc["dispatches"] == 3
    assert acc["lanes_covered"] == len(grid)
    # the executed fallback warms the same set
    auto2 = Arena(eng, k_mode="auto")
    stats2 = auto2.warmup(params0, sp, bank, grid, T, h_all=h_all,
                          aot=False)
    assert stats2["aot"] is False
    assert stats2["executables_built"] == 3
    rep2 = auto2.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    assert rep2.meta["executables_built"] == 0
    for name in rep.metrics:
        np.testing.assert_array_equal(rep.metrics[name],
                                      rep2.metrics[name])


def test_meta_bucket_accounting_is_additive_in_every_k_mode():
    """Satellite contract: meta['buckets'] counters are per-executable
    and additive — their sums reproduce meta['dispatches'] /
    meta['executables_built'] exactly in pad, group, and auto modes
    (dispatch_accounting raises otherwise)."""
    task, eng, bank, sp, params0 = _setup()
    grid = _mixed_k_grid()
    T = 3
    lr = np.full(T, 0.1, np.float32)
    expected = {"pad": 1, "group": 3, "auto": 3}
    for mode, want in expected.items():
        arena = Arena(eng, k_mode=mode, cost_model=_SPLIT_CM)
        h_all = arena.sample_channels(grid, T, N)
        rep = arena.run(params0, sp, bank, grid, T, lr, h_all=h_all)
        acc = rep.dispatch_accounting()
        assert acc["dispatches"] == rep.meta["dispatches"] == want
        assert acc["executables_built"] == rep.meta["executables_built"]
        assert acc["lanes_covered"] == len(grid)
        assert sum(b["dispatches"] for b in rep.meta["buckets"]) == \
            rep.meta["dispatches"]


# -- K validation -----------------------------------------------------------


def test_grid_validates_sample_count_against_n():
    with pytest.raises(ValueError, match="exceed num_devices"):
        ScenarioGrid.product(controllers=("lroa",), seeds=(0,), V=(1.0,),
                             lam=(1.0,), sample_count=(2, 99),
                             num_devices=N)
    with pytest.raises(ValueError, match="exceed num_devices"):
        ScenarioGrid.create(controllers=["lroa"], seeds=[0], V=1.0,
                            lam=1.0, sample_count=N + 1, num_devices=N)
    with pytest.raises(ValueError, match=">= 1"):
        ScenarioGrid.create(controllers=["lroa"], seeds=[0], V=1.0,
                            lam=1.0, sample_count=0)
    # without num_devices construction passes, but Arena.run still
    # rejects the oversized K before tracing anything
    grid = ScenarioGrid.create(controllers=["lroa"], seeds=[0], V=1.0,
                               lam=1.0, sample_count=N + 2)
    task, eng, bank, sp, params0 = _setup()
    with pytest.raises(ValueError, match="K <= N"):
        Arena(eng).run(params0, sp, bank, grid, 2,
                       np.full(2, 0.1, np.float32))
    with pytest.raises(ValueError, match="k_mode"):
        Arena(eng, k_mode="bogus")


# -- pure-jax hyper-parameter estimates ------------------------------------


def test_estimate_hyperparams_arrays_matches_host_and_jits():
    sp = paper_default_params(num_devices=N, sample_count=3,
                              data_sizes=np.full(N, 64, np.float32))
    hp = estimate_hyperparams(sp, 0.1, loss_scale=1.5, mu=2.0, nu=1e4)
    lam, v, lam0, v0 = jax.jit(estimate_hyperparams_arrays,
                               static_argnums=())(
        sp, jnp.float32(0.1), jnp.float32(1.5), jnp.float32(2.0),
        jnp.float32(1e4))
    assert float(lam) == pytest.approx(hp.lam, rel=1e-6)
    assert float(v) == pytest.approx(hp.V, rel=1e-6)
    assert float(lam0) == pytest.approx(hp.lam0, rel=1e-6)
    assert float(v0) == pytest.approx(hp.V0, rel=1e-6)
    # vmappable over per-scenario (mean_gain, mu, nu) — the arena's
    # setup-jit use case
    lam_b, v_b, _, _ = jax.jit(jax.vmap(
        lambda g, m, n: estimate_hyperparams_arrays(sp, g, 1.5, m, n)))(
        jnp.asarray([0.1, 0.2]), jnp.asarray([2.0, 1.0]),
        jnp.asarray([1e4, 1e5]))
    assert float(lam_b[0]) == pytest.approx(hp.lam, rel=1e-6)
    assert float(v_b[0]) == pytest.approx(hp.V, rel=1e-6)


def test_derive_hyperparams_fills_grid_per_scenario():
    sp = paper_default_params(num_devices=N, sample_count=4,
                              data_sizes=np.full(N, 64, np.float32))
    grid = ScenarioGrid.create(controllers=["lroa", "uni_d"],
                               seeds=[0, 1], V=0.0, lam=0.0,
                               mean_gain=[0.1, 0.2], sample_count=[4, 2])
    out = derive_hyperparams(sp, grid, mu=1.0, nu=1e5, loss_scale=1.5)
    hp0 = estimate_hyperparams(sp, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    assert out.lam[0] == pytest.approx(hp0.lam, rel=1e-6)
    assert out.V[0] == pytest.approx(hp0.V, rel=1e-6)
    # lane 1 uses its own K and channel mean
    import dataclasses as dc
    sp1 = dc.replace(sp, sample_count=2)
    hp1 = estimate_hyperparams(sp1, 0.2, loss_scale=1.5, mu=1.0, nu=1e5)
    assert out.lam[1] == pytest.approx(hp1.lam, rel=1e-6)
    assert out.V[1] == pytest.approx(hp1.V, rel=1e-6)


# -- channel pregeneration --------------------------------------------------


def test_sample_channels_per_scenario_statistics():
    task, eng, bank, sp, params0 = _setup()
    grid = ScenarioGrid.create(controllers=["lroa"] * 3, seeds=[0, 1, 2],
                               V=1.0, lam=1.0,
                               mean_gain=[0.05, 0.1, 0.3],
                               min_gain=[0.01, 0.01, 0.05],
                               max_gain=[0.2, 0.5, 0.9])
    arena = Arena(eng)
    h = np.asarray(arena.sample_channels(grid, 200, N))
    assert h.shape == (3, 200, N)
    for s in range(3):
        assert h[s].min() >= grid.min_gain[s]
        assert h[s].max() <= grid.max_gain[s]
    # larger mean_gain must shift the realised mean up
    assert h[0].mean() < h[1].mean() < h[2].mean()
    # deterministic in the grid seeds
    h2 = np.asarray(arena.sample_channels(grid, 200, N))
    np.testing.assert_array_equal(h, h2)


# -- 2-device CPU scenario sharding ----------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=2")
    import numpy as np, jax
    from repro.core import paper_default_params
    from repro.data import synthetic_image_classification
    from repro.fl import ClientConfig, RoundEngine
    from repro.launch.mesh import make_fl_mesh
    from repro.models import MLPTask
    from repro.sim import Arena, ScenarioGrid

    assert jax.device_count() == 2, jax.devices()
    N, BS, T, S = 6, 16, 3, 4
    sizes = [64] * N
    x, y = synthetic_image_classification(sum(sizes), (8, 8, 1), 4,
                                          noise=0.3, seed=3)
    offs = np.cumsum([0] + sizes)
    cd = [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
          for i in range(N)]
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    eng = RoundEngine(task, ClientConfig(local_epochs=2, batch_size=BS))
    bank = eng.make_bank(cd, tiered="single")
    sp = paper_default_params(num_devices=N, sample_count=4,
                              data_sizes=np.asarray(sizes, np.float32))
    params0 = task.init(jax.random.PRNGKey(0))
    grid = ScenarioGrid.create(
        controllers=["lroa", "uni_d", "uni_s", "lroa"], seeds=[0, 1, 2, 3],
        V=[100.0, 50.0, 0.0, 200.0], lam=0.5, sample_count=4)
    lr = np.full(T, 0.1, np.float32)
    plain = Arena(eng)
    h_all = plain.sample_channels(grid, T, N)
    sharded = Arena(eng, mesh=make_fl_mesh())
    rep_1 = plain.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    rep_2 = sharded.run(params0, sp, bank, grid, T, lr, h_all=h_all)
    for a, b in zip(jax.tree_util.tree_leaves(rep_1.params),
                    jax.tree_util.tree_leaves(rep_2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    for name in rep_1.metrics:
        np.testing.assert_allclose(rep_1.metrics[name],
                                   rep_2.metrics[name], rtol=1e-5,
                                   atol=1e-4)
    np.testing.assert_allclose(rep_1.queues, rep_2.queues, rtol=1e-5,
                               atol=1e-4)
    # indivisible scenario counts are a clear error, not silent padding
    bad = ScenarioGrid.create(controllers=["lroa"] * 3, seeds=[0, 1, 2],
                              V=1.0, lam=1.0, sample_count=4)
    try:
        sharded.run(params0, sp, bank, bad, T, lr)
        raise SystemExit("expected divisibility error")
    except ValueError as e:
        assert "divisible" in str(e)
    print("ARENA-SHARDED-OK")
""")


def test_scenario_sharded_arena_matches_unsharded(tmp_path):
    """Whole-rollout-per-shard over a 2-device CPU ('data',) mesh (forced
    host devices in a subprocess) must reproduce the unsharded arena —
    the scenario axis has no cross-shard communication."""
    script = tmp_path / "arena_shard_check.py"
    script.write_text(_SHARD_SCRIPT)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ARENA-SHARDED-OK" in out.stdout
