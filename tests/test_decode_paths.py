"""Decode-path correctness: ring-buffer window caches (across wraps and
prefill handoff), grouped MoE dispatch, M-RoPE position streams, and the
client-parallel FL round step."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.transformer import TransformerLM
from repro.models.vlm import mrope_decode_positions, mrope_positions


def _ring_cfg():
    return ModelConfig(name="ring", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61,
                       block_pattern=("local", "global"), window_size=8)


def test_ring_buffer_decode_matches_full_forward():
    cfg = _ring_cfg()
    m = TransformerLM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    s = 24                                      # wraps the 8-slot ring 3x
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, 61)
    full, _, _ = m.apply(params, toks)
    cache = m.init_cache(2, s)
    assert cache["b0"]["k"].shape[2] == 8       # ring-sized local cache
    assert cache["b1"]["k"].shape[2] == s       # full global cache
    step = jax.jit(m.decode_step)
    worst = 0.0
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t:t + 1],
                         jnp.asarray(t, jnp.int32))
        worst = max(worst, float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert worst < 5e-5, worst


def test_ring_buffer_prefill_handoff_past_wrap():
    cfg = _ring_cfg()
    m = TransformerLM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0, 61)
    full, _, _ = m.apply(params, toks)
    _, _, cache = m.apply(params, toks[:, :20], mode="prefill")
    ref = m.init_cache(2, 21)
    cache = jax.tree_util.tree_map(
        lambda cp, cf: jnp.pad(cp, [(0, cf.shape[i] - cp.shape[i])
                                    for i in range(cp.ndim)]), cache, ref)
    lg, _ = m.decode_step(params, cache, toks[:, 20:21],
                          jnp.asarray(20, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 20]),
                               atol=5e-5)


def test_ring_cache_ablation_restores_full_cache():
    cfg = dataclasses.replace(_ring_cfg(), local_ring_cache=False)
    m = TransformerLM(cfg)
    cache = m.init_cache(2, 24)
    assert cache["b0"]["k"].shape[2] == 24


def test_grouped_moe_matches_dense_with_ample_capacity():
    cfg = ModelConfig(name="m", family="moe", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=97,
                      num_experts=4, experts_per_token=2,
                      moe_capacity_factor=8.0, moe_groups=4)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y_sort, _ = moe_lib.apply_moe(params, x, cfg, "sort")
    y_dense, _ = moe_lib.apply_moe(params, x, cfg, "dense")
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_dense),
                               atol=2e-5)


def test_grouped_moe_group1_matches_capacity():
    cfg = ModelConfig(name="m", family="moe", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=97,
                      num_experts=4, experts_per_token=2,
                      moe_capacity_factor=1.0, moe_groups=1)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 16, 32))
    y_sort, _ = moe_lib.apply_moe(params, x, cfg, "sort")
    y_cap, _ = moe_lib.apply_moe(params, x, cfg, "capacity")
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_cap),
                               atol=2e-5)


def test_mrope_text_only_equals_vanilla_positions():
    pos = mrope_positions(2, 10, num_patches=0)
    assert pos.shape == (3, 2, 10)
    expected = np.broadcast_to(np.arange(10), (2, 10))
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(pos[i]), expected)


def test_mrope_vision_prefix_layout():
    pos = np.asarray(mrope_positions(1, 8, num_patches=4))  # 2x2 grid
    t, h, w = pos[:, 0, :]
    assert (t[:4] == 0).all()
    np.testing.assert_array_equal(h[:4], [0, 0, 1, 1])
    np.testing.assert_array_equal(w[:4], [0, 1, 0, 1])
    # text resumes with equal t == h == w
    assert (t[4:] == h[4:]).all() and (h[4:] == w[4:]).all()
    assert (np.diff(t[4:]) == 1).all()
    dec = np.asarray(mrope_decode_positions(1, jnp.asarray(9), 4))
    assert dec.shape == (3, 1, 1)
    assert (dec == dec[0]).all()


def test_fl_round_step_improves_loss():
    from repro.configs import get_smoke_config
    from repro.launch.steps import build_model, make_fl_round_step
    cfg = get_smoke_config("yi-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_fl_round_step(cfg, 2, lr=0.3, local_steps=3))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 4, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:],
             "coeffs": jnp.asarray([0.5, 0.5])}
    losses = []
    for _ in range(4):
        params, metrics = step(params, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
