"""Virtual-queue dynamics (eqs. 19-21) + client sampling / unbiased
aggregation (eq. 4, Appendix A)."""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_channel, make_params
from repro.core import (energy_increment, init_queues, lyapunov,
                        update_queues)
from repro.fl import server as fl_server


def test_queue_never_negative():
    q = init_queues(4)
    q = update_queues(q, jnp.asarray([-5.0, 3.0, -0.1, 0.0]))
    assert bool(jnp.all(q >= 0))
    np.testing.assert_allclose(np.asarray(q), [0.0, 3.0, 0.0, 0.0])


def test_queue_accumulates_violation():
    params = make_params(4)
    h = make_channel(4)
    q = jnp.full((4,), 0.25)
    inc = energy_increment(params, h, params.p_max, params.f_max, q)
    queues = update_queues(init_queues(4), inc)
    queues2 = update_queues(queues, inc)
    # p_max/f_max at 25% selection on this config violates the budget
    assert bool(jnp.all(queues2 >= queues))


def test_lyapunov():
    assert float(lyapunov(jnp.asarray([3.0, 4.0]))) == 12.5


def test_sampling_with_replacement_distribution():
    rng = np.random.default_rng(0)
    q = np.asarray([0.5, 0.25, 0.125, 0.125])
    counts = np.zeros(4)
    trials = 4000
    for _ in range(trials):
        sel = fl_server.sample_clients(rng, q, 2)
        assert sel.shape == (2,)
        for s in sel:
            counts[s] += 1
    freq = counts / (2 * trials)
    np.testing.assert_allclose(freq, q, atol=0.03)


def test_aggregation_unbiased():
    """E[theta_agg] == full-participation weighted aggregate (Appendix A)."""
    rng = np.random.default_rng(1)
    n, k, d = 6, 2, 5
    w = rng.dirichlet(np.ones(n))
    q = rng.dirichlet(np.ones(n) * 2)
    deltas = rng.normal(0, 1, (n, d)).astype(np.float32)
    theta = np.zeros(d, np.float32)

    acc = np.zeros(d)
    trials = 20000
    for _ in range(trials):
        sel = fl_server.sample_clients(rng, q, k)
        coeffs = fl_server.aggregation_weights(sel, q, w, k)
        out = theta + (coeffs[:, None] * deltas[sel]).sum(0)
        acc += out
    expected = (w[:, None] * deltas).sum(0)
    np.testing.assert_allclose(acc / trials, expected, atol=0.05)


def test_aggregate_matches_stacked():
    rng = np.random.default_rng(2)
    tree = {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    deltas = [
        {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
        for _ in range(3)]
    coeffs = np.asarray([0.5, 0.25, 0.75], np.float32)
    out1 = fl_server.aggregate(tree, deltas, coeffs)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *deltas)
    out2 = fl_server.aggregate_stacked(tree, stacked, jnp.asarray(coeffs))
    for k in tree:
        np.testing.assert_allclose(np.asarray(out1[k]), np.asarray(out2[k]),
                                   rtol=1e-5)


def test_aggregate_kernel_path_matches():
    from repro.kernels import fl_aggregate_pytree
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    stacked = {"w": jnp.asarray(rng.normal(size=(3, 8, 4)), jnp.float32)}
    coeffs = jnp.asarray([0.2, 0.3, 0.5], jnp.float32)
    out_k = fl_aggregate_pytree(tree, stacked, coeffs, impl="pallas")
    out_r = fl_server.aggregate_stacked(tree, stacked, coeffs)
    np.testing.assert_allclose(np.asarray(out_k["w"]),
                               np.asarray(out_r["w"]), rtol=1e-4, atol=1e-6)
