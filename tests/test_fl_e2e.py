"""End-to-end FL behaviour: the full Algorithm 1 loop on a small synthetic
non-IID problem — model learns, LROA beats the static baseline on latency,
queues remain stable (energy constraint)."""

import numpy as np
import pytest

from repro.core import (LROAController, UniformDynamicController,
                        UniformStaticController, estimate_hyperparams,
                        paper_default_params)
from repro.data import (dirichlet_partition, make_client_datasets,
                        synthetic_image_classification, train_test_split)
from repro.fl import (ChannelConfig, ChannelProcess, ClientConfig,
                      FederatedTrainer)
from repro.models import MLPTask
from repro.optim import constant


N_DEVICES = 10
ROUNDS = 12


@pytest.fixture(scope="module")
def fl_setup():
    x, y = synthetic_image_classification(1500, (8, 8, 1), num_classes=4,
                                          noise=0.3, seed=0)
    (xtr, ytr), (xte, yte) = train_test_split(x, y, 0.2, seed=1)
    parts = dirichlet_partition(ytr, N_DEVICES, 0.5, seed=2)
    client_data = make_client_datasets(xtr, ytr, parts)
    sizes = np.asarray([len(p) for p in parts], np.float32)
    params = paper_default_params(num_devices=N_DEVICES, data_sizes=sizes)
    task = MLPTask(input_dim=64, num_classes=4, hidden=32)
    return params, task, client_data, (xte, yte)


def _run(controller_cls, fl_setup, seed=0, **ctrl_kw):
    params, task, client_data, test = fl_setup
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    controller = controller_cls(params, hp, **ctrl_kw)
    trainer = FederatedTrainer(
        task, params, controller,
        ChannelProcess(N_DEVICES, ChannelConfig(seed=seed)),
        client_data, ClientConfig(local_epochs=2, batch_size=16),
        constant(0.1), test_data=test, eval_every=6, seed=seed)
    return trainer.run(ROUNDS)


def test_fl_learns(fl_setup):
    res = _run(LROAController, fl_setup)
    accs = [a for _, _, a in res.accuracy_curve()]
    assert accs[-1] > 0.45, f"final accuracy {accs[-1]}"
    assert accs[-1] > accs[0]


def test_lroa_latency_beats_static(fl_setup):
    res_lroa = _run(LROAController, fl_setup)
    res_unis = _run(UniformStaticController, fl_setup)
    # LROA optimises f/p per round; Uni-S fixes p mid and f by energy balance
    assert res_lroa.total_time < res_unis.total_time * 1.05, (
        res_lroa.total_time, res_unis.total_time)


def test_queue_growth_sublinear(fl_setup):
    params, task, client_data, test = fl_setup
    res = _run(LROAController, fl_setup)
    q_means = [r.queue_mean for r in res.records]
    # queue mean must not explode: growth rate decays
    first_half = q_means[len(q_means) // 2] - q_means[0]
    second_half = q_means[-1] - q_means[len(q_means) // 2]
    assert second_half <= first_half * 2.0 + 1e3


def test_round_records_complete(fl_setup):
    res = _run(UniformDynamicController, fl_setup)
    assert len(res.records) == ROUNDS
    for r in res.records:
        assert r.wall_time > 0
        assert len(r.selected) == 2            # K = 2
        assert 0 < r.q_min <= r.q_max <= 1
