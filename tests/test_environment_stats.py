"""Statistical pins on the channel environment: Gilbert-Elliott occupancy
vs the stationary distribution, per-state truncated-exponential gain
means, dropout mask frequency, host-vs-jax markov agreement — plus the
stream-separation regression: adding the markov/dropout axes leaves the
stationary gains stream bitwise untouched."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.environment import (ChannelConfig, ChannelProcess,
                                  markov_stationary, sample_channel_sequence,
                                  sample_dropout_mask, sample_gains,
                                  sample_gains_markov, sample_markov_states)

P_GB, P_BG = 0.2, 0.5


def test_channel_config_validation():
    with pytest.raises(ValueError, match="unknown channel mode"):
        ChannelConfig(mode="rayleigh")
    with pytest.raises(ValueError, match="transition probabilities"):
        ChannelConfig(mode="markov", p_gb=1.5, p_bg=0.5)
    with pytest.raises(ValueError, match="dropout rate"):
        ChannelConfig(dropout=1.0)


def _truncated_exp_mean(m, lo, hi):
    """E[X | lo <= X <= hi] for X ~ Exp(mean m) — the closed form the
    redraw scheme's stationary distribution must match."""
    a, b = np.exp(-lo / m), np.exp(-hi / m)
    return m + (lo * a - hi * b) / (a - b)


def test_markov_occupancy_matches_stationary_distribution():
    """Time-average bad-state occupancy of the sampled chain converges
    to pi_bad = p_gb / (p_gb + p_bg)."""
    T, N = 4000, 24
    states = np.asarray(sample_markov_states(jax.random.PRNGKey(0), T, N,
                                             P_GB, P_BG))
    assert states.shape == (T, N)
    assert set(np.unique(states)) <= {0, 1}
    pi_bad = float(markov_stationary(P_GB, P_BG))
    assert abs(pi_bad - P_GB / (P_GB + P_BG)) < 1e-7
    # chain autocorrelation (1 - p_gb - p_bg = 0.3) leaves ~T*N/2
    # effective samples; 3-sigma is well under 0.01
    assert abs(states.mean() - pi_bad) < 0.01
    # the degenerate chain never leaves all-good
    degen = np.asarray(sample_markov_states(jax.random.PRNGKey(1), 100, 8,
                                            0.0, 0.0))
    assert np.all(degen == 0)


def test_markov_initial_state_draws_from_stationary():
    """The chain starts in steady state: round-0 occupancy across many
    clients already matches pi_bad (no burn-in transient)."""
    states = np.asarray(sample_markov_states(jax.random.PRNGKey(2), 1,
                                             20000, P_GB, P_BG))
    pi_bad = float(markov_stationary(P_GB, P_BG))
    assert abs(states[0].mean() - pi_bad) < 0.01


def test_markov_gains_per_state_means_and_clip():
    """Partitioning the Gilbert-Elliott gains by the (reconstructed)
    state chain, each state's empirical mean matches the truncated-
    exponential closed form for its own mean parameter, and every draw
    respects the clip range."""
    key = jax.random.PRNGKey(3)
    T, N = 2000, 24
    cfg = dict(mean_gain=0.1, bad_gain=0.02, min_gain=0.01, max_gain=0.5)
    h = np.asarray(sample_gains_markov(key, T, N, cfg["mean_gain"],
                                       cfg["bad_gain"], cfg["min_gain"],
                                       cfg["max_gain"], P_GB, P_BG))
    assert np.all((h >= cfg["min_gain"]) & (h <= cfg["max_gain"]))
    # the same stream split sample_gains_markov consumes internally
    k_states, _ = jax.random.split(jax.random.fold_in(key, 1))
    states = np.asarray(sample_markov_states(k_states, T, N, P_GB, P_BG))
    good, bad = h[states == 0], h[states == 1]
    assert good.size > 10000 and bad.size > 5000
    want_good = _truncated_exp_mean(cfg["mean_gain"], cfg["min_gain"],
                                    cfg["max_gain"])
    want_bad = _truncated_exp_mean(cfg["bad_gain"], cfg["min_gain"],
                                   cfg["max_gain"])
    np.testing.assert_allclose(good.mean(), want_good, rtol=0.03)
    np.testing.assert_allclose(bad.mean(), want_bad, rtol=0.03)
    # the two regimes are actually distinct
    assert good.mean() > 2.0 * bad.mean()


def test_iid_gains_match_truncated_exponential_mean():
    h = np.asarray(sample_gains(jax.random.PRNGKey(4), 2000, 24,
                                0.1, 0.01, 0.5))
    np.testing.assert_allclose(h.mean(),
                               _truncated_exp_mean(0.1, 0.01, 0.5),
                               rtol=0.02)
    assert np.all((h >= 0.01) & (h <= 0.5))


def test_dropout_mask_frequency_matches_rate():
    for rate in (0.0, 0.1, 0.45):
        mask = np.asarray(sample_dropout_mask(jax.random.PRNGKey(5),
                                              2000, 24, rate))
        assert set(np.unique(mask)) <= {0.0, 1.0}
        assert abs((1.0 - mask.mean()) - rate) < 0.01, rate
    assert np.all(np.asarray(sample_dropout_mask(
        jax.random.PRNGKey(6), 50, 8, 0.0)) == 1.0)


def test_host_markov_mirror_agrees_statistically_with_jax():
    """The numpy ChannelProcess markov mirror and the jax sampler are
    independent streams of the SAME process: occupancy, mean, and spread
    agree within sampling tolerance."""
    T, N = 2000, 24
    cfg = ChannelConfig(mode="markov", p_gb=P_GB, p_bg=P_BG,
                        mean_gain=0.1, bad_gain=0.02, seed=11)
    proc = ChannelProcess(N, cfg)
    host_states = proc.markov_state_sequence(T)
    pi_bad = float(markov_stationary(P_GB, P_BG))
    assert abs(host_states.mean() - pi_bad) < 0.015
    host = ChannelProcess(N, cfg).sample_sequence(T)
    dev = np.asarray(ChannelProcess(N, cfg).sample_jax(
        jax.random.PRNGKey(7), T))
    assert host.shape == dev.shape == (T, N)
    np.testing.assert_allclose(host.mean(), dev.mean(), rtol=0.03)
    np.testing.assert_allclose(host.std(), dev.std(), rtol=0.05)
    # single-round host sample advances the same persistent chain
    one = ChannelProcess(N, cfg)
    seq = np.stack([one.sample() for _ in range(50)])
    assert seq.shape == (50, N)
    assert np.all((seq >= cfg.min_gain) & (seq <= cfg.max_gain))


def test_iid_process_paths_agree_with_pure_samplers():
    """On an iid config the host mirror is statistically the truncated
    exponential and ``sample_jax`` dispatches bitwise to the plain
    ``sample_gains`` stream; ``stream()`` yields the persistent chain."""
    cfg = ChannelConfig(mean_gain=0.1, seed=17)
    proc = ChannelProcess(16, cfg)
    host = proc.sample_sequence(2000)
    np.testing.assert_allclose(host.mean(),
                               _truncated_exp_mean(0.1, cfg.min_gain,
                                                   cfg.max_gain),
                               rtol=0.02)
    key = jax.random.PRNGKey(12)
    np.testing.assert_array_equal(
        np.asarray(proc.sample_jax(key, 7)),
        np.asarray(sample_gains(key, 7, 16, cfg.mean_gain, cfg.min_gain,
                                cfg.max_gain)))
    got = np.stack(list(itertools.islice(ChannelProcess(16, cfg).stream(),
                                         3)))
    fresh = ChannelProcess(16, cfg)
    want = np.stack([fresh.sample() for _ in range(3)])
    np.testing.assert_array_equal(got, want)


def test_host_dropout_mirror_matches_rate():
    cfg = ChannelConfig(dropout=0.3, seed=13)
    proc = ChannelProcess(16, cfg)
    mask = proc.dropout_sequence(2000)
    assert abs((1.0 - mask.mean()) - 0.3) < 0.015
    dev = np.asarray(proc.dropout_jax(jax.random.PRNGKey(8), 2000))
    assert abs(dev.mean() - mask.mean()) < 0.02


# -- stream separation: the satellite regression ---------------------------


def test_iid_lane_of_mode_dispatch_is_bitwise_raw_sample_gains():
    """``sample_channel_sequence`` with mode='iid' is bitwise the plain
    ``sample_gains`` stream — the markov branch computes on fold_in
    streams and the final ``where`` select is exact, so adding the
    non-stationary machinery cannot move any stationary trajectory."""
    key = jax.random.PRNGKey(9)
    T, N = 64, 12
    raw = np.asarray(sample_gains(key, T, N, 0.1, 0.01, 0.5))
    via = np.asarray(sample_channel_sequence(key, T, N, 0, 0.1, 0.02,
                                             0.01, 0.5, P_GB, P_BG))
    np.testing.assert_array_equal(via, raw)
    # the Gilbert-Elliott shape parameters are inert on an iid lane
    via2 = np.asarray(sample_channel_sequence(key, T, N, 0, 0.1, 0.004,
                                              0.01, 0.5, 0.9, 0.05))
    np.testing.assert_array_equal(via2, raw)
    # while a markov lane with the same key actually moves
    mk = np.asarray(sample_channel_sequence(key, T, N, 1, 0.1, 0.02,
                                            0.01, 0.5, P_GB, P_BG))
    assert not np.array_equal(mk, raw)


def test_gains_and_dropout_consume_disjoint_streams():
    """Gains read the RAW rollout key; markov reads fold_in(key, 1);
    dropout reads fold_in(key, 2).  Distinct fold_in streams mean the
    dropout axis cannot perturb gains (and vice versa) — checked by
    direct stream identity, not just statistics."""
    key = jax.random.PRNGKey(10)
    T, N = 32, 8
    raw = np.asarray(sample_gains(key, T, N, 0.1, 0.01, 0.5))
    mask = np.asarray(sample_dropout_mask(key, T, N, 0.25))
    # dropout's uniform block comes from fold_in(key, 2), nothing else
    u = np.asarray(jax.random.uniform(jax.random.fold_in(key, 2), (T, N)))
    np.testing.assert_array_equal(mask, (u >= 0.25).astype(np.float32))
    # markov's chain comes from fold_in(key, 1) — so neither stream
    # overlaps the raw-key exponential block the gains consume
    raw_again = np.asarray(sample_gains(key, T, N, 0.1, 0.01, 0.5))
    np.testing.assert_array_equal(raw, raw_again)


def test_arena_channel_tensor_default_grid_is_raw_sample_gains():
    """Arena.sample_channels on a default (stationary, no-dropout) grid
    is bitwise the vmapped raw ``sample_gains`` over the scenario chan
    keys — the grid-level form of the stream-separation regression."""
    from repro.sim import Arena, ScenarioGrid, scenario_keys
    from repro.fl import ClientConfig, RoundEngine
    from repro.models import MLPTask

    eng = RoundEngine(MLPTask(input_dim=8, num_classes=2, hidden=4),
                      ClientConfig(local_epochs=1, batch_size=4))
    arena = Arena(eng)
    grid = ScenarioGrid.create(controllers=["lroa", "uni_d", "divfl"],
                               seeds=[0, 1, 2], V=10.0, lam=0.5,
                               sample_count=2)
    T, N = 6, 5
    h_all = np.asarray(arena.sample_channels(grid, T, N))
    chan_keys, _ = scenario_keys(grid)
    for s in range(len(grid)):
        want = np.asarray(sample_gains(chan_keys[s], T, N,
                                       float(grid.mean_gain[s]),
                                       float(grid.min_gain[s]),
                                       float(grid.max_gain[s])))
        np.testing.assert_array_equal(h_all[s], want)
    # adding a dropout column leaves the channel tensor untouched
    gd = ScenarioGrid.create(controllers=["lroa", "uni_d", "divfl"],
                             seeds=[0, 1, 2], V=10.0, lam=0.5,
                             sample_count=2, dropout=0.35)
    h_drop = np.asarray(Arena(eng).sample_channels(gd, T, N))
    np.testing.assert_array_equal(h_drop, h_all)
