"""Million-client data plane (PR 10): int8 quantized bank storage, the
slot-recycled streaming ``BankPool``, and hierarchical cluster
aggregation.

Contracts pinned here:

* int8 storage is a TOLERANCE contract (per-element dequant error is
  bounded by half a quantization step; a round's params stay close to
  fp32) while fp32 storage stays BITWISE (storage='fp32' feeds the
  engine the exact arrays the unquantized path always had);
* the pool's admit/evict churn is zero-retrace after warmup — ONE
  scatter executable forever — and an evict + re-admit round-trips the
  device rows exactly;
* hierarchical eq.-(4) (cluster partials, then global) matches the flat
  reduce at f32 resolution with bitwise-equal losses;
* ``validate_client_data`` names the offending client;
* ``nbytes`` accounting matches ``estimate_bank_nbytes`` exactly and
  int8 beats fp32 by ~4x on the feature plane.
"""

import jax
import numpy as np
import pytest

from repro.data import synthetic_image_classification
from repro.data.pipeline import (assign_clusters, client_cluster_features,
                                 dequantize_stack, kmeans_clusters,
                                 quantize_stack, validate_client_data)
from repro.fl import (BankPool, ClientBank, ClientConfig, RoundEngine,
                      aggregate_fused, aggregate_hierarchical,
                      estimate_bank_nbytes)
from repro.models import MLPTask

N, M, BS, K = 10, 48, 8, 4
SHAPE = (4, 4, 1)


def _client_data(n=N, m=M, seed=0):
    x, y = synthetic_image_classification(n * m, SHAPE, num_classes=2,
                                          noise=0.3, seed=seed)
    return [(x[i * m:(i + 1) * m], y[i * m:(i + 1) * m]) for i in range(n)]


def _engine():
    task = MLPTask(input_dim=int(np.prod(SHAPE)), num_classes=2, hidden=16)
    return task, RoundEngine(task, ClientConfig(local_epochs=1,
                                                batch_size=BS))


def _one_round(eng, task, bank, hierarchical=False, k=K, seed=0):
    params = task.init(jax.random.PRNGKey(seed))
    sel = np.arange(k, dtype=np.int32)
    coeffs = np.full(k, 1.0 / k, np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(seed), k)
    return eng.round_step(params, bank, sel, coeffs, 0.1, rngs,
                          hierarchical=hierarchical)


def _max_leaf_dev(a, b):
    return max(float(np.abs(np.asarray(p) - np.asarray(q)).max())
               for p, q in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# -- quantization -----------------------------------------------------------

def test_quantize_dequantize_half_step_error_bound():
    """Affine int8: per-element |x_hat - x| <= 0.5 * scale_i (half a
    quantization step), the whole storage contract."""
    rng = np.random.default_rng(0)
    stack = rng.normal(size=(6, 16, 4)).astype(np.float32) * \
        rng.uniform(0.1, 10.0, size=(6, 1, 1)).astype(np.float32)
    q, scale, zero = quantize_stack(stack)
    assert q.dtype == np.int8 and scale.shape == (6,) and zero.shape == (6,)
    err = np.abs(dequantize_stack(q, scale, zero) - stack)
    assert (err <= 0.5 * scale[:, None, None] + 1e-7).all()


def test_quantize_constant_row_is_exact():
    """A zero-range client (scale would be 0) must not divide by zero and
    must reconstruct exactly."""
    stack = np.full((2, 8, 3), 2.5, np.float32)
    q, scale, zero = quantize_stack(stack)
    np.testing.assert_array_equal(dequantize_stack(q, scale, zero), stack)


def test_int8_round_matches_fp32_within_tolerance():
    """One fused round over an int8 bank tracks the fp32 round closely —
    the dequant lives inside the gather, so any plumbing error (wrong
    scale row, transposed zero) blows far past this bound."""
    cd = _client_data()
    task, eng = _engine()
    bank_f = eng.make_bank(cd, tiered="single")
    bank_q = eng.make_bank(cd, tiered="single", storage="int8")
    assert bank_q.storage == "int8" and bank_q.xs.dtype == np.int8
    p_f, l_f = _one_round(eng, task, bank_f)
    p_q, l_q = _one_round(eng, task, bank_q)
    assert _max_leaf_dev(p_f, p_q) < 5e-3
    np.testing.assert_allclose(np.asarray(l_f), np.asarray(l_q), atol=0.05)


def test_fp32_path_bitwise_unaffected_by_int8_sibling():
    """The quant flag is part of the executable cache key: compiling and
    running the int8 variant must leave the fp32 round bitwise
    identical."""
    cd = _client_data()
    task, eng = _engine()
    bank_f = eng.make_bank(cd, tiered="single")
    p_before, l_before = _one_round(eng, task, bank_f)
    bank_q = eng.make_bank(cd, tiered="single", storage="int8")
    _one_round(eng, task, bank_q)                  # compiles the quant step
    p_after, l_after = _one_round(eng, task, bank_f)
    assert len(eng._step_fns) == 2                 # distinct executables
    for a, b in zip(jax.tree_util.tree_leaves(p_before),
                    jax.tree_util.tree_leaves(p_after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(l_before),
                                  np.asarray(l_after))


def test_gather_host_returns_unquantized_reference():
    """``gather_host`` is the fp32 reference plane even on an int8 bank —
    equivalence tests diff device rounds against TRUE data."""
    cd = _client_data()
    _, eng = _engine()
    bank_q = eng.make_bank(cd, tiered="single", storage="int8")
    xs, ys, ns, ne = bank_q.gather_host(np.array([0, 3]))
    assert xs.dtype == np.float32
    np.testing.assert_array_equal(
        np.asarray(xs[0, :M]).reshape(M, *SHAPE), cd[0][0])


# -- hierarchical aggregation ----------------------------------------------

def test_aggregate_hierarchical_matches_flat():
    """Cluster-partial-then-global is the same sum reassociated: equal to
    the flat fused reduce at f32 resolution for any cluster routing."""
    rng = np.random.default_rng(1)
    gp = {"w": rng.normal(size=(6, 3)).astype(np.float32),
          "b": rng.normal(size=(3,)).astype(np.float32)}
    deltas = {k: rng.normal(size=(K,) + v.shape).astype(np.float32)
              for k, v in gp.items()}
    coeffs = rng.uniform(0.1, 1.0, K).astype(np.float32)
    flat = aggregate_fused(gp, deltas, coeffs)
    for csel in ([0, 0, 0, 0], [0, 1, 2, 3], [2, 0, 2, 1]):
        hier = aggregate_hierarchical(gp, deltas, coeffs,
                                      np.asarray(csel, np.int32), 4)
        assert _max_leaf_dev(flat, hier) < 1e-5


def test_hierarchical_round_matches_flat_round():
    """``round_step(hierarchical=True)`` over a clustered bank: params at
    f32 resolution of the flat round, losses bitwise equal (the local
    training is identical; only the reduce is reassociated)."""
    cd = _client_data()
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="single", clusters=3)
    assert bank.num_clusters == 3
    assert bank.cluster_of.shape == (N,)
    p_flat, l_flat = _one_round(eng, task, bank, hierarchical=False)
    p_hier, l_hier = _one_round(eng, task, bank, hierarchical=True)
    np.testing.assert_array_equal(np.asarray(l_flat), np.asarray(l_hier))
    assert _max_leaf_dev(p_flat, p_hier) < 1e-5


def test_hierarchical_requires_clusters():
    cd = _client_data()
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="single")
    with pytest.raises(ValueError, match="cluster"):
        _one_round(eng, task, bank, hierarchical=True)


def test_make_bank_rejects_tiered_clusters():
    sizes = [8, 8, 48, 48, 200, 200]
    cd = [(x[:s], y[:s]) for s, (x, y) in
          zip(sizes, [_client_data(1, 200, seed=i)[0] for i in range(6)])]
    _, eng = _engine()
    with pytest.raises(ValueError, match="single-bucket"):
        eng.make_bank(cd, tiered="tiered", clusters=2)


def test_kmeans_is_deterministic_and_total():
    cd = _client_data()
    feats = client_cluster_features(cd)
    assert feats.shape[0] == N
    la, ca = kmeans_clusters(feats, 3)
    lb, cb = kmeans_clusters(feats, 3)
    np.testing.assert_array_equal(la, lb)
    np.testing.assert_array_equal(ca, cb)
    assert set(np.unique(la)) <= set(range(3))
    np.testing.assert_array_equal(assign_clusters(feats, ca), la)


# -- validation -------------------------------------------------------------

def test_validation_names_offending_client():
    good = _client_data(3, 16)
    bad_dtype = good[:2] + [(good[2][0].astype(np.int32), good[2][1])]
    with pytest.raises(ValueError, match="client 2.*float"):
        validate_client_data(bad_dtype)
    bad_count = good[:1] + [(good[1][0], good[1][1][:-3])]
    with pytest.raises(ValueError, match="client 1"):
        validate_client_data(bad_count)
    with pytest.raises(ValueError, match="client 1.*match"):
        validate_client_data(
            [good[0], (good[1][0].astype(np.float64), good[1][1])])
    with pytest.raises(ValueError, match="empty"):
        validate_client_data([])
    _, eng = _engine()
    with pytest.raises(ValueError, match="client 2"):
        eng.make_bank(bad_dtype)


def test_bank_rejects_bad_storage():
    cd = _client_data(2, 16)
    _, eng = _engine()
    with pytest.raises(ValueError, match="storage"):
        eng.make_bank(cd, tiered="single", storage="int4")


# -- nbytes accounting ------------------------------------------------------

def test_nbytes_matches_estimate_and_int8_shrinks():
    cd = _client_data()
    cfg = ClientConfig(local_epochs=1, batch_size=BS)
    sizes = [M] * N
    for storage in ("fp32", "int8"):
        bank = ClientBank(cd, cfg, storage=storage)
        est = estimate_bank_nbytes(sizes, BS, SHAPE, storage=storage)
        assert bank.nbytes == est
        assert bank.bytes_per_client == pytest.approx(est / N)
    f32 = estimate_bank_nbytes(sizes, BS, SHAPE)
    i8 = estimate_bank_nbytes(sizes, BS, SHAPE, storage="int8")
    assert f32 / i8 > 3            # ~4x on features; labels/codes dilute


# -- BankPool ---------------------------------------------------------------

def _pool(capacity=6, storage="int8", clusters=None, n_init=4):
    cd = _client_data(n_init + 4, M, seed=2)
    cfg = ClientConfig(local_epochs=1, batch_size=BS)
    init = {i: cd[i] for i in range(n_init)}
    return BankPool(cfg, capacity=capacity, max_examples=M, storage=storage,
                    clusters=clusters, initial_clients=init), cd


def test_pool_admit_evict_roundtrip_exact():
    """Evict + re-admit reproduces the exact device rows (int8 codes AND
    scale/zero), through the one warmed scatter executable."""
    pool, cd = _pool()
    slot = pool.slot_of[1]
    row = np.asarray(pool.xs[slot]).copy()
    sc, zp = np.asarray(pool.x_scale[slot]), np.asarray(pool.x_zero[slot])
    pool.evict(1)
    assert 1 not in pool.slot_of
    new_slot = pool.admit(1, *cd[1])
    np.testing.assert_array_equal(np.asarray(pool.xs[new_slot]), row)
    assert np.asarray(pool.x_scale[new_slot]) == sc
    assert np.asarray(pool.x_zero[new_slot]) == zp
    x, y = pool.client_view(1)
    np.testing.assert_array_equal(x, cd[1][0])


def test_pool_zero_retrace_churn():
    """After warmup the scatter never retraces — admits across distinct
    clients, evicts, and re-admits are all cache hits on ONE executable,
    and the registry tallies stay views over the pool."""
    pool, cd = _pool(capacity=5, n_init=3)
    pool.warmup()
    base = pool.traces
    for i in range(3, 8):
        if len(pool.slot_of) == pool.capacity:
            pool.evict(min(pool.slot_of))
        pool.admit(i, *cd[i % len(cd)])
    assert pool.traces == base
    assert pool.traces == 1
    assert pool.admits == pool.registry.get("pool.admits")
    assert pool.evicts == pool.registry.get("pool.evicts")
    assert pool.uploads == pool.admits
    assert pool.registry.get("pool.resident") == len(pool.slot_of)
    err = pool.registry.get("pool.quant.abs_err")
    assert err.count == pool.admits


def test_pool_engine_round_and_full_capacity_errors():
    pool, cd = _pool(capacity=4, n_init=4)
    task, eng = _engine()
    params, losses = _one_round(eng, task, pool, k=3)
    assert np.isfinite(np.asarray(losses)).all()
    with pytest.raises(ValueError, match="full"):
        pool.admit(99, *cd[0])
    with pytest.raises(ValueError, match="resident"):
        pool.evict(99)
    with pytest.raises(ValueError, match="already resident"):
        pool.evict(0), pool.admit(1, *cd[1])
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="occupied"):
        pool.sample_slots(rng, pool.capacity + 1)


def test_pool_clustered_assignment_is_admit_order_free():
    """Centroids are fitted ONCE on the initial population; a churned-in
    client lands in the same cluster regardless of admit order."""
    pool, cd = _pool(capacity=8, clusters=2, n_init=6)
    feats = client_cluster_features([cd[6]])
    expect = int(assign_clusters(feats, pool.cluster_centroids)[0])
    slot = pool.admit(6, *cd[6])
    assert int(np.asarray(pool.cluster_of_device)[slot]) == expect


def test_rollout_meta_surfaces_bank_accounting():
    """The memory claim is a tracked number: every arena run's meta
    carries the bank's storage mode and nbytes/bytes-per-client."""
    from repro.core import paper_default_params
    from repro.sim import Arena, ScenarioGrid

    cd = _client_data()
    task, eng = _engine()
    bank = eng.make_bank(cd, tiered="single", storage="int8")
    sp = paper_default_params(num_devices=N, sample_count=2,
                              data_sizes=np.full(N, M, np.float32))
    grid = ScenarioGrid.create(controllers=["uni_d"], seeds=[0], V=100.0,
                               lam=0.5, sample_count=2, num_devices=N)
    rep = Arena(eng).run(task.init(jax.random.PRNGKey(0)), sp, bank, grid,
                         2, np.full(2, 0.1, np.float32))
    assert rep.meta["bank_storage"] == "int8"
    assert rep.meta["bank_nbytes"] == bank.nbytes
    assert rep.meta["bank_bytes_per_client"] == bank.bytes_per_client


def test_pool_nbytes_beats_fp32_oneshot():
    pool, _ = _pool(capacity=8, n_init=4)
    f32 = estimate_bank_nbytes([M] * 8, BS, SHAPE)
    assert f32 / pool.nbytes > 3
    assert pool.bytes_per_client == pytest.approx(pool.nbytes / 8)
