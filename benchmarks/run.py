"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * convergence (Figs. 1/2): LROA vs Uni-D/Uni-S/DivFL + % latency saved
  * lambda sweep (Fig. 3), V sweep (Fig. 4), K sweep (Figs. 5/6), and the
    ScenarioArena grid throughput (S-batched vs host-looped rollouts,
    recorded in the ``arena`` section of BENCH_round_engine.json)
  * kernel microbenches + Algorithm-2 solver latency
  * round-engine throughput (sequential vs fused vs scan rounds/sec,
    written to BENCH_round_engine.json)
  * roofline terms per (arch x shape x mesh) from the dry-run dumps

Default scale finishes on CPU in tens of minutes; --paper-scale switches to
the paper's 120-device / 2000-round configuration; --smoke runs every
section at tiny shapes in well under a minute (CI guard for the perf paths).
"""

from __future__ import annotations

import argparse
import sys


def smoke_config():
    from benchmarks.common import BenchConfig
    return BenchConfig(num_devices=6, rounds=3, sample_count=2,
                       local_epochs=1, batch_size=8, num_classes=2,
                       image_shape=(4, 4, 1), examples=240)


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface, importable without jax/numpy — the docs drift
    guard (``tools/check_docs.py``) parses every ``python -m
    benchmarks.run ...`` command quoted in docs/ against this parser."""
    ap = argparse.ArgumentParser(prog="benchmarks.run", description=__doc__)
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes everywhere; exercises every bench path")
    ap.add_argument("--skip", default="",
                    help="comma list: convergence,sweeps,kernels,"
                         "round_engine,roofline")
    ap.add_argument("--obs", metavar="LOG", nargs="?",
                    const="runlogs/bench.jsonl", default=None,
                    help="record a flight-recorder span log (JSONL) for "
                         "the whole bench run; optional path, default "
                         "runlogs/bench.jsonl — render it with "
                         "tools/obs_report.py")
    return ap


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    skip = set(filter(None, args.skip.split(",")))

    from benchmarks.common import BenchConfig
    if args.smoke:
        cfg = smoke_config()
    elif args.paper_scale:
        cfg = BenchConfig.paper_scale()
    else:
        cfg = BenchConfig()

    sink = None
    if args.obs:
        from repro.obs import trace as obs_trace
        sink = obs_trace.install_sink(obs_trace.JsonlSink(args.obs))
        print(f"# obs: recording spans to {args.obs}", file=sys.stderr)

    print("name,us_per_call,derived")
    if "kernels" not in skip:
        from benchmarks import bench_kernels
        for row in bench_kernels.run(smoke=args.smoke):
            print(row, flush=True)
    if "round_engine" not in skip:
        from benchmarks import bench_round_engine
        for row in bench_round_engine.run(smoke=args.smoke):
            print(row, flush=True)
    if "convergence" not in skip:
        from benchmarks import bench_convergence
        for row in bench_convergence.run(cfg):
            print(row, flush=True)
    if "sweeps" not in skip:
        from benchmarks import bench_sweeps
        sweeps = [
            (bench_sweeps.lambda_sweep, dict(mus=(1.0,))),
            (bench_sweeps.v_sweep, dict(nus=(1e5,), rounds=10)),
            (bench_sweeps.k_sweep, dict(ks=(2,))),
            (bench_sweeps.heterogeneity_sweep,
             dict(spreads=(2.0,), rounds=10)),
            (bench_sweeps.zoo_sweep, dict(rounds=3, seeds=1)),
            (bench_sweeps.arena_sweep,
             dict(s_values=(2, 4), rounds=3, smoke=True)),
        ]
        for fn, smoke_kwargs in sweeps:
            for row in fn(cfg, **(smoke_kwargs if args.smoke else {})):
                print(row, flush=True)
    if "roofline" not in skip:
        from benchmarks import bench_roofline
        for row in bench_roofline.run():
            print(row, flush=True)

    if sink is not None:
        from repro.obs import trace as obs_trace
        obs_trace.remove_sink(sink)
        sink.close()
        print(f"# obs: span log written to {sink.path}", file=sys.stderr)


if __name__ == "__main__":
    main()
