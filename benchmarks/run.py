"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * convergence (Figs. 1/2): LROA vs Uni-D/Uni-S/DivFL + % latency saved
  * lambda sweep (Fig. 3), V sweep (Fig. 4), K sweep (Figs. 5/6)
  * kernel microbenches + Algorithm-2 solver latency
  * roofline terms per (arch x shape x mesh) from the dry-run dumps

Default scale finishes on CPU in tens of minutes; --paper-scale switches to
the paper's 120-device / 2000-round configuration.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--skip", default="",
                    help="comma list: convergence,sweeps,kernels,roofline")
    args = ap.parse_args(argv)
    skip = set(filter(None, args.skip.split(",")))

    from benchmarks.common import BenchConfig
    cfg = BenchConfig.paper_scale() if args.paper_scale else BenchConfig()

    print("name,us_per_call,derived")
    if "kernels" not in skip:
        from benchmarks import bench_kernels
        for row in bench_kernels.run():
            print(row, flush=True)
    if "convergence" not in skip:
        from benchmarks import bench_convergence
        for row in bench_convergence.run(cfg):
            print(row, flush=True)
    if "sweeps" not in skip:
        from benchmarks import bench_sweeps
        for row in bench_sweeps.lambda_sweep(cfg):
            print(row, flush=True)
        for row in bench_sweeps.v_sweep(cfg):
            print(row, flush=True)
        for row in bench_sweeps.k_sweep(cfg):
            print(row, flush=True)
        for row in bench_sweeps.heterogeneity_sweep(cfg):
            print(row, flush=True)
    if "roofline" not in skip:
        from benchmarks import bench_roofline
        for row in bench_roofline.run():
            print(row, flush=True)


if __name__ == "__main__":
    main()
