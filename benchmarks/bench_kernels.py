"""Kernel microbenchmarks (CPU: the jnp reference path is timed; the Pallas
bodies are validated in interpret mode by tests — wall-clock kernel numbers
only mean something on real TPUs, so `derived` records the modelled TPU-v5e
roofline time for the same shape instead)."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_us
from repro.kernels import ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def bench_flash(b=1, h=8, hkv=2, s=1024, d=64) -> str:
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(rng, 1), (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (b, hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(rng, 3), (b, hkv, s, d))
    f = jax.jit(lambda q, k, v: ref.mha_reference(q, k, v, causal=True))
    us = time_us(lambda *a: jax.block_until_ready(f(*a)), q, k, v, iters=5)
    flops = 4.0 * b * h * s * s * d
    tpu_us = flops / PEAK_FLOPS_BF16 * 1e6
    return csv_row(f"kernels/flash_attention/b{b}h{h}s{s}d{d}", us,
                   f"flops={flops:.2e};tpu_roofline_us={tpu_us:.1f}")


def bench_ssd(b=2, s=2048, nh=8, hd=64, n=64, chunk=128) -> str:
    rng = jax.random.PRNGKey(1)
    from repro.kernels import ssd_chunk
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 2),
                                           (b, s, nh)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, nh))
    b_in = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n))
    c_in = jax.random.normal(jax.random.fold_in(rng, 4), (b, s, n))
    us = time_us(lambda: jax.block_until_ready(
        ssd_chunk(x, dt, a_log, b_in, c_in, chunk=chunk)), iters=5)
    flops = b * s * chunk * nh * (n + hd) * 2.0
    return csv_row(f"kernels/ssd_chunk/b{b}s{s}nh{nh}", us,
                   f"intra_chunk_flops={flops:.2e}")


def bench_aggregate(n=4_000_000, k=4) -> str:
    rng = jax.random.PRNGKey(2)
    from repro.kernels import fl_aggregate
    theta = jax.random.normal(jax.random.fold_in(rng, 1), (n,))
    deltas = jax.random.normal(jax.random.fold_in(rng, 2), (k, n))
    coeffs = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 3),
                                              (k,)))
    us = time_us(lambda: jax.block_until_ready(
        fl_aggregate(theta, deltas, coeffs)), iters=10)
    bytes_moved = (k + 2.0) * n * 4
    tpu_us = bytes_moved / HBM_BW * 1e6
    return csv_row(f"kernels/fl_aggregate/n{n}k{k}", us,
                   f"bytes={bytes_moved:.2e};tpu_roofline_us={tpu_us:.1f}")


def bench_aggregate_pytree(hidden=256, k=8) -> str:
    """eq.-(4) on a real model pytree: per-leaf reduce vs ravelled fused."""
    from repro.fl import aggregate_fused, aggregate_stacked
    from repro.models import MLPTask
    task = MLPTask(input_dim=3072, num_classes=10, hidden=hidden)
    params = task.init(jax.random.PRNGKey(0))
    deltas = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1),
                                    (k,) + p.shape, p.dtype), params)
    coeffs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (k,)))
    stacked = jax.jit(aggregate_stacked)
    fused = jax.jit(aggregate_fused, static_argnames=("impl",))
    us_s = time_us(lambda: jax.block_until_ready(
        stacked(params, deltas, coeffs)), iters=10)
    us_f = time_us(lambda: jax.block_until_ready(
        fused(params, deltas, coeffs)), iters=10)
    return csv_row(f"kernels/fl_aggregate_pytree/h{hidden}k{k}", us_f,
                   f"per_leaf_us={us_s:.1f};fused_us={us_f:.1f}")


def bench_solver(n=120) -> str:
    import numpy as np
    from repro.core import estimate_hyperparams, paper_default_params, solve_p2
    rng = np.random.default_rng(0)
    params = paper_default_params(
        num_devices=n, data_sizes=rng.integers(200, 600, n).astype("float32"))
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5)
    import jax.numpy as jnp
    h = jnp.asarray(np.clip(rng.exponential(0.1, n), 0.01, 0.5)
                    .astype("float32"))
    queues = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (n,))) * 1e3
    us = time_us(lambda: jax.block_until_ready(
        solve_p2(params, h, queues, hp.V, hp.lam)), iters=10)
    return csv_row(f"core/algorithm2_solve_p2/N{n}", us,
                   "per_round_decision_latency")


def run(smoke: bool = False) -> List[str]:
    if smoke:
        return [bench_flash(b=1, h=2, hkv=2, s=64, d=16),
                bench_ssd(b=1, s=64, nh=2, hd=16, n=8, chunk=16),
                bench_aggregate(n=10_000, k=2),
                bench_aggregate_pytree(hidden=16, k=2),
                bench_solver(n=8)]
    return [bench_flash(), bench_ssd(), bench_aggregate(),
            bench_aggregate_pytree(), bench_solver()]


if __name__ == "__main__":
    for row in run():
        print(row)
