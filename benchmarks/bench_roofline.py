"""Roofline table from the dry-run JSON dumps (§Roofline deliverable).

Reads dryrun_baseline.json / dryrun_optimized.json when present and emits
one CSV row per (arch x shape x mesh) with the three terms + dominant +
useful-flops ratio. Does NOT recompile (the sweeps are hour-scale; run
``python -m repro.launch.dryrun --all`` to regenerate)."""

from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import csv_row

CANDIDATES = ("dryrun_optimized.json", "dryrun_baseline.json")


def run() -> List[str]:
    rows = []
    for fname in CANDIDATES:
        if not os.path.exists(fname):
            continue
        tag = fname.replace("dryrun_", "").replace(".json", "")
        with open(fname) as f:
            data = json.load(f)
        for r in data["results"]:
            t = r["terms"]
            rows.append(csv_row(
                f"roofline[{tag}]/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                f"compute_s={t['compute_s']:.4f};"
                f"memory_s={t['memory_s']:.4f};"
                f"collective_s={t['collective_s']:.4f};"
                f"dominant={t['dominant']};"
                f"useful={t.get('model_flops_ratio', 0):.3f}"))
        if data.get("failures"):
            rows.append(csv_row(f"roofline[{tag}]/FAILURES", 0.0,
                                f"count={len(data['failures'])}"))
        break            # prefer the optimized dump when both exist
    if not rows:
        rows.append(csv_row("roofline/missing", 0.0,
                            "run repro.launch.dryrun --all first"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
