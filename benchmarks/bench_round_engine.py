"""Round-engine benchmark: simulated FL rounds/sec, seed sequential path vs
the fused round engine (bank-resident vs host-restacked data planes) vs the
multi-round ``lax.scan`` fast path.

The comparison holds everything fixed (task, controller, channel, client
data, K) and only swaps the execution strategy:

* ``sequential``     — the seed semantics: one jitted ``local_update``
  dispatch per sampled client + list-of-pytrees aggregation
  (``use_engine=False``);
* ``host_restacked`` — the PR-1 data plane: one fused jit per round, but
  the K selected clients' ``[K, B, ...]`` batch is gathered on the host
  and re-uploaded every round (``bank.gather_host`` +
  ``round_step_stacked``);
* ``engine``         — the ClientBank data plane: the ``[N, B, ...]``
  stacks live on device and the round's jit gathers its K rows by
  ``selected`` inside the trace — zero per-round client-data transfers;
* ``scan``           — whole rollout in one jit (decide/sample/train/
  aggregate/queue-update inside ``lax.scan`` over the same bank).

A second section holds the data volume fixed but skews the partition
(dirichlet-0.5 sizes, the non-iid workload of Luo et al. / Dinh et al.)
and compares the single-global-bucket bank against the bucket-ladder
``TieredClientBank``: device rows held (padded vs true example counts —
the memory win the ladder exists for) and rounds/sec under identical
mixed-tier selections.

A third section exercises the million-client data plane (PR 10): an int8
slot-recycled ``BankPool`` at N_cap=10k (smoke: 24), flat vs hierarchical
cluster aggregation rounds/sec, admit/evict churn under a strict
``Watchdog`` (zero retraces), and the fp32-one-shot vs int8-pooled
bytes-per-client accounting.

Emits ``BENCH_round_engine.json`` with rounds/sec for the trajectory so the
perf numbers are tracked across PRs.  The default shape is the acceptance
operating point K=8, N=120.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import List, Optional

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.core import LROAController, estimate_hyperparams, paper_default_params
from repro.data import synthetic_image_classification
from repro.fl import (ChannelConfig, ChannelProcess, ClientConfig,
                      FederatedTrainer, RoundEngine)
from repro.models import MLPTask
from repro.optim import constant


@dataclasses.dataclass
class EngineBenchConfig:
    num_devices: int = 120         # N=120: the paper's device population
    sample_count: int = 8          # K=8: the acceptance-criteria operating point
    examples_per_client: int = 64  # equal sizes => one compiled shape per path
    image_shape: tuple = (8, 8, 1)
    num_classes: int = 4
    local_epochs: int = 2
    batch_size: int = 16
    rounds: int = 30               # timed rounds (after warmup)
    warmup_rounds: int = 3
    lr: float = 0.1
    seed: int = 0

    @classmethod
    def smoke(cls) -> "EngineBenchConfig":
        return cls(num_devices=6, sample_count=2, examples_per_client=32,
                   image_shape=(4, 4, 1), num_classes=2, batch_size=8,
                   rounds=3, warmup_rounds=1)


def _build_trainer(cfg: EngineBenchConfig, use_engine: bool
                   ) -> FederatedTrainer:
    n, m = cfg.num_devices, cfg.examples_per_client
    x, y = synthetic_image_classification(n * m, cfg.image_shape,
                                          cfg.num_classes, noise=0.3,
                                          seed=cfg.seed)
    client_data = [(x[i * m:(i + 1) * m], y[i * m:(i + 1) * m])
                   for i in range(n)]
    params = paper_default_params(
        num_devices=n, sample_count=cfg.sample_count,
        local_epochs=cfg.local_epochs,
        data_sizes=np.full(n, m, np.float32))
    task = MLPTask(input_dim=int(np.prod(cfg.image_shape)),
                   num_classes=cfg.num_classes, hidden=32)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    return FederatedTrainer(
        task, params, LROAController(params, hp),
        ChannelProcess(n, ChannelConfig(seed=cfg.seed)), client_data,
        ClientConfig(local_epochs=cfg.local_epochs,
                     batch_size=cfg.batch_size),
        constant(cfg.lr), test_data=None, seed=cfg.seed,
        use_engine=use_engine)


def _rounds_per_sec(trainer: FederatedTrainer, cfg: EngineBenchConfig
                    ) -> float:
    for t in range(cfg.warmup_rounds):
        trainer.run_round(t)
    t0 = time.perf_counter()
    for t in range(cfg.rounds):
        trainer.run_round(cfg.warmup_rounds + t)
    return cfg.rounds / (time.perf_counter() - t0)


def _data_plane_rounds_per_sec(cfg: EngineBenchConfig, bank_resident: bool
                               ) -> float:
    """Isolate the round data plane: identical selections/coeffs/rngs per
    round, only the client-data path differs — gathered inside the jit
    from the device bank (``bank_resident``) vs host-restacked
    ``[K, B, ...]`` uploads (the PR-1 plane: ``bank.gather_host`` +
    ``round_step_stacked``)."""
    trainer = _build_trainer(cfg, use_engine=True)
    eng, bank = trainer.engine, trainer.bank
    k = cfg.sample_count
    rng = np.random.default_rng(cfg.seed)
    params = trainer.global_params
    rngs = jax.random.split(jax.random.PRNGKey(cfg.seed), k)
    coeffs = np.full(k, 1.0 / k, np.float32)

    def one_round(params):
        selected = rng.integers(0, cfg.num_devices, k)
        if bank_resident:
            params, losses = eng.round_step(params, bank, selected, coeffs,
                                            cfg.lr, rngs)
        else:
            xs, ys, ns, ne = bank.gather_host(selected)
            params, losses = eng.round_step_stacked(params, xs, ys, coeffs,
                                                    cfg.lr, rngs, ns, ne)
        jax.block_until_ready(losses)
        return params

    # These loops time only the data plane (no controller/queue work), so
    # rounds are ~ms each — run 10x the trainer budget to pull the
    # bank-vs-host ratio out of scheduler noise.
    plane_rounds = cfg.rounds * 10
    for _ in range(cfg.warmup_rounds):
        params = one_round(params)
    t0 = time.perf_counter()
    for _ in range(plane_rounds):
        params = one_round(params)
    return plane_rounds / (time.perf_counter() - t0)


def _scan_rounds_per_sec(cfg: EngineBenchConfig) -> float:
    trainer = _build_trainer(cfg, use_engine=True)
    eng, bank = trainer.engine, trainer.bank
    chan = ChannelProcess(cfg.num_devices, ChannelConfig(seed=cfg.seed))
    h_seq = chan.sample_sequence(cfg.rounds)
    lr_seq = np.full(cfg.rounds, cfg.lr, np.float32)
    hp = trainer.controller.hp

    def once(seed):
        p, q, m = eng.run_scan(
            trainer.task.init(jax.random.PRNGKey(seed)), trainer.params,
            bank, h_seq, lr_seq, jax.random.PRNGKey(seed), policy="lroa",
            V=hp.V, lam=hp.lam)
        jax.block_until_ready(jax.tree_util.tree_leaves(p))
        return m

    once(0)                                    # compile
    t0 = time.perf_counter()
    once(1)
    return cfg.rounds / (time.perf_counter() - t0)


def _skewed_client_data(cfg: EngineBenchConfig, alpha: float = 0.5):
    """Dirichlet-``alpha`` split of the SAME total data volume as the
    uniform sections (``N * examples_per_client``), so padded-row counts
    are directly comparable."""
    rng = np.random.default_rng(cfg.seed + 1)
    n = cfg.num_devices
    total = n * cfg.examples_per_client
    props = rng.dirichlet(np.full(n, alpha))
    sizes = np.maximum((props * total).astype(np.int64), 2)
    # the largest client absorbs the floor/clamp remainder so the skewed
    # partition holds EXACTLY the uniform sections' example count
    sizes[np.argmax(sizes)] += total - sizes.sum()
    assert sizes.min() >= 2 and sizes.sum() == total
    x, y = synthetic_image_classification(int(sizes.sum()), cfg.image_shape,
                                          cfg.num_classes, noise=0.3,
                                          seed=cfg.seed)
    offs = np.cumsum(np.concatenate([[0], sizes]))
    return sizes, [(x[offs[i]:offs[i + 1]], y[offs[i]:offs[i + 1]])
                   for i in range(n)]


def _skewed_bank_section(cfg: EngineBenchConfig, alpha: float = 0.5):
    """Single-bucket vs bucket-ladder bank on the skewed partition:
    device rows held (padded vs true) and rounds/sec under identical
    mixed-tier selections.  Returns (csv rows, json sub-dict)."""
    sizes, cd = _skewed_client_data(cfg, alpha)
    task = MLPTask(input_dim=int(np.prod(cfg.image_shape)),
                   num_classes=cfg.num_classes, hidden=32)
    eng = RoundEngine(task, ClientConfig(local_epochs=cfg.local_epochs,
                                         batch_size=cfg.batch_size))
    k = cfg.sample_count
    plane_rounds = cfg.rounds * 10
    # one fixed selection sequence: the warm pass compiles every hit-tier
    # subset the timed pass will see, and both bank modes replay it
    sel_rng = np.random.default_rng(cfg.seed)
    selections = [sel_rng.integers(0, cfg.num_devices, k)
                  for _ in range(plane_rounds)]
    rngs = jax.random.split(jax.random.PRNGKey(cfg.seed), k)
    coeffs = np.full(k, 1.0 / k, np.float32)
    stats = {"alpha": alpha, "sizes_min": int(sizes.min()),
             "sizes_max": int(sizes.max()),
             "true_examples": int(sizes.sum())}
    for mode in ("single", "tiered"):
        bank = eng.make_bank(cd, tiered=mode)
        stats[f"padded_examples_{mode}"] = bank.padded_examples
        stats[f"padding_ratio_{mode}"] = (bank.padded_examples /
                                          bank.true_examples)
        if mode == "tiered":
            stats["tier_buckets"] = list(bank.tier_buckets)
            stats["tier_counts"] = [int(m.size)
                                    for m in bank.tier_members]
        params = task.init(jax.random.PRNGKey(0))
        for sel in selections:                      # compile + warm pass
            params, losses = eng.round_step(params, bank, sel, coeffs,
                                            cfg.lr, rngs)
        jax.block_until_ready(losses)
        t0 = time.perf_counter()
        for sel in selections:                      # timed replay
            params, losses = eng.round_step(params, bank, sel, coeffs,
                                            cfg.lr, rngs)
            jax.block_until_ready(losses)
        stats[f"{mode}_rounds_per_sec"] = (plane_rounds /
                                           (time.perf_counter() - t0))
        # the scan path on the same skewed bank: the tiered scan body
        # wraps each tier in a selection-conditioned lax.cond (tier-aware
        # skipping), so rounds hitting few tiers stop paying
        # K * sum_t B_t work — this row tracks that win vs the single
        # global bucket's K * B_max
        sp = paper_default_params(
            num_devices=cfg.num_devices, sample_count=k,
            local_epochs=cfg.local_epochs,
            data_sizes=sizes.astype(np.float32))
        chan = ChannelProcess(cfg.num_devices, ChannelConfig(seed=cfg.seed))
        h_seq = chan.sample_sequence(cfg.rounds)
        lr_seq = np.full(cfg.rounds, cfg.lr, np.float32)

        def scan_once(seed):
            p, q, m = eng.run_scan(
                task.init(jax.random.PRNGKey(seed)), sp, bank, h_seq,
                lr_seq, jax.random.PRNGKey(seed), policy="uni_d")
            jax.block_until_ready(jax.tree_util.tree_leaves(p))

        scan_once(0)                                # compile
        t0 = time.perf_counter()
        scan_once(1)
        stats[f"{mode}_scan_rounds_per_sec"] = (cfg.rounds /
                                                (time.perf_counter() - t0))
    stats["padding_saving_tiered_vs_single"] = (
        stats["padded_examples_single"] / stats["padded_examples_tiered"])
    tag = f"K{cfg.sample_count}N{cfg.num_devices}dir{alpha}"
    rows = [
        csv_row(f"round_engine/skewed_single_bucket/{tag}",
                1e6 / stats["single_rounds_per_sec"],
                f"rounds_per_sec={stats['single_rounds_per_sec']:.2f};"
                f"padded_examples={stats['padded_examples_single']};"
                f"padding_ratio={stats['padding_ratio_single']:.2f}"),
        csv_row(f"round_engine/skewed_tiered_bank/{tag}",
                1e6 / stats["tiered_rounds_per_sec"],
                f"rounds_per_sec={stats['tiered_rounds_per_sec']:.2f};"
                f"padded_examples={stats['padded_examples_tiered']};"
                f"padding_ratio={stats['padding_ratio_tiered']:.2f};"
                f"mem_saving_vs_single="
                f"{stats['padding_saving_tiered_vs_single']:.2f}"),
        csv_row(f"round_engine/skewed_scan_single/{tag}",
                1e6 / stats["single_scan_rounds_per_sec"],
                f"rounds_per_sec="
                f"{stats['single_scan_rounds_per_sec']:.2f}"),
        csv_row(f"round_engine/skewed_scan_tiered/{tag}",
                1e6 / stats["tiered_scan_rounds_per_sec"],
                f"rounds_per_sec="
                f"{stats['tiered_scan_rounds_per_sec']:.2f};"
                f"vs_single_bucket_scan="
                f"{stats['tiered_scan_rounds_per_sec'] / stats['single_scan_rounds_per_sec']:.2f}"),
    ]
    return rows, stats


def _scale_section(cfg: EngineBenchConfig, smoke: bool = False):
    """Million-client data plane: the int8 slot-recycled ``BankPool`` at
    an N the fp32 one-shot bank cannot reach.

    Full scale is N_cap=10k, K=8: the pool is bulk-populated (one row
    upload per admit through ONE scatter executable), timed on fused
    rounds (flat and hierarchical cluster aggregation), then churned
    under an armed STRICT watchdog — admits/evicts must hit zero arena
    retraces and zero pool scatter retraces.  The fp32 one-shot
    footprint at the same shape is recorded by pure accounting
    (:func:`~repro.fl.client_bank.estimate_bank_nbytes`) — building it
    is exactly the infeasibility the section documents.  An int8-vs-fp32
    equivalence guard runs at small N on the same data distribution.
    Returns (csv rows, json sub-dict); raises AssertionError if the
    bytes-reduction, zero-retrace, or equivalence contracts fail.
    """
    from repro.fl.client_bank import BankPool, estimate_bank_nbytes
    from repro.obs.watchdog import Watchdog
    from repro.sim import Arena, ScenarioGrid

    if smoke:
        n_cap, k, m, clusters, churn, t_rounds = 24, 2, 32, 4, 6, 3
        min_ratio = 2.5          # tiny smoke shape (4x4 images, int32
        #                          labels) caps the ratio below full scale
    else:
        n_cap, k, m, clusters, churn, t_rounds = 10_000, 8, 64, 64, 64, 3
        min_ratio = 3.5
    bs, shape = cfg.batch_size, cfg.image_shape
    feat = int(np.prod(shape))
    client_cfg = ClientConfig(local_epochs=cfg.local_epochs, batch_size=bs)
    task = MLPTask(input_dim=feat, num_classes=cfg.num_classes, hidden=32)
    eng = RoundEngine(task, client_cfg)
    # one bounded base set; clients slice it with wraparound, so N_cap
    # scales free of host data volume
    base_n = min(n_cap * m, 65_536)
    bx, by = synthetic_image_classification(base_n, shape, cfg.num_classes,
                                            noise=0.3, seed=cfg.seed)

    def client(i):
        idx = (i * m + np.arange(m)) % base_n
        return bx[idx], by[idx]

    stats = {"n_cap": n_cap, "k": k, "examples_per_client": m,
             "storage": "int8", "num_clusters": clusters}

    t0 = time.perf_counter()
    pool = BankPool(client_cfg, capacity=n_cap, storage="int8",
                    clusters=clusters,
                    initial_clients={i: client(i) for i in range(n_cap)})
    stats["populate_s"] = time.perf_counter() - t0
    stats["populate_admits"] = pool.admits
    stats["bucket_examples"] = pool.bucket_examples

    # -- the memory claim, as tracked numbers -----------------------------
    fp32_bytes = estimate_bank_nbytes([m] * n_cap, bs, shape,
                                      label_shape=by.shape[1:],
                                      feature_dtype=bx.dtype,
                                      label_dtype=by.dtype)
    stats["fp32_oneshot_nbytes"] = fp32_bytes
    stats["int8_pool_nbytes"] = pool.nbytes
    stats["bytes_per_client_fp32_oneshot"] = fp32_bytes / n_cap
    stats["bytes_per_client_int8_pooled"] = pool.bytes_per_client
    ratio = fp32_bytes / pool.nbytes
    stats["bytes_reduction"] = ratio
    assert ratio >= min_ratio, (
        f"int8 pooled bank reduces bytes-per-client only {ratio:.2f}x "
        f"(need >= {min_ratio}x)")

    # -- int8-vs-fp32 equivalence guard at small N ------------------------
    guard_n = min(n_cap, 12)
    guard_cd = [client(i) for i in range(guard_n)]
    bank_f = eng.make_bank(guard_cd, tiered="single")
    bank_q = eng.make_bank(guard_cd, tiered="single", storage="int8")
    params0 = task.init(jax.random.PRNGKey(cfg.seed))
    sel = np.arange(min(k, guard_n))
    coeffs = np.full(sel.size, 1.0 / sel.size, np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(cfg.seed), sel.size)
    p_f, _ = eng.round_step(params0, bank_f, sel, coeffs, cfg.lr, rngs)
    p_q, _ = eng.round_step(params0, bank_q, sel, coeffs, cfg.lr, rngs)
    dev = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in zip(jax.tree_util.tree_leaves(p_f),
                              jax.tree_util.tree_leaves(p_q)))
    stats["quant_guard_max_param_dev"] = dev
    stats["quant_guard_tol"] = 0.02
    assert dev <= 0.02, (
        f"int8 round drifted {dev:.4f} from fp32 (tolerance contract "
        f"0.02) — quantization plumbing is broken, not just lossy")

    # -- pooled rounds/sec (flat + hierarchical eq.-(4)) ------------------
    pool.warmup()
    slot_rng = np.random.default_rng(cfg.seed + 2)
    rngs_k = jax.random.split(jax.random.PRNGKey(cfg.seed), k)
    coeffs_k = np.full(k, 1.0 / k, np.float32)
    plane_rounds = cfg.rounds * (1 if smoke else 10)

    def timed_rounds(hierarchical):
        params = params0
        for _ in range(cfg.warmup_rounds):        # compile + warm
            slots = pool.sample_slots(slot_rng, k)
            params, losses = eng.round_step(params, pool, slots, coeffs_k,
                                            cfg.lr, rngs_k,
                                            hierarchical=hierarchical)
        jax.block_until_ready(losses)
        t0 = time.perf_counter()
        for _ in range(plane_rounds):
            slots = pool.sample_slots(slot_rng, k)
            params, losses = eng.round_step(params, pool, slots, coeffs_k,
                                            cfg.lr, rngs_k,
                                            hierarchical=hierarchical)
            jax.block_until_ready(losses)
        return plane_rounds / (time.perf_counter() - t0)

    stats["pooled_rounds_per_sec"] = timed_rounds(False)
    stats["hierarchical_rounds_per_sec"] = timed_rounds(True)

    # -- churn under the strict watchdog ----------------------------------
    arena = Arena(eng)
    dog = Watchdog(strict=True).attach(arena)
    sp = paper_default_params(
        num_devices=n_cap, sample_count=k, local_epochs=cfg.local_epochs,
        data_sizes=pool.sizes.astype(np.float32))
    grid = ScenarioGrid.create(
        controllers=["uni_d", "uni_d"], seeds=np.arange(2),
        V=np.full(2, 100.0, np.float32), lam=np.full(2, 1.0, np.float32),
        sample_count=k)
    lr_seq = np.full(t_rounds, cfg.lr, np.float32)
    arena.warmup(params0, sp, pool, grid, t_rounds)
    h_all = arena.sample_channels(grid, t_rounds, n_cap)
    arena.run(params0, sp, pool, grid, t_rounds, lr_seq, h_all=h_all)
    traces_before = pool.traces
    t0 = time.perf_counter()
    next_id = n_cap
    for i in range(churn):
        pool.evict(i % n_cap if i % n_cap in pool.slot_of else next_id - 1)
        pool.admit(next_id, *client(next_id))
        next_id += 1
    stats["churn_cycles"] = churn
    stats["churn_admits_per_sec"] = churn / (time.perf_counter() - t0)
    # the strict watchdog raises RetraceError here if churn invalidated
    # any warmed executable — the run doubles as the assertion
    arena.run(params0, sp, pool, grid, t_rounds, lr_seq, h_all=h_all)
    stats["watchdog_retraces"] = len(dog.violations)
    stats["pool_scatter_retraces"] = pool.traces - traces_before
    assert stats["watchdog_retraces"] == 0
    assert stats["pool_scatter_retraces"] == 0, (
        f"pool churn retraced the scatter "
        f"{stats['pool_scatter_retraces']} time(s)")
    q_err = pool.registry.get("pool.quant.abs_err", default=None)
    if q_err is not None and q_err.count:
        stats["quant_abs_err_mean"] = q_err.mean
        stats["quant_abs_err_p99"] = q_err.percentiles((99.0,))[99.0]

    tag = f"K{k}N{n_cap}"
    rows = [
        csv_row(f"round_engine/scale_pooled_int8/{tag}",
                1e6 / stats["pooled_rounds_per_sec"],
                f"rounds_per_sec={stats['pooled_rounds_per_sec']:.2f};"
                f"bytes_per_client={pool.bytes_per_client:.0f};"
                f"fp32_oneshot_bytes_per_client={fp32_bytes / n_cap:.0f};"
                f"bytes_reduction={ratio:.2f}"),
        csv_row(f"round_engine/scale_hierarchical/{tag}",
                1e6 / stats["hierarchical_rounds_per_sec"],
                f"rounds_per_sec="
                f"{stats['hierarchical_rounds_per_sec']:.2f};"
                f"clusters={clusters}"),
        csv_row(f"round_engine/scale_churn/{tag}",
                1e6 / stats["churn_admits_per_sec"],
                f"admits_per_sec={stats['churn_admits_per_sec']:.2f};"
                f"watchdog_retraces=0;pool_scatter_retraces=0"),
    ]
    return rows, stats


def _obs_overhead_section(cfg: EngineBenchConfig) -> dict:
    """The flight recorder's cost at the acceptance operating point:
    the SAME instrumented trainer loop timed with no sink installed
    (the ``repro.obs`` zero-overhead contract — spans collapse to a
    shared no-op singleton) and with a live ``JsonlSink`` recording
    every span.  ``sink_off`` is the production configuration; its
    rounds/sec must sit within noise of the historical ``engine``
    row."""
    from repro.obs import trace as obs_trace

    off_a = _rounds_per_sec(_build_trainer(cfg, use_engine=True), cfg)
    fd, log = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        os.remove(log)               # JsonlSink appends; start clean
        with obs_trace.installed(obs_trace.JsonlSink(log)):
            on = _rounds_per_sec(_build_trainer(cfg, use_engine=True),
                                 cfg)
        spans = len(obs_trace.load_jsonl(log))
    finally:
        if os.path.exists(log):
            os.remove(log)
    # second no-sink pass AFTER the sink-on pass: process-level warmup
    # (allocator, BLAS threads) lands on whichever pass runs first, so
    # an off/on/off sandwich with best-of-off is order-robust
    off_b = _rounds_per_sec(_build_trainer(cfg, use_engine=True), cfg)
    off = max(off_a, off_b)
    return {"sink_off_rounds_per_sec": off,
            "sink_on_rounds_per_sec": on,
            "sink_on_slowdown": off / on,
            "spans_recorded": spans}


def preserve_foreign_sections(result: dict, prev: dict) -> dict:
    """Carry every top-level section of a previous record that this
    bench does not itself produce into the fresh ``result`` — the
    shared-record contract: ``BENCH_round_engine.json`` is co-owned by
    several benches (``bench_sweeps`` writes ``arena`` and
    ``arena.streaming``-style sections), and a re-record of THIS bench
    must never silently drop a sibling's data.  Keys present in
    ``result`` are this bench's own and always win."""
    out = dict(result)
    for key, value in prev.items():
        if key not in out:
            out[key] = value
    return out


def run(cfg: Optional[EngineBenchConfig] = None, smoke: bool = False,
        json_path: Optional[str] = None) -> List[str]:
    if cfg is None:
        cfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    if json_path is None:
        # smoke numbers must not clobber the tracked full-scale record
        json_path = ("BENCH_round_engine.smoke.json" if smoke
                     else "BENCH_round_engine.json")
    seq = _rounds_per_sec(_build_trainer(cfg, use_engine=False), cfg)
    eng = _rounds_per_sec(_build_trainer(cfg, use_engine=True), cfg)
    host = _data_plane_rounds_per_sec(cfg, bank_resident=False)
    bank = _data_plane_rounds_per_sec(cfg, bank_resident=True)
    scan = _scan_rounds_per_sec(cfg)
    skew_rows, skew_stats = _skewed_bank_section(cfg)
    scale_rows, scale_stats = _scale_section(cfg, smoke=smoke)
    obs_stats = _obs_overhead_section(cfg)
    result = {
        "config": dataclasses.asdict(cfg),
        "backend": jax.default_backend(),
        "seq_rounds_per_sec": seq,
        "engine_rounds_per_sec": eng,
        "host_restacked_rounds_per_sec": host,
        "bank_resident_rounds_per_sec": bank,
        "scan_rounds_per_sec": scan,
        "speedup_engine_vs_seq": eng / seq,
        "speedup_bank_vs_host_restacked": bank / host,
        "speedup_scan_vs_seq": scan / seq,
        "skewed": skew_stats,
        "scale": scale_stats,
        "obs_overhead": obs_stats,
    }
    # other benches (bench_sweeps' "arena" section, future sections such
    # as "arena.streaming" siblings) merge into the same tracked file —
    # keep every section this bench does not own when it rewrites
    try:
        with open(json_path) as f:
            prev = json.load(f)
        result = preserve_foreign_sections(result, prev)
    except (OSError, ValueError):
        pass
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    tag = f"K{cfg.sample_count}N{cfg.num_devices}"
    return [
        csv_row(f"round_engine/sequential/{tag}", 1e6 / seq,
                f"rounds_per_sec={seq:.2f}"),
        csv_row(f"round_engine/fused/{tag}", 1e6 / eng,
                f"rounds_per_sec={eng:.2f};speedup_vs_seq={eng / seq:.2f}"),
        csv_row(f"round_engine/host_restacked/{tag}", 1e6 / host,
                f"rounds_per_sec={host:.2f}"),
        csv_row(f"round_engine/bank_resident/{tag}", 1e6 / bank,
                f"rounds_per_sec={bank:.2f};"
                f"speedup_vs_host_restacked={bank / host:.2f}"),
        csv_row(f"round_engine/scan/{tag}", 1e6 / scan,
                f"rounds_per_sec={scan:.2f};speedup_vs_seq={scan / seq:.2f}"),
        csv_row(f"round_engine/obs_overhead/{tag}",
                1e6 / obs_stats["sink_off_rounds_per_sec"],
                f"sink_off_rounds_per_sec="
                f"{obs_stats['sink_off_rounds_per_sec']:.2f};"
                f"sink_on_rounds_per_sec="
                f"{obs_stats['sink_on_rounds_per_sec']:.2f};"
                f"sink_on_slowdown="
                f"{obs_stats['sink_on_slowdown']:.3f};"
                f"spans={obs_stats['spans_recorded']}"),
    ] + skew_rows + scale_rows


if __name__ == "__main__":
    for row in run():
        print(row)
