"""Round-engine benchmark: simulated FL rounds/sec, seed sequential path vs
the fused round engine (bank-resident vs host-restacked data planes) vs the
multi-round ``lax.scan`` fast path.

The comparison holds everything fixed (task, controller, channel, client
data, K) and only swaps the execution strategy:

* ``sequential``     — the seed semantics: one jitted ``local_update``
  dispatch per sampled client + list-of-pytrees aggregation
  (``use_engine=False``);
* ``host_restacked`` — the PR-1 data plane: one fused jit per round, but
  the K selected clients' ``[K, B, ...]`` batch is gathered on the host
  and re-uploaded every round (``bank.gather_host`` +
  ``round_step_stacked``);
* ``engine``         — the ClientBank data plane: the ``[N, B, ...]``
  stacks live on device and the round's jit gathers its K rows by
  ``selected`` inside the trace — zero per-round client-data transfers;
* ``scan``           — whole rollout in one jit (decide/sample/train/
  aggregate/queue-update inside ``lax.scan`` over the same bank).

Emits ``BENCH_round_engine.json`` with rounds/sec for the trajectory so the
perf numbers are tracked across PRs.  The default shape is the acceptance
operating point K=8, N=120.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import List, Optional

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.core import LROAController, estimate_hyperparams, paper_default_params
from repro.data import synthetic_image_classification
from repro.fl import ChannelConfig, ChannelProcess, ClientConfig, FederatedTrainer
from repro.models import MLPTask
from repro.optim import constant


@dataclasses.dataclass
class EngineBenchConfig:
    num_devices: int = 120         # N=120: the paper's device population
    sample_count: int = 8          # K=8: the acceptance-criteria operating point
    examples_per_client: int = 64  # equal sizes => one compiled shape per path
    image_shape: tuple = (8, 8, 1)
    num_classes: int = 4
    local_epochs: int = 2
    batch_size: int = 16
    rounds: int = 30               # timed rounds (after warmup)
    warmup_rounds: int = 3
    lr: float = 0.1
    seed: int = 0

    @classmethod
    def smoke(cls) -> "EngineBenchConfig":
        return cls(num_devices=6, sample_count=2, examples_per_client=32,
                   image_shape=(4, 4, 1), num_classes=2, batch_size=8,
                   rounds=3, warmup_rounds=1)


def _build_trainer(cfg: EngineBenchConfig, use_engine: bool
                   ) -> FederatedTrainer:
    n, m = cfg.num_devices, cfg.examples_per_client
    x, y = synthetic_image_classification(n * m, cfg.image_shape,
                                          cfg.num_classes, noise=0.3,
                                          seed=cfg.seed)
    client_data = [(x[i * m:(i + 1) * m], y[i * m:(i + 1) * m])
                   for i in range(n)]
    params = paper_default_params(
        num_devices=n, sample_count=cfg.sample_count,
        local_epochs=cfg.local_epochs,
        data_sizes=np.full(n, m, np.float32))
    task = MLPTask(input_dim=int(np.prod(cfg.image_shape)),
                   num_classes=cfg.num_classes, hidden=32)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    return FederatedTrainer(
        task, params, LROAController(params, hp),
        ChannelProcess(n, ChannelConfig(seed=cfg.seed)), client_data,
        ClientConfig(local_epochs=cfg.local_epochs,
                     batch_size=cfg.batch_size),
        constant(cfg.lr), test_data=None, seed=cfg.seed,
        use_engine=use_engine)


def _rounds_per_sec(trainer: FederatedTrainer, cfg: EngineBenchConfig
                    ) -> float:
    for t in range(cfg.warmup_rounds):
        trainer.run_round(t)
    t0 = time.perf_counter()
    for t in range(cfg.rounds):
        trainer.run_round(cfg.warmup_rounds + t)
    return cfg.rounds / (time.perf_counter() - t0)


def _data_plane_rounds_per_sec(cfg: EngineBenchConfig, bank_resident: bool
                               ) -> float:
    """Isolate the round data plane: identical selections/coeffs/rngs per
    round, only the client-data path differs — gathered inside the jit
    from the device bank (``bank_resident``) vs host-restacked
    ``[K, B, ...]`` uploads (the PR-1 plane: ``bank.gather_host`` +
    ``round_step_stacked``)."""
    trainer = _build_trainer(cfg, use_engine=True)
    eng, bank = trainer.engine, trainer.bank
    k = cfg.sample_count
    rng = np.random.default_rng(cfg.seed)
    params = trainer.global_params
    rngs = jax.random.split(jax.random.PRNGKey(cfg.seed), k)
    coeffs = np.full(k, 1.0 / k, np.float32)

    def one_round(params):
        selected = rng.integers(0, cfg.num_devices, k)
        if bank_resident:
            params, losses = eng.round_step(params, bank, selected, coeffs,
                                            cfg.lr, rngs)
        else:
            xs, ys, ns, ne = bank.gather_host(selected)
            params, losses = eng.round_step_stacked(params, xs, ys, coeffs,
                                                    cfg.lr, rngs, ns, ne)
        jax.block_until_ready(losses)
        return params

    # These loops time only the data plane (no controller/queue work), so
    # rounds are ~ms each — run 10x the trainer budget to pull the
    # bank-vs-host ratio out of scheduler noise.
    plane_rounds = cfg.rounds * 10
    for _ in range(cfg.warmup_rounds):
        params = one_round(params)
    t0 = time.perf_counter()
    for _ in range(plane_rounds):
        params = one_round(params)
    return plane_rounds / (time.perf_counter() - t0)


def _scan_rounds_per_sec(cfg: EngineBenchConfig) -> float:
    trainer = _build_trainer(cfg, use_engine=True)
    eng, bank = trainer.engine, trainer.bank
    chan = ChannelProcess(cfg.num_devices, ChannelConfig(seed=cfg.seed))
    h_seq = chan.sample_sequence(cfg.rounds)
    lr_seq = np.full(cfg.rounds, cfg.lr, np.float32)
    hp = trainer.controller.hp

    def once(seed):
        p, q, m = eng.run_scan(
            trainer.task.init(jax.random.PRNGKey(seed)), trainer.params,
            bank, h_seq, lr_seq, jax.random.PRNGKey(seed), policy="lroa",
            V=hp.V, lam=hp.lam)
        jax.block_until_ready(jax.tree_util.tree_leaves(p))
        return m

    once(0)                                    # compile
    t0 = time.perf_counter()
    once(1)
    return cfg.rounds / (time.perf_counter() - t0)


def run(cfg: Optional[EngineBenchConfig] = None, smoke: bool = False,
        json_path: Optional[str] = None) -> List[str]:
    if cfg is None:
        cfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    if json_path is None:
        # smoke numbers must not clobber the tracked full-scale record
        json_path = ("BENCH_round_engine.smoke.json" if smoke
                     else "BENCH_round_engine.json")
    seq = _rounds_per_sec(_build_trainer(cfg, use_engine=False), cfg)
    eng = _rounds_per_sec(_build_trainer(cfg, use_engine=True), cfg)
    host = _data_plane_rounds_per_sec(cfg, bank_resident=False)
    bank = _data_plane_rounds_per_sec(cfg, bank_resident=True)
    scan = _scan_rounds_per_sec(cfg)
    result = {
        "config": dataclasses.asdict(cfg),
        "backend": jax.default_backend(),
        "seq_rounds_per_sec": seq,
        "engine_rounds_per_sec": eng,
        "host_restacked_rounds_per_sec": host,
        "bank_resident_rounds_per_sec": bank,
        "scan_rounds_per_sec": scan,
        "speedup_engine_vs_seq": eng / seq,
        "speedup_bank_vs_host_restacked": bank / host,
        "speedup_scan_vs_seq": scan / seq,
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    tag = f"K{cfg.sample_count}N{cfg.num_devices}"
    return [
        csv_row(f"round_engine/sequential/{tag}", 1e6 / seq,
                f"rounds_per_sec={seq:.2f}"),
        csv_row(f"round_engine/fused/{tag}", 1e6 / eng,
                f"rounds_per_sec={eng:.2f};speedup_vs_seq={eng / seq:.2f}"),
        csv_row(f"round_engine/host_restacked/{tag}", 1e6 / host,
                f"rounds_per_sec={host:.2f}"),
        csv_row(f"round_engine/bank_resident/{tag}", 1e6 / bank,
                f"rounds_per_sec={bank:.2f};"
                f"speedup_vs_host_restacked={bank / host:.2f}"),
        csv_row(f"round_engine/scan/{tag}", 1e6 / scan,
                f"rounds_per_sec={scan:.2f};speedup_vs_seq={scan / seq:.2f}"),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
