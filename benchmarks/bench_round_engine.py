"""Round-engine benchmark: simulated FL rounds/sec, seed sequential path vs
the fused round engine vs the multi-round ``lax.scan`` fast path.

The comparison holds everything fixed (task, controller, channel, client
data, K) and only swaps the execution strategy:

* ``sequential`` — the seed semantics: one jitted ``local_update`` dispatch
  per sampled client + list-of-pytrees aggregation (``use_engine=False``);
* ``engine``     — one fused jit per round (vmapped K-client training +
  ravelled eq.-(4) reduction);
* ``scan``       — whole rollout in one jit (decide/sample/train/aggregate/
  queue-update inside ``lax.scan``), no host round-trips between rounds.

Emits ``BENCH_round_engine.json`` with rounds/sec for the trajectory so the
perf numbers are tracked across PRs.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import List, Optional

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.core import LROAController, estimate_hyperparams, paper_default_params
from repro.data import synthetic_image_classification
from repro.fl import ChannelConfig, ChannelProcess, ClientConfig, FederatedTrainer
from repro.models import MLPTask
from repro.optim import constant


@dataclasses.dataclass
class EngineBenchConfig:
    num_devices: int = 20
    sample_count: int = 8          # K=8: the acceptance-criteria operating point
    examples_per_client: int = 64  # equal sizes => one compiled shape per path
    image_shape: tuple = (8, 8, 1)
    num_classes: int = 4
    local_epochs: int = 2
    batch_size: int = 16
    rounds: int = 30               # timed rounds (after warmup)
    warmup_rounds: int = 3
    lr: float = 0.1
    seed: int = 0

    @classmethod
    def smoke(cls) -> "EngineBenchConfig":
        return cls(num_devices=6, sample_count=2, examples_per_client=32,
                   image_shape=(4, 4, 1), num_classes=2, batch_size=8,
                   rounds=3, warmup_rounds=1)


def _build_trainer(cfg: EngineBenchConfig, use_engine: bool
                   ) -> FederatedTrainer:
    n, m = cfg.num_devices, cfg.examples_per_client
    x, y = synthetic_image_classification(n * m, cfg.image_shape,
                                          cfg.num_classes, noise=0.3,
                                          seed=cfg.seed)
    client_data = [(x[i * m:(i + 1) * m], y[i * m:(i + 1) * m])
                   for i in range(n)]
    params = paper_default_params(
        num_devices=n, sample_count=cfg.sample_count,
        local_epochs=cfg.local_epochs,
        data_sizes=np.full(n, m, np.float32))
    task = MLPTask(input_dim=int(np.prod(cfg.image_shape)),
                   num_classes=cfg.num_classes, hidden=32)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=1.0, nu=1e5)
    return FederatedTrainer(
        task, params, LROAController(params, hp),
        ChannelProcess(n, ChannelConfig(seed=cfg.seed)), client_data,
        ClientConfig(local_epochs=cfg.local_epochs,
                     batch_size=cfg.batch_size),
        constant(cfg.lr), test_data=None, seed=cfg.seed,
        use_engine=use_engine)


def _rounds_per_sec(trainer: FederatedTrainer, cfg: EngineBenchConfig
                    ) -> float:
    for t in range(cfg.warmup_rounds):
        trainer.run_round(t)
    t0 = time.perf_counter()
    for t in range(cfg.rounds):
        trainer.run_round(cfg.warmup_rounds + t)
    return cfg.rounds / (time.perf_counter() - t0)


def _scan_rounds_per_sec(cfg: EngineBenchConfig) -> float:
    trainer = _build_trainer(cfg, use_engine=True)
    eng = trainer.engine
    all_x, all_y, all_steps, all_sizes = eng.stack_all_clients(
        trainer.client_data)
    chan = ChannelProcess(cfg.num_devices, ChannelConfig(seed=cfg.seed))
    h_seq = np.stack([chan.sample() for _ in range(cfg.rounds)])
    lr_seq = np.full(cfg.rounds, cfg.lr, np.float32)
    hp = trainer.controller.hp

    def once(seed):
        p, q, m = eng.run_scan(
            trainer.task.init(jax.random.PRNGKey(seed)), trainer.params,
            all_x, all_y, h_seq, lr_seq, jax.random.PRNGKey(seed),
            num_steps=all_steps, num_examples=all_sizes, policy="lroa",
            V=hp.V, lam=hp.lam)
        jax.block_until_ready(jax.tree_util.tree_leaves(p))
        return m

    once(0)                                    # compile
    t0 = time.perf_counter()
    once(1)
    return cfg.rounds / (time.perf_counter() - t0)


def run(cfg: Optional[EngineBenchConfig] = None, smoke: bool = False,
        json_path: Optional[str] = None) -> List[str]:
    if cfg is None:
        cfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    if json_path is None:
        # smoke numbers must not clobber the tracked full-scale record
        json_path = ("BENCH_round_engine.smoke.json" if smoke
                     else "BENCH_round_engine.json")
    seq = _rounds_per_sec(_build_trainer(cfg, use_engine=False), cfg)
    eng = _rounds_per_sec(_build_trainer(cfg, use_engine=True), cfg)
    scan = _scan_rounds_per_sec(cfg)
    result = {
        "config": dataclasses.asdict(cfg),
        "backend": jax.default_backend(),
        "seq_rounds_per_sec": seq,
        "engine_rounds_per_sec": eng,
        "scan_rounds_per_sec": scan,
        "speedup_engine_vs_seq": eng / seq,
        "speedup_scan_vs_seq": scan / seq,
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    tag = f"K{cfg.sample_count}N{cfg.num_devices}"
    return [
        csv_row(f"round_engine/sequential/{tag}", 1e6 / seq,
                f"rounds_per_sec={seq:.2f}"),
        csv_row(f"round_engine/fused/{tag}", 1e6 / eng,
                f"rounds_per_sec={eng:.2f};speedup_vs_seq={eng / seq:.2f}"),
        csv_row(f"round_engine/scan/{tag}", 1e6 / scan,
                f"rounds_per_sec={scan:.2f};speedup_vs_seq={scan / seq:.2f}"),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
