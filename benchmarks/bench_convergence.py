"""Paper Figs. 1 & 2 — accuracy vs wall-clock latency and vs rounds,
LROA against Uni-D / Uni-S / DivFL; headline metric = % latency saved to
reach the accuracy target (paper: up to 50.1%)."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import BenchConfig, csv_row, make_trainer


def time_to_accuracy(result, target: float) -> float:
    for rnd, cum, acc in result.accuracy_curve():
        if acc is not None and acc >= target:
            return cum
    return float("inf")


def run(cfg: BenchConfig, controllers=("lroa", "uni_d", "uni_s", "divfl")
        ) -> List[str]:
    rows = []
    results: Dict[str, object] = {}
    for name in controllers:
        trainer = make_trainer(name, cfg)
        # compile all local-training executables (every bucket / step
        # count) outside the timing; warmup mutates no trainer state, so
        # the measured run is still a clean T-round Algorithm-1 rollout
        trainer.warmup()
        t0 = time.perf_counter()
        results[name] = trainer.run(cfg.rounds)
        sim_rps = cfg.rounds / (time.perf_counter() - t0)
        rows.append(csv_row(f"convergence/{name}/sim_throughput", 0.0,
                            f"sim_rounds_per_sec={sim_rps:.2f}"))
    accs = {n: (r.accuracy_curve()[-1][2] or 0.0)
            for n, r in results.items()}
    # accuracy target: 95% of the worst controller's final accuracy —
    # everything reaches it, so time-to-target is well-defined
    target = 0.95 * min(accs.values())
    t = {n: time_to_accuracy(r, target) for n, r in results.items()}
    for n, r in results.items():
        rows.append(csv_row(
            f"convergence/{n}", 0.0,
            f"final_acc={accs[n]:.3f};total_time_s={r.total_time:.0f};"
            f"time_to_{target:.2f}={t[n]:.0f}"))
    for base in ("uni_d", "uni_s", "divfl"):
        if base not in results:
            continue
        if np.isfinite(t[base]) and np.isfinite(t["lroa"]):
            save = 100.0 * (1.0 - t["lroa"] / t[base])
            rows.append(csv_row(f"latency_saving_vs_{base}", 0.0,
                                f"time_to_target_percent={save:.1f}"))
        # the paper's headline metric: % of total training latency saved
        # for the full round budget (paper: 20.8% vs Uni-D, 50.1% vs Uni-S)
        tot = 100.0 * (1.0 - results["lroa"].total_time /
                       results[base].total_time)
        rows.append(csv_row(f"total_latency_saving_vs_{base}", 0.0,
                            f"percent={tot:.1f}"))
    return rows


if __name__ == "__main__":
    for row in run(BenchConfig()):
        print(row)
