"""Paper Figs. 3-6 — hyper-parameter sweeps, plus the ScenarioArena
sweep-engine section.

* lambda sweep (Fig. 3): total time and final accuracy vs mu.
* V sweep (Fig. 4): time-averaged energy (constraint satisfaction) and
  time-averaged objective vs nu — the Theorem-4 O(C/V) trade-off.
* K sweep (Figs. 5/6): LROA vs Uni-D across sampling counts.
* zoo sweep (Sec. VII trade-off table): every registered controller x
  {stationary, Markov} channel modes as ONE batched ``Arena.run``,
  seed-aggregated trade-off points + single-planned-dispatch guard.
* arena (Sec. VII grid execution): S-batched ``Arena.run`` vs S
  host-looped ``run_scan`` calls on a mixed-controller grid at the
  round-engine operating point (K=8, N=120), recorded in the ``arena``
  section of ``BENCH_round_engine.json``; the ``arena.mixed_k``
  sub-section additionally pits the padded-K single program against the
  per-K-group execution of a mixed-K grid — and against the
  shape-adaptive ``k_mode='auto'`` dispatch planner (cold collapses to
  the padded workflow win, warmed recovers the grouped steady
  throughput) — plus the on-device batched EvalBank evaluation against
  the host-side per-lane eval loop.  The ``arena.skewed`` sub-section
  shows the auto planner's static per-bucket tier subsets recovering
  the tiered bank's scan-skip under vmap batching, and
  ``planner_guard`` asserts the planner's split/no-split contract in
  the parent process (CI's smoke guard).  The ``arena.streaming``
  sub-section measures the streaming chunked pipeline: chunked
  ``Arena.run`` vs the monolithic one-shot scan (bitwise guard on the
  model trajectory + overhead at chunk in {1, T/4, T}) and the
  ``SweepService``'s sustained scenarios/sec over repeated warmed
  submissions vs the one-shot batched floor.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import List, Optional

import numpy as np

from benchmarks.common import BenchConfig, csv_row, run_controller
from repro.core import (LROAController, estimate_hyperparams,
                        paper_default_params)
from repro.core import system_model as sm
from repro.fl import ChannelConfig, ChannelProcess
import jax.numpy as jnp


def lambda_sweep(cfg: BenchConfig, mus=(0.3, 1.0, 10.0, 50.0)) -> List[str]:
    rows = []
    for mu in mus:
        res = run_controller("lroa", cfg, mu=mu)
        acc = res.accuracy_curve()[-1][2]
        rows.append(csv_row(f"lambda_sweep/mu={mu}", 0.0,
                            f"total_time_s={res.total_time:.0f};"
                            f"final_acc={acc:.3f}"))
    return rows


def v_sweep(cfg: BenchConfig, nus=(1e3, 1e4, 1e5, 1e6),
            rounds: int = 600) -> List[str]:
    """Control-only rollout (no model training needed): tracks the
    time-averaged energy vs budget and the time-averaged objective."""
    rows = []
    n = cfg.num_devices
    rng = np.random.default_rng(cfg.seed)
    sizes = rng.integers(200, 600, n).astype(np.float32)
    params = paper_default_params(num_devices=n, data_sizes=sizes,
                                  sample_count=cfg.sample_count)
    for nu in nus:
        hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=cfg.mu,
                                  nu=nu)
        ctrl = LROAController(params, hp)
        chan = ChannelProcess(n, ChannelConfig(seed=cfg.seed))
        tot_e = np.zeros(n)
        tot_obj = 0.0
        for _ in range(rounds):
            h = jnp.asarray(chan.sample())
            dec = ctrl.decide(h)
            tot_e += np.asarray(sm.expected_energy(params, h, dec.p, dec.f,
                                                   dec.q))
            t = sm.round_time(params, h, dec.p, dec.f)
            w = params.data_weights
            tot_obj += float(jnp.sum(dec.q * t +
                                     hp.lam * jnp.square(w) / dec.q))
            ctrl.step_queues(h, dec)
        rows.append(csv_row(
            f"v_sweep/nu={nu:.0e}", 0.0,
            f"avg_energy_J={tot_e.mean() / rounds:.2f};"
            f"budget_J={float(np.asarray(params.energy_budget).mean()):.1f};"
            f"avg_objective={tot_obj / rounds:.1f};"
            f"queue_mean={float(np.asarray(ctrl.queues).mean()):.0f}"))
    return rows


def k_sweep(cfg: BenchConfig, ks=(2, 4, 6)) -> List[str]:
    rows = []
    for k in ks:
        for name in ("lroa", "uni_d"):
            res = run_controller(name, cfg, sample_count=k)
            acc = res.accuracy_curve()[-1][2]
            rows.append(csv_row(f"k_sweep/K={k}/{name}", 0.0,
                                f"total_time_s={res.total_time:.0f};"
                                f"final_acc={acc:.3f}"))
    return rows


def heterogeneity_sweep(cfg: BenchConfig, spreads=(1.0, 2.0, 4.0),
                        rounds: int = 150) -> List[str]:
    """System-heterogeneity ablation (the paper's core motivation): as the
    CPU-speed spread grows, adaptive sampling should increasingly out-run
    uniform sampling because stragglers are demoted. Control-only rollout —
    realised round latency = max over the sampled set (eq. 10)."""
    import dataclasses as dc

    from repro.core import (LROAController, UniformStaticController,
                            estimate_hyperparams, paper_default_params)
    from repro.core.controller import realized_round_time
    from repro.fl import ChannelConfig, ChannelProcess, HeterogeneityConfig
    from repro.fl import heterogeneous_params, sample_clients

    rows = []
    n = cfg.num_devices
    rng0 = np.random.default_rng(cfg.seed)
    sizes = rng0.integers(200, 600, n).astype(np.float32)
    for spread in spreads:
        base = paper_default_params(num_devices=n, data_sizes=sizes,
                                    sample_count=cfg.sample_count)
        params = heterogeneous_params(
            base, HeterogeneityConfig(cpu_speed_spread=spread,
                                      cycles_spread=spread, seed=7))
        hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=cfg.mu,
                                  nu=cfg.nu)
        totals = {}
        for name, ctrl_cls in (("lroa", LROAController),
                               ("uni_s", UniformStaticController)):
            ctrl = ctrl_cls(params, hp)
            chan = ChannelProcess(n, ChannelConfig(seed=cfg.seed))
            rng = np.random.default_rng(cfg.seed + 1)
            total = 0.0
            for _ in range(rounds):
                h = jnp.asarray(chan.sample())
                dec = ctrl.decide(h)
                sel = sample_clients(rng, np.asarray(dec.q),
                                     params.sample_count)
                total += realized_round_time(params, h, dec, sel)
                ctrl.step_queues(h, dec)
            totals[name] = total
        save = 100.0 * (1 - totals["lroa"] / totals["uni_s"])
        rows.append(csv_row(
            f"heterogeneity_sweep/spread={spread}", 0.0,
            f"lroa_s={totals['lroa']:.0f};uni_s_s={totals['uni_s']:.0f};"
            f"latency_saving_pct={save:.1f}"))
    return rows


def zoo_sweep(cfg: BenchConfig, rounds: int = 20, seeds: int = 2
              ) -> List[str]:
    """Sec.-VII-style trade-off table: the FULL controller zoo (all
    registered decide rules, in-trace DivFL included) crossed with
    {stationary, Markov/Gilbert-Elliott} channel modes, executed as ONE
    batched ``Arena.run`` under ``k_mode='auto'`` — the headline grid of
    the controller-zoo milestone.  Emits one row per
    (controller, channel-mode) trade-off point (seed-aggregated latency /
    loss / energy) plus a dispatch row asserting the whole mixed grid ran
    as a single planned bucket."""
    import jax

    from benchmarks.common import build_testbed
    from repro.core import POLICIES, estimate_hyperparams
    from repro.fl import ClientConfig, RoundEngine
    from repro.optim import paper_step_decay
    from repro.sim import Arena, ScenarioGrid

    params, task, client_data, _ = build_testbed(cfg)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=cfg.mu,
                              nu=cfg.nu)
    engine = RoundEngine(task, ClientConfig(local_epochs=cfg.local_epochs,
                                            batch_size=cfg.batch_size))
    bank = engine.make_bank(client_data)
    grid = ScenarioGrid.product(
        controllers=tuple(POLICIES), seeds=tuple(range(seeds)),
        V=(hp.V,), lam=(hp.lam,), sample_count=(cfg.sample_count,),
        chan_mode=("iid", "markov"), p_gb=(0.15,), p_bg=(0.4,),
        num_devices=cfg.num_devices)
    arena = Arena(engine, k_mode="auto")
    sched = paper_step_decay(cfg.lr, cfg.rounds)
    lr_seq = np.asarray([float(sched(t)) for t in range(rounds)],
                        np.float32)
    t0 = time.perf_counter()
    report = arena.run(task.init(jax.random.PRNGKey(cfg.seed + 1)),
                       params, bank, grid, rounds, lr_seq)
    wall = time.perf_counter() - t0
    rows = []
    for pt in report.tradeoff_table():
        rows.append(csv_row(
            f"zoo_sweep/{pt['controller']}/{pt['chan_mode']}", 0.0,
            f"total_time_s={pt['total_latency']:.0f};"
            f"final_loss={pt['final_loss']:.3f};"
            f"mean_energy_J={pt['mean_energy']:.2f};"
            f"seeds={pt['num_seeds']}"))
    acct = report.dispatch_accounting()
    lanes = len(grid)
    rows.append(csv_row(
        f"zoo_sweep/dispatch/S{lanes}", 1e6 * wall / max(lanes, 1),
        f"buckets={acct['buckets']};dispatches={acct['dispatches']};"
        f"executables_built={acct['executables_built']};"
        f"controllers={len(POLICIES)}"))
    return rows


_ARENA_SHARDS = 2        # forced host devices for the sharded row
_ARENA_SENTINEL = "ARENA-SWEEP-JSON:"


def _arena_measure(s_values, rounds: int, smoke: bool) -> dict:
    """Runs INSIDE the arena subprocess (forced multi-device CPU): for
    each S, time the full grid-execution WORKFLOW of a mixed-controller
    grid three ways — S host-looped iterations (per-rollout channel
    generation + ``run_scan``, the pre-arena workflow), the vmapped
    single-device ``Arena.run``, and the scenario-sharded
    ``Arena(mesh=..., batch='map')`` run (whole rollouts per local
    device, per-lane solver trip counts, the arena's strong-scaling
    mode).  Channel generation is counted on both sides: the host loop
    draws each rollout's sequence separately (``ChannelProcess.
    sample_jax`` semantics) while the arena pregenerates the whole
    ``[S, T, N]`` tensor in one vmapped jit.  Best-of-3 timings."""
    import jax
    from benchmarks.bench_round_engine import (EngineBenchConfig,
                                               _build_trainer)
    from repro.core.policy import POLICIES
    from repro.fl.environment import sample_gains
    from repro.launch.mesh import make_fl_mesh
    from repro.sim import Arena, ScenarioGrid, scenario_keys

    ecfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    trainer = _build_trainer(ecfg, use_engine=True)
    eng, bank, sp = trainer.engine, trainer.bank, trainer.params
    # the host loop replays run_scan from the SAME params0 every rollout;
    # with donation on (GPU/TPU) the first call would delete its buffer —
    # disable donation before any scan executable is built (the arena
    # never donates, so this changes nothing on that side)
    eng.donate = False
    hp = trainer.controller.hp
    params0 = trainer.task.init(jax.random.PRNGKey(0))
    lr_seq = np.full(rounds, ecfg.lr, np.float32)
    n = ecfg.num_devices
    shards = len(jax.devices())
    stats = {"rounds": rounds, "K": ecfg.sample_count, "N": n,
             "shards": shards, "controllers": list(POLICIES),
             "sharded_batch_mode": "map"}
    arena = Arena(eng)
    arena_sharded = Arena(eng, mesh=make_fl_mesh(), batch="map")
    gen_one = jax.jit(sample_gains, static_argnums=(1, 2))
    for s_count in s_values:
        grid = ScenarioGrid.create(
            controllers=[POLICIES[i % len(POLICIES)]
                         for i in range(s_count)],
            seeds=np.arange(s_count), V=hp.V, lam=hp.lam,
            sample_count=ecfg.sample_count)
        chan_keys, roll_keys = scenario_keys(grid)
        names = grid.controller_names()

        def host_looped():
            for s in range(s_count):
                h_s = gen_one(chan_keys[s], rounds, n,
                              float(grid.mean_gain[s]),
                              float(grid.min_gain[s]),
                              float(grid.max_gain[s]))
                # run_scan syncs per rollout (metrics come back as numpy)
                eng.run_scan(params0, grid.scenario_system_params(sp, s),
                             bank, h_s, lr_seq, roll_keys[s],
                             policy=names[s], V=float(grid.V[s]),
                             lam=float(grid.lam[s]))

        def batched(a):
            rep = a.run(params0, sp, bank, grid, rounds, lr_seq)
            jax.block_until_ready(jax.tree_util.tree_leaves(rep.params))

        def timed(fn):
            fn()                                       # compile / warm
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                fn()
                best = min(best, time.perf_counter() - t0)
            return s_count * rounds / best

        host_rps = timed(host_looped)
        vmap_rps = timed(lambda: batched(arena))
        shard_rps = timed(lambda: batched(arena_sharded))
        stats[f"S{s_count}"] = {
            "host_looped_rounds_per_sec": host_rps,
            "batched_rounds_per_sec": vmap_rps,
            "batched_sharded_rounds_per_sec": shard_rps,
            "speedup_batched_vs_host_looped": vmap_rps / host_rps,
            "speedup_sharded_vs_host_looped": shard_rps / host_rps,
        }
    stats["mixed_k"] = _mixed_k_measure(trainer, rounds, smoke)
    stats["skewed"] = _skewed_arena_measure(trainer, rounds, smoke)
    stats["streaming"] = _streaming_measure(trainer, smoke)
    return stats


def _streaming_measure(trainer, smoke: bool) -> dict:
    """Streaming chunked pipeline vs the one-shot batched scan (runs
    INSIDE the arena subprocess), at the round-engine operating point
    (S=16, K=8, N=120 full scale).  Three measurements:

    * the one-shot floor — warmed monolithic ``Arena.run`` best-of-3
      scenarios/sec, params blocked per run (the pre-streaming
      workflow);
    * chunked-vs-monolithic overhead — steady chunked throughput at
      chunk in {1, ceil(T/4), T} as a ratio of the one-shot time, with
      the model trajectory (params + loss/selected/wall_time) asserted
      BITWISE equal to the monolithic run at every chunking first (an
      assertion failure fails the bench — and CI's smoke guard);
    * sustained service throughput — a warmed ``SweepService`` fed
      repeated same-shape submissions, drained in one
      ``run_pending`` (per-batch host reduction overlaps the next
      batch's device chunks; only the LAST batch's params block), at the
      half-rollout and whole-rollout chunkings; the headline
      ``streamed_scenarios_per_sec`` is the better of the two and must
      not fall below the one-shot floor."""
    import jax
    from benchmarks.bench_round_engine import EngineBenchConfig
    from repro.core.policy import POLICIES
    from repro.sim import Arena, ScenarioGrid, SweepService

    ecfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    eng, bank, sp = trainer.engine, trainer.bank, trainer.params
    hp = trainer.controller.hp
    params0 = trainer.task.init(jax.random.PRNGKey(0))
    s_count = 4 if smoke else 16
    rounds = 4 if smoke else 8
    n = ecfg.num_devices
    lr_seq = np.full(rounds, ecfg.lr, np.float32)
    grid = ScenarioGrid.create(
        controllers=[POLICIES[i % len(POLICIES)] for i in range(s_count)],
        seeds=np.arange(s_count), V=hp.V, lam=hp.lam,
        sample_count=ecfg.sample_count)
    st = {"S": s_count, "K": ecfg.sample_count, "N": n, "rounds": rounds}
    arena = Arena(eng)
    # prime the channel cache so every run (mono, chunked, service) reads
    # the identical [S, T, N] device tensor and transfers nothing
    jax.block_until_ready(arena.sample_channels(grid, rounds, n))

    def mono_run(**kw):
        rep = arena.run(params0, sp, bank, grid, rounds, lr_seq, **kw)
        jax.block_until_ready(jax.tree_util.tree_leaves(rep.params))
        return rep

    def best_seconds(fn, reps=3):
        fn()                                   # compile / warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    mono_s = best_seconds(mono_run)
    st["oneshot_scenarios_per_sec"] = s_count / mono_s

    rep_mono = mono_run()
    chunks = sorted({1, max(1, -(-rounds // 4)), rounds})
    st["chunk_overhead_vs_oneshot"] = {}
    for chunk in chunks:
        rep_c = mono_run(chunk_size=chunk)     # compile + bitwise guard
        # Segments of length >= 2 keep the scan's fused While body and are
        # bitwise-identical to the one-shot program; a length-1 segment
        # (chunk_size=1, or a trailing remainder of 1) gets its
        # trip-count-1 loop unrolled by XLA, which may re-fuse large-shape
        # reductions — hold those chunkings to f32 resolution instead.
        unrolled = chunk == 1 or rounds % chunk == 1
        def _guard(a, b):
            a, b = np.asarray(a), np.asarray(b)
            if unrolled:
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=0)
            else:
                np.testing.assert_array_equal(a, b)
        for name in ("loss", "selected", "wall_time"):
            _guard(rep_mono.metrics[name], rep_c.metrics[name])
        for a, b in zip(jax.tree_util.tree_leaves(rep_mono.params),
                        jax.tree_util.tree_leaves(rep_c.params)):
            _guard(a, b)
        sec = best_seconds(lambda: mono_run(chunk_size=chunk))
        st["chunk_overhead_vs_oneshot"][str(chunk)] = sec / mono_s
    st["chunked_bitwise_equal"] = True

    submissions = 4 if smoke else 8
    st["submissions_per_drain"] = submissions
    st["streamed_by_chunk"] = {}
    for chunk in sorted({max(1, -(-rounds // 2)), rounds}):
        svc = SweepService(arena, params0, sp, bank, chunk_size=chunk,
                           max_lanes=s_count)
        svc.warmup(grid, rounds, lr_seq)

        def stream():
            tickets = [svc.submit(grid, rounds, lr_seq)
                       for _ in range(submissions)]
            svc.run_pending()
            for t in tickets:
                svc.result(t)
        sec = best_seconds(stream)
        st["streamed_by_chunk"][str(chunk)] = (
            submissions * s_count / sec)
    stream_chunk, stream_rps = max(st["streamed_by_chunk"].items(),
                                   key=lambda kv: kv[1])
    st["stream_chunk"] = int(stream_chunk)
    st["streamed_scenarios_per_sec"] = stream_rps
    st["speedup_streamed_vs_oneshot"] = (
        stream_rps / st["oneshot_scenarios_per_sec"])
    return st


def _mixed_k_measure(trainer, rounds: int, smoke: bool) -> dict:
    """Mixed-K grid execution + evaluation (runs INSIDE the arena
    subprocess): a controllers x seeds x K grid executed per-K-group
    (``k_mode='group'``, one compile + one dispatch chain per distinct
    K) vs as ONE padded-K program (``k_mode='pad'``), and the S-lane
    evaluation done as a host loop (jitted per-lane ``task.metrics`` over
    sliced params — the pre-EvalBank workflow) vs one vmapped on-device
    batched pass.

    Records both the steady-state throughput (executables cached) and
    the WORKFLOW throughput of a fresh grid execution including
    compilation — the operating point the fusion exists for: an
    iterate-on-the-grid sweep pays the compile chain on every new shape,
    and the padded program compiles (and dispatches) once instead of
    once per K."""
    import jax
    import jax.numpy as jnp
    from benchmarks.bench_round_engine import EngineBenchConfig
    from repro.data import synthetic_image_classification
    from repro.sim import Arena, EvalBank, ScenarioGrid

    ecfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    eng, bank, sp = trainer.engine, trainer.bank, trainer.params
    hp = trainer.controller.hp
    params0 = trainer.task.init(jax.random.PRNGKey(0))
    lr_seq = np.full(rounds, ecfg.lr, np.float32)
    n = ecfg.num_devices
    ks = (2, 4) if smoke else (4, 8, 16)
    grid = ScenarioGrid.product(
        controllers=("lroa", "uni_d"), seeds=(0, 1), V=(hp.V,),
        lam=(hp.lam,), sample_count=ks, num_devices=n)
    s_count = len(grid)
    mk = {"K_values": [int(k) for k in ks], "S": s_count,
          "rounds": rounds, "controllers": ["lroa", "uni_d"],
          "num_seeds": 2}
    probe = Arena(eng)
    h_all = probe.sample_channels(grid, rounds, n)
    jax.block_until_ready(h_all)

    def run(a, **kw):
        rep = a.run(params0, sp, bank, grid, rounds, lr_seq, h_all=h_all,
                    **kw)
        jax.block_until_ready(jax.tree_util.tree_leaves(rep.params))
        return rep

    reports = {}
    for mode in ("group", "pad"):
        a = Arena(eng, k_mode=mode)
        t0 = time.perf_counter()
        cold_rep = run(a)                  # cold: compiles + executes
        cold = time.perf_counter() - t0
        best = float("inf")
        for _ in range(3):                 # steady: executables cached
            t0 = time.perf_counter()
            rep = run(a)
            best = min(best, time.perf_counter() - t0)
        tag = "grouped" if mode == "group" else "padded"
        # executables the cold run actually compiled for THIS grid (the
        # fusion claim), not the arena-lifetime cache size
        mk[f"{tag}_executables"] = cold_rep.meta["executables_built"]
        mk[f"{tag}_dispatches"] = rep.meta["dispatches"]
        mk[f"{tag}_cold_seconds"] = cold
        mk[f"{tag}_workflow_rounds_per_sec"] = s_count * rounds / cold
        mk[f"{tag}_rounds_per_sec"] = s_count * rounds / best
        reports[mode] = rep
    mk["speedup_padded_vs_grouped_workflow"] = (
        mk["grouped_cold_seconds"] / mk["padded_cold_seconds"])
    mk["speedup_padded_vs_grouped_steady"] = (
        mk["padded_rounds_per_sec"] / mk["grouped_rounds_per_sec"])

    # -- shape-adaptive dispatch (k_mode='auto') ----------------------------
    # cold: a fresh auto arena plans at the one-run horizon, which
    # collapses to the single padded executable — it must keep the padded
    # workflow win; steady: an auto arena warmed through Arena.warmup
    # compiles the runs=inf signature split, and the cache-aware replan
    # snaps every later run to those buckets — it must recover (or beat)
    # the grouped steady throughput.  Both taxes die in one mode.
    a_cold = Arena(eng, k_mode="auto")
    t0 = time.perf_counter()
    cold_rep = run(a_cold)
    cold = time.perf_counter() - t0
    mk["auto_executables"] = cold_rep.meta["executables_built"]
    mk["auto_cold_dispatches"] = cold_rep.meta["dispatches"]
    mk["auto_cold_seconds"] = cold
    mk["auto_workflow_rounds_per_sec"] = s_count * rounds / cold
    a_steady = Arena(eng, k_mode="auto")
    warm = a_steady.warmup(params0, sp, bank, grid, rounds, lr_seq,
                           h_all=h_all)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        rep = run(a_steady)
        best = min(best, time.perf_counter() - t0)
    rep.dispatch_accounting()          # additive per-bucket counters
    # auto lanes are bitwise-identical to the padded program (the
    # prefix-stable padded-K invariant) — assert it where it's measured
    np.testing.assert_array_equal(rep.metrics["loss"],
                                  reports["pad"].metrics["loss"])
    mk["auto_rounds_per_sec"] = s_count * rounds / best
    mk["auto_steady_dispatches"] = rep.meta["dispatches"]
    mk["auto_steady_executables"] = warm["executables_built"]
    mk["auto_warmup_aot"] = warm["aot"]
    mk["auto_steady_plan"] = rep.meta["plan"]
    mk["speedup_auto_vs_grouped_workflow"] = (
        mk["grouped_cold_seconds"] / mk["auto_cold_seconds"])
    mk["speedup_auto_vs_grouped_steady"] = (
        mk["auto_rounds_per_sec"] / mk["grouped_rounds_per_sec"])

    # -- S-lane evaluation: host loop vs on-device batched ------------------
    test_n = 64 if smoke else 1024
    xte, yte = synthetic_image_classification(
        test_n, ecfg.image_shape, ecfg.num_classes, noise=0.3, seed=123)
    ebank = EvalBank(trainer.task, xte, yte)
    rep = reports["pad"]
    xte_d, yte_d = jnp.asarray(xte), jnp.asarray(yte)
    host_metrics = jax.jit(trainer.task.metrics)

    def eval_host_loop():
        for s in range(s_count):
            out = host_metrics(rep.scenario_params(s),
                               {"x": xte_d, "y": yte_d})
            jax.block_until_ready(out["accuracy"])

    def eval_batched():
        ebank.evaluate_stacked(rep.params)

    def best_seconds(fn):
        fn()                               # compile / warm
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    mk["eval_test_examples"] = test_n
    mk["eval_host_loop_seconds"] = best_seconds(eval_host_loop)
    mk["eval_batched_seconds"] = best_seconds(eval_batched)
    mk["speedup_eval_batched_vs_host_loop"] = (
        mk["eval_host_loop_seconds"] / mk["eval_batched_seconds"])
    return mk


def _skewed_arena_measure(trainer, rounds: int, smoke: bool) -> dict:
    """Tier-subset scan-skip recovered under batching (runs INSIDE the
    arena subprocess): on a Dirichlet-skewed tiered bank the per-round
    tier bodies are selection-conditioned ``lax.cond``s, so a SINGLE
    rollout's scan skips the tiers a round misses — but vmapping S lanes
    lowers cond to select and every lane pays every tier body on every
    round (``k_mode='pad'`` ships the full ladder in its one
    executable: the tier-select tax).  ``k_mode='auto'`` probes each
    lane's realised tier footprint on the control plane, buckets lanes
    by it, and compiles each bucket with ONLY its hit tiers — the
    batched-execution form of the skip.  Pad-warmed vs auto-warmed
    steady throughput on the same uniform-K grid."""
    import jax
    from benchmarks.bench_round_engine import (EngineBenchConfig,
                                               _skewed_client_data)
    from repro.core import paper_default_params
    from repro.core.policy import POLICIES
    from repro.sim import Arena, ScenarioGrid

    ecfg = EngineBenchConfig.smoke() if smoke else EngineBenchConfig()
    eng = trainer.engine
    sizes, cd = _skewed_client_data(ecfg)
    bank = eng.make_bank(cd, tiered="tiered")
    sp = paper_default_params(
        num_devices=ecfg.num_devices, sample_count=ecfg.sample_count,
        local_epochs=ecfg.local_epochs,
        data_sizes=sizes.astype(np.float32))
    hp = trainer.controller.hp
    s_count = 4 if smoke else 8
    k = 2 if smoke else 4          # few draws/lane => sparse footprints
    grid = ScenarioGrid.create(
        controllers=[POLICIES[i % len(POLICIES)] for i in range(s_count)],
        seeds=np.arange(s_count), V=hp.V, lam=hp.lam, sample_count=k)
    params0 = trainer.task.init(jax.random.PRNGKey(0))
    lr_seq = np.full(rounds, ecfg.lr, np.float32)
    h_all = Arena(eng).sample_channels(grid, rounds, ecfg.num_devices)
    jax.block_until_ready(h_all)
    stats = {"S": s_count, "K": k, "rounds": rounds,
             "num_tiers": int(bank.num_tiers),
             "tier_buckets": [int(b) for b in bank.tier_buckets]}

    def steady(a):
        a.warmup(params0, sp, bank, grid, rounds, lr_seq, h_all=h_all)
        best, rep = float("inf"), None
        for _ in range(3):
            t0 = time.perf_counter()
            rep = a.run(params0, sp, bank, grid, rounds, lr_seq,
                        h_all=h_all)
            jax.block_until_ready(jax.tree_util.tree_leaves(rep.params))
            best = min(best, time.perf_counter() - t0)
        return s_count * rounds / best, rep

    pad_rps, _ = steady(Arena(eng, k_mode="pad"))
    auto_rps, auto_rep = steady(Arena(eng, k_mode="auto"))
    stats["padded_rounds_per_sec"] = pad_rps
    stats["auto_rounds_per_sec"] = auto_rps
    stats["auto_executables"] = len(auto_rep.meta["plan"])
    stats["auto_plan"] = auto_rep.meta["plan"]
    stats["tiers_per_bucket"] = [
        bank.num_tiers if b["tiers"] is None else len(b["tiers"])
        for b in auto_rep.meta["plan"]]
    stats["speedup_auto_vs_padded_steady"] = auto_rps / pad_rps
    return stats


def planner_guard() -> List[str]:
    """CI guard for the ``k_mode='auto'`` planner (pure host logic, no
    rollouts — runs in the arena_sweep PARENT process): the steady-state
    plan must SPLIT a synthetic K-skewed grid (the padded-slot waste is
    real work), a uniform grid must stay ONE bucket at every horizon (no
    spurious executables), and the cold one-run horizon must collapse
    the skewed grid back to the single padded program (the workflow
    win).  Assertion failures fail the bench — and CI's smoke run."""
    import math

    from repro.sim import plan_dispatch

    work = {0: 128.0}
    skewed_ks = np.array([2] * 10 + [16, 16])
    skew = plan_dispatch(skewed_ks, rounds=5, tier_work=work,
                         runs=math.inf)
    assert skew.num_buckets > 1, (
        f"planner failed to split the K-skewed grid: {skew.describe()}")
    uni = plan_dispatch(np.array([8] * 12), rounds=5, tier_work=work,
                        runs=math.inf)
    assert uni.num_buckets == 1, (
        f"planner split a uniform grid: {uni.describe()}")
    cold = plan_dispatch(skewed_ks, rounds=5, tier_work=work, runs=1.0)
    assert cold.num_buckets == 1, (
        f"cold horizon failed to collapse to padded: {cold.describe()}")
    return [csv_row(
        "arena_sweep/planner_guard", 0.0,
        f"skewed_steady_buckets={skew.num_buckets};"
        f"uniform_steady_buckets={uni.num_buckets};"
        f"skewed_cold_buckets={cold.num_buckets}")]


def arena_sweep(cfg: BenchConfig, s_values=(4, 16), rounds: int = 5,
                smoke: bool = False, json_path: Optional[str] = None
                ) -> List[str]:
    """ScenarioArena throughput: a mixed LROA/Uni-D/Uni-S grid of S
    rollouts executed as S host-looped ``run_scan`` calls vs ONE batched
    ``Arena.run`` — unsharded (vmap only) and scenario-sharded over
    ``_ARENA_SHARDS`` forced host devices (whole rollouts per device, no
    cross-device collectives; the sharded row is the arena's headline
    number — see the scaling note below for what it can reach per host).

    Pins the round-engine operating point (K=8, N=120 full scale; tiny
    shapes under ``smoke``) at pilot-rollout length (``rounds=5`` — the
    section measures GRID-EXECUTION cost; long-rollout throughput is the
    round_engine scan row's job), and merges an ``arena`` section into
    ``BENCH_round_engine.json`` (the tracked record of
    execution-strategy throughput; ``bench_round_engine`` preserves the
    section when it rewrites the file).  The ``arena.mixed_k``
    sub-section (``_mixed_k_measure``) compares a mixed-K grid run
    per-K-group vs as ONE padded-K program vs the cost-model
    ``k_mode='auto'`` dispatch (cold and ``Arena.warmup``-primed steady
    rows) — workflow (compile included) and steady-state throughput,
    executable/dispatch counts — plus the S-lane evaluation as a host
    loop vs the EvalBank's batched on-device pass; ``arena.skewed``
    (``_skewed_arena_measure``) adds the tiered-bank row where auto's
    per-bucket tier subsets recover the scan-skip under batching;
    ``arena.streaming`` (``_streaming_measure``) adds the streaming
    chunked pipeline — chunked-vs-monolithic bitwise guard + overhead,
    and the ``SweepService``'s sustained scenarios/sec against the
    one-shot batched floor.
    Measurement runs in a subprocess because the forced host-device
    count must be set before jax initialises; :func:`planner_guard`
    asserts the planner's split/no-split contract host-side.

    Scaling note: the sharded row's ceiling is the local device count.
    On the 2-core recording host the fused per-rollout scan baseline
    already keeps both cores busy, so the S=16 sharded row lands around
    1.5-2x (the tracked record: ~1.99x at S=16); the scenario axis is
    embarrassingly parallel, so clearing 2x with margin needs more local
    devices than the baseline can itself exploit (any accelerator host,
    or a >= 4-core CPU).
    """
    import os
    import subprocess
    import sys
    from pathlib import Path

    if json_path is None:
        json_path = ("BENCH_round_engine.smoke.json" if smoke
                     else "BENCH_round_engine.json")
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    # single-threaded eigen: the sharded program already keeps every core
    # busy with one shard each; per-op multi-threading on top only adds
    # pool contention (it speeds the host loop too — the flag applies to
    # both sides of the comparison)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{_ARENA_SHARDS}"
                        " --xla_cpu_multi_thread_eigen=false")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root)] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    spec = json.dumps({"s_values": list(s_values), "rounds": rounds,
                       "smoke": smoke})
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sweeps",
         "--arena-subprocess", spec],
        env=env, capture_output=True, text=True, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(f"arena subprocess failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    payload = [line for line in out.stdout.splitlines()
               if line.startswith(_ARENA_SENTINEL)]
    stats = json.loads(payload[-1][len(_ARENA_SENTINEL):])
    rows = []
    for s_count in s_values:
        sec = stats[f"S{s_count}"]
        tag = f"S{s_count}K{stats['K']}N{stats['N']}"
        rows += [
            csv_row(f"arena_sweep/host_looped/{tag}",
                    1e6 / sec["host_looped_rounds_per_sec"],
                    f"rounds_per_sec="
                    f"{sec['host_looped_rounds_per_sec']:.2f}"),
            csv_row(f"arena_sweep/batched/{tag}",
                    1e6 / sec["batched_rounds_per_sec"],
                    f"rounds_per_sec={sec['batched_rounds_per_sec']:.2f};"
                    f"speedup_vs_host_looped="
                    f"{sec['speedup_batched_vs_host_looped']:.2f}"),
            csv_row(f"arena_sweep/batched_sharded/{tag}",
                    1e6 / sec["batched_sharded_rounds_per_sec"],
                    f"rounds_per_sec="
                    f"{sec['batched_sharded_rounds_per_sec']:.2f};"
                    f"shards={stats['shards']};"
                    f"speedup_vs_host_looped="
                    f"{sec['speedup_sharded_vs_host_looped']:.2f}"),
        ]
    mk = stats["mixed_k"]
    mtag = (f"S{mk['S']}K" + "+".join(str(k) for k in mk["K_values"]) +
            f"N{stats['N']}")
    rows += [
        csv_row(f"arena_sweep/mixed_k_grouped/{mtag}",
                1e6 / mk["grouped_workflow_rounds_per_sec"],
                f"workflow_rounds_per_sec="
                f"{mk['grouped_workflow_rounds_per_sec']:.2f};"
                f"steady_rounds_per_sec={mk['grouped_rounds_per_sec']:.2f};"
                f"executables={mk['grouped_executables']};"
                f"dispatches={mk['grouped_dispatches']}"),
        csv_row(f"arena_sweep/mixed_k_padded/{mtag}",
                1e6 / mk["padded_workflow_rounds_per_sec"],
                f"workflow_rounds_per_sec="
                f"{mk['padded_workflow_rounds_per_sec']:.2f};"
                f"steady_rounds_per_sec={mk['padded_rounds_per_sec']:.2f};"
                f"executables={mk['padded_executables']};"
                f"dispatches={mk['padded_dispatches']};"
                f"speedup_workflow_vs_grouped="
                f"{mk['speedup_padded_vs_grouped_workflow']:.2f}"),
        csv_row(f"arena_sweep/mixed_k_auto/{mtag}",
                1e6 / mk["auto_workflow_rounds_per_sec"],
                f"workflow_rounds_per_sec="
                f"{mk['auto_workflow_rounds_per_sec']:.2f};"
                f"steady_rounds_per_sec={mk['auto_rounds_per_sec']:.2f};"
                f"cold_executables={mk['auto_executables']};"
                f"steady_dispatches={mk['auto_steady_dispatches']};"
                f"speedup_workflow_vs_grouped="
                f"{mk['speedup_auto_vs_grouped_workflow']:.2f};"
                f"speedup_steady_vs_grouped="
                f"{mk['speedup_auto_vs_grouped_steady']:.2f}"),
        csv_row(f"arena_sweep/mixed_k_eval_host_loop/{mtag}",
                1e6 * mk["eval_host_loop_seconds"],
                f"seconds={mk['eval_host_loop_seconds']:.4f}"),
        csv_row(f"arena_sweep/mixed_k_eval_batched/{mtag}",
                1e6 * mk["eval_batched_seconds"],
                f"seconds={mk['eval_batched_seconds']:.4f};"
                f"speedup_vs_host_loop="
                f"{mk['speedup_eval_batched_vs_host_loop']:.2f}"),
    ]
    sk = stats["skewed"]
    stag = f"S{sk['S']}K{sk['K']}N{stats['N']}tiers{sk['num_tiers']}"
    rows += [
        csv_row(f"arena_sweep/skewed_padded/{stag}",
                1e6 / sk["padded_rounds_per_sec"],
                f"rounds_per_sec={sk['padded_rounds_per_sec']:.2f};"
                f"tiers_compiled={sk['num_tiers']}"),
        csv_row(f"arena_sweep/skewed_auto/{stag}",
                1e6 / sk["auto_rounds_per_sec"],
                f"rounds_per_sec={sk['auto_rounds_per_sec']:.2f};"
                f"executables={sk['auto_executables']};"
                "tiers_per_bucket="
                + "+".join(str(t) for t in sk["tiers_per_bucket"]) + ";"
                f"speedup_vs_padded="
                f"{sk['speedup_auto_vs_padded_steady']:.2f}"),
    ]
    sr = stats["streaming"]
    ttag = f"S{sr['S']}K{sr['K']}N{sr['N']}T{sr['rounds']}"
    rows += [
        csv_row(f"arena_sweep/streaming_oneshot/{ttag}",
                1e6 / sr["oneshot_scenarios_per_sec"],
                f"scenarios_per_sec="
                f"{sr['oneshot_scenarios_per_sec']:.2f}"),
        csv_row(f"arena_sweep/streaming_sustained/{ttag}",
                1e6 / sr["streamed_scenarios_per_sec"],
                f"scenarios_per_sec="
                f"{sr['streamed_scenarios_per_sec']:.2f};"
                f"chunk={sr['stream_chunk']};"
                f"speedup_vs_oneshot="
                f"{sr['speedup_streamed_vs_oneshot']:.2f};"
                f"bitwise_guard={sr['chunked_bitwise_equal']}"),
        csv_row(f"arena_sweep/streaming_chunk_overhead/{ttag}", 0.0,
                "chunked_over_oneshot=" + "+".join(
                    f"{c}:{v:.2f}" for c, v in
                    sr["chunk_overhead_vs_oneshot"].items())),
    ]
    rows += planner_guard()
    try:
        with open(json_path) as f:
            record = json.load(f)
    except (OSError, ValueError):
        record = {}
    record["arena"] = stats
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 2 and sys.argv[1] == "--arena-subprocess":
        # worker mode for arena_sweep: measure under the forced
        # host-device count the parent set in XLA_FLAGS
        spec = json.loads(sys.argv[2])
        print(_ARENA_SENTINEL + json.dumps(_arena_measure(
            spec["s_values"], spec["rounds"], spec["smoke"])))
        sys.exit(0)
    cfg = BenchConfig()
    for row in (lambda_sweep(cfg) + v_sweep(cfg) + k_sweep(cfg)
                + heterogeneity_sweep(cfg) + zoo_sweep(cfg)
                + arena_sweep(cfg)):
        print(row)
