"""Paper Figs. 3-6 — hyper-parameter sweeps.

* lambda sweep (Fig. 3): total time and final accuracy vs mu.
* V sweep (Fig. 4): time-averaged energy (constraint satisfaction) and
  time-averaged objective vs nu — the Theorem-4 O(C/V) trade-off.
* K sweep (Figs. 5/6): LROA vs Uni-D across sampling counts.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from benchmarks.common import BenchConfig, csv_row, run_controller
from repro.core import (LROAController, estimate_hyperparams,
                        paper_default_params)
from repro.core import system_model as sm
from repro.fl import ChannelConfig, ChannelProcess
import jax.numpy as jnp


def lambda_sweep(cfg: BenchConfig, mus=(0.3, 1.0, 10.0, 50.0)) -> List[str]:
    rows = []
    for mu in mus:
        res = run_controller("lroa", cfg, mu=mu)
        acc = res.accuracy_curve()[-1][2]
        rows.append(csv_row(f"lambda_sweep/mu={mu}", 0.0,
                            f"total_time_s={res.total_time:.0f};"
                            f"final_acc={acc:.3f}"))
    return rows


def v_sweep(cfg: BenchConfig, nus=(1e3, 1e4, 1e5, 1e6),
            rounds: int = 600) -> List[str]:
    """Control-only rollout (no model training needed): tracks the
    time-averaged energy vs budget and the time-averaged objective."""
    rows = []
    n = cfg.num_devices
    rng = np.random.default_rng(cfg.seed)
    sizes = rng.integers(200, 600, n).astype(np.float32)
    params = paper_default_params(num_devices=n, data_sizes=sizes,
                                  sample_count=cfg.sample_count)
    for nu in nus:
        hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=cfg.mu,
                                  nu=nu)
        ctrl = LROAController(params, hp)
        chan = ChannelProcess(n, ChannelConfig(seed=cfg.seed))
        tot_e = np.zeros(n)
        tot_obj = 0.0
        for _ in range(rounds):
            h = jnp.asarray(chan.sample())
            dec = ctrl.decide(h)
            tot_e += np.asarray(sm.expected_energy(params, h, dec.p, dec.f,
                                                   dec.q))
            t = sm.round_time(params, h, dec.p, dec.f)
            w = params.data_weights
            tot_obj += float(jnp.sum(dec.q * t +
                                     hp.lam * jnp.square(w) / dec.q))
            ctrl.step_queues(h, dec)
        rows.append(csv_row(
            f"v_sweep/nu={nu:.0e}", 0.0,
            f"avg_energy_J={tot_e.mean() / rounds:.2f};"
            f"budget_J={float(np.asarray(params.energy_budget).mean()):.1f};"
            f"avg_objective={tot_obj / rounds:.1f};"
            f"queue_mean={float(np.asarray(ctrl.queues).mean()):.0f}"))
    return rows


def k_sweep(cfg: BenchConfig, ks=(2, 4, 6)) -> List[str]:
    rows = []
    for k in ks:
        for name in ("lroa", "uni_d"):
            res = run_controller(name, cfg, sample_count=k)
            acc = res.accuracy_curve()[-1][2]
            rows.append(csv_row(f"k_sweep/K={k}/{name}", 0.0,
                                f"total_time_s={res.total_time:.0f};"
                                f"final_acc={acc:.3f}"))
    return rows


def heterogeneity_sweep(cfg: BenchConfig, spreads=(1.0, 2.0, 4.0),
                        rounds: int = 150) -> List[str]:
    """System-heterogeneity ablation (the paper's core motivation): as the
    CPU-speed spread grows, adaptive sampling should increasingly out-run
    uniform sampling because stragglers are demoted. Control-only rollout —
    realised round latency = max over the sampled set (eq. 10)."""
    import dataclasses as dc

    from repro.core import (LROAController, UniformStaticController,
                            estimate_hyperparams, paper_default_params)
    from repro.core.controller import realized_round_time
    from repro.fl import ChannelConfig, ChannelProcess, HeterogeneityConfig
    from repro.fl import heterogeneous_params, sample_clients

    rows = []
    n = cfg.num_devices
    rng0 = np.random.default_rng(cfg.seed)
    sizes = rng0.integers(200, 600, n).astype(np.float32)
    for spread in spreads:
        base = paper_default_params(num_devices=n, data_sizes=sizes,
                                    sample_count=cfg.sample_count)
        params = heterogeneous_params(
            base, HeterogeneityConfig(cpu_speed_spread=spread,
                                      cycles_spread=spread, seed=7))
        hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=cfg.mu,
                                  nu=cfg.nu)
        totals = {}
        for name, ctrl_cls in (("lroa", LROAController),
                               ("uni_s", UniformStaticController)):
            ctrl = ctrl_cls(params, hp)
            chan = ChannelProcess(n, ChannelConfig(seed=cfg.seed))
            rng = np.random.default_rng(cfg.seed + 1)
            total = 0.0
            for _ in range(rounds):
                h = jnp.asarray(chan.sample())
                dec = ctrl.decide(h)
                sel = sample_clients(rng, np.asarray(dec.q),
                                     params.sample_count)
                total += realized_round_time(params, h, dec, sel)
                ctrl.step_queues(h, dec)
            totals[name] = total
        save = 100.0 * (1 - totals["lroa"] / totals["uni_s"])
        rows.append(csv_row(
            f"heterogeneity_sweep/spread={spread}", 0.0,
            f"lroa_s={totals['lroa']:.0f};uni_s_s={totals['uni_s']:.0f};"
            f"latency_saving_pct={save:.1f}"))
    return rows


if __name__ == "__main__":
    cfg = BenchConfig()
    for row in (lambda_sweep(cfg) + v_sweep(cfg) + k_sweep(cfg)
                + heterogeneity_sweep(cfg)):
        print(row)
