"""Shared benchmark harness: builds the paper's FL testbed (scaled-down by
default so `python -m benchmarks.run` completes on CPU; pass --paper-scale
for the 120-device configuration) and timing helpers."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (DivFLController, LROAController,
                        UniformDynamicController, UniformStaticController,
                        estimate_hyperparams, paper_default_params)
from repro.data import (dirichlet_partition, make_client_datasets,
                        synthetic_image_classification, train_test_split)
from repro.fl import (ChannelConfig, ChannelProcess, ClientConfig,
                      FederatedTrainer)
from repro.models import CNNTask, MLPTask
from repro.optim import paper_step_decay

CONTROLLERS = {
    "lroa": LROAController,
    "uni_d": UniformDynamicController,
    "uni_s": UniformStaticController,
    "divfl": DivFLController,
}


@dataclasses.dataclass
class BenchConfig:
    num_devices: int = 20
    rounds: int = 30
    sample_count: int = 2
    local_epochs: int = 2
    batch_size: int = 16
    num_classes: int = 4
    image_shape: tuple = (8, 8, 1)
    examples: int = 2500
    lr: float = 0.1
    mu: float = 1.0
    nu: float = 1e5
    seed: int = 0
    use_cnn: bool = False

    @classmethod
    def paper_scale(cls) -> "BenchConfig":
        return cls(num_devices=120, rounds=2000, examples=50_000,
                   num_classes=10, image_shape=(32, 32, 3), use_cnn=True)


def build_testbed(cfg: BenchConfig):
    x, y = synthetic_image_classification(
        cfg.examples, cfg.image_shape, cfg.num_classes, noise=0.3,
        seed=cfg.seed)
    (xtr, ytr), (xte, yte) = train_test_split(x, y, 0.15, seed=cfg.seed + 1)
    parts = dirichlet_partition(ytr, cfg.num_devices, 0.5, seed=cfg.seed + 2)
    client_data = make_client_datasets(xtr, ytr, parts)
    sizes = np.asarray([len(p) for p in parts], np.float32)
    params = paper_default_params(
        num_devices=cfg.num_devices, sample_count=cfg.sample_count,
        local_epochs=cfg.local_epochs, data_sizes=sizes)
    if cfg.use_cnn:
        task = CNNTask(image_shape=cfg.image_shape,
                       num_classes=cfg.num_classes)
    else:
        task = MLPTask(input_dim=int(np.prod(cfg.image_shape)),
                       num_classes=cfg.num_classes, hidden=32)
    return params, task, client_data, (xte, yte)


def make_trainer(name: str, cfg: BenchConfig, *, mu=None, nu=None,
                 sample_count=None) -> FederatedTrainer:
    """Build the testbed + trainer without running it (lets benchmarks
    separate setup/compile cost from steady-state round throughput)."""
    if sample_count is not None:
        cfg = dataclasses.replace(cfg, sample_count=sample_count)
    params, task, client_data, test = build_testbed(cfg)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5,
                              mu=mu if mu is not None else cfg.mu,
                              nu=nu if nu is not None else cfg.nu)
    controller = CONTROLLERS[name](params, hp)
    return FederatedTrainer(
        task, params, controller,
        ChannelProcess(cfg.num_devices, ChannelConfig(seed=cfg.seed)),
        client_data,
        ClientConfig(local_epochs=cfg.local_epochs,
                     batch_size=cfg.batch_size),
        paper_step_decay(cfg.lr, cfg.rounds),
        test_data=test, eval_every=max(cfg.rounds // 6, 1), seed=cfg.seed)


def run_controller(name: str, cfg: BenchConfig, *, mu=None, nu=None,
                   sample_count=None, verbose=False):
    trainer = make_trainer(name, cfg, mu=mu, nu=nu,
                           sample_count=sample_count)
    return trainer.run(cfg.rounds, verbose=verbose)


def time_us(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
