"""repro.launch — mesh builders, dry-run, roofline, training drivers."""
