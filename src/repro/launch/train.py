"""Runnable LM training driver with checkpointing and resume.

On CPU this trains the smoke variant of any ``--arch`` for a few hundred
steps (the end-to-end driver deliverable); on real hardware the same driver
takes the full config and the production mesh (``--mesh prod``).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import lm_batches, synthetic_lm_tokens
from repro.launch.steps import build_model, make_train_step
from repro.optim import SGD


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="mamba2-130m")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-smoke) architecture")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config \
        else get_smoke_config(args.arch)
    if cfg.is_encoder_decoder or cfg.family == "vlm":
        print(f"note: {args.arch} takes stub modality inputs; training the "
              "decoder on text-only batches here")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"training {cfg.name}: {n_params/1e6:.2f}M params, "
          f"batch {args.batch} x seq {args.seq}, {args.steps} steps")

    opt_state = SGD(momentum=0.9).init(params)
    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            params, meta = restore_checkpoint(args.ckpt_dir,
                                              f"step_{last}", params)
            start = int(meta.get("step", last))
            print(f"resumed from step {start}")

    toks = synthetic_lm_tokens(max(args.batch * 16, 64), args.seq + 1,
                               cfg.vocab_size, seed=0)
    batches = lm_batches(toks, args.batch, seed=1)

    step_fn = make_train_step(cfg, lr=args.lr, remat=False)
    if cfg.is_encoder_decoder:
        frame = jnp.zeros((args.batch, cfg.encoder_seq_len, cfg.d_model),
                          jnp.float32)
    if cfg.family == "vlm":
        vis = jnp.zeros((args.batch, cfg.vision_patches, cfg.d_model),
                        jnp.float32)
    step_fn = jax.jit(step_fn)

    t0 = time.time()
    loss0 = None
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        if cfg.is_encoder_decoder:
            batch["frame_embeds"] = frame
        if cfg.family == "vlm":
            batch["vision_embeds"] = vis
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if loss0 is None:
            loss0 = loss
        if step % args.log_every == 0 or step == args.steps - 1:
            rate = (step - start + 1) / (time.time() - t0)
            print(f"step {step:5d}  loss {loss:8.4f}  {rate:5.2f} it/s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, f"step_{step + 1}", params,
                            {"step": step + 1, "loss": loss})
    print(f"done: loss {loss0:.4f} -> {loss:.4f} "
          f"({(1 - loss / max(loss0, 1e-9)) * 100:.1f}% reduction)")


if __name__ == "__main__":
    main()
