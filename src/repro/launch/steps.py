"""Step functions (train / prefill / decode) + input_specs for every
assigned architecture x input shape, ready for jit lowering on a mesh.

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
allocation) for every model input; the audio/VLM modality frontends are
stubs per the assignment — frame/patch embeddings arrive as inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchSpec, get_spec
from repro.configs.shapes import InputShape
from repro.models.config import ModelConfig
from repro.models.encdec import EncoderDecoderLM
from repro.models.transformer import TransformerLM
from repro.models.layers import token_nll
from repro.models.vlm import mrope_positions, mrope_decode_positions
from repro.optim import SGD, apply_updates

PyTree = Any


def build_model(cfg: ModelConfig, remat: bool = False):
    if cfg.is_encoder_decoder:
        return EncoderDecoderLM(cfg)
    return TransformerLM(cfg, remat=remat)


def dryrun_config(cfg: ModelConfig, multi_pod: bool = False) -> ModelConfig:
    """TPU-realistic dtypes for lowering: bf16 params + activations, flash
    attention, activation sharding constraints bound to the mesh axes."""
    data_shards = 32 if multi_pod else 16
    return dataclasses.replace(
        cfg, param_dtype="bfloat16", dtype="bfloat16", attn_impl="flash",
        batch_axes=("pod", "data") if multi_pod else ("data",),
        moe_groups=data_shards if cfg.family == "moe" else cfg.moe_groups,
        # vocabs not divisible by the model axis would replicate the
        # embedding/logits; pad to the next multiple (masked -inf slots)
        vocab_pad_multiple=16 if cfg.vocab_size % 16 else 0)


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------

def input_specs(arch: str, shape: InputShape,
                cfg: Optional[ModelConfig] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the data inputs of one step."""
    spec = get_spec(arch)
    cfg = cfg or spec.config
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        out: Dict[str, jax.ShapeDtypeStruct] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
        }
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "audio":
            out["frame_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), f32)
        if cfg.family == "vlm":
            out["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_patches, cfg.d_model), f32)
        return out

    # decode: one token against a seq_len cache
    out = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
           "cache_index": jax.ShapeDtypeStruct((), i32)}
    if cfg.family == "audio":
        out["enc_states"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), f32)
    return out


def cache_specs(arch: str, shape: InputShape,
                cfg: Optional[ModelConfig] = None) -> PyTree:
    spec = get_spec(arch)
    cfg = cfg or spec.config
    model = build_model(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype))


def param_specs(cfg: ModelConfig) -> PyTree:
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, lr: float = 1e-3,
                    remat: bool = True,
                    microbatch: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatch`` > 1 splits the global batch into sequential accumulation
    chunks (scan) — the memory-roofline lever for the big train shapes.
    """
    model = build_model(cfg, remat=remat)
    opt = SGD(momentum=0.9)

    def loss_fn(params, batch):
        if cfg.is_encoder_decoder:
            logits, aux, _ = model.apply(params, batch["tokens"],
                                         frame_embeds=batch["frame_embeds"])
        elif cfg.family == "vlm":
            b, s = batch["tokens"].shape
            pthw = mrope_positions(b, s, cfg.vision_patches)
            logits, aux, _ = model.apply(params, batch["tokens"],
                                         positions_thw=pthw,
                                         vision_embeds=batch["vision_embeds"])
        else:
            logits, aux, _ = model.apply(params, batch["tokens"])
        labels = batch["labels"]
        nll = token_nll(logits, labels)
        return jnp.mean(nll) + cfg.router_aux_loss_coef * aux

    def train_step(params, opt_state, batch):
        if microbatch <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatch, x.shape[0] // microbatch)
                                 + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + loss,
                        jax.tree_util.tree_map(jnp.add, grad_acc, grads)), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / microbatch
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)
        updates, new_opt = opt.update(grads, opt_state, params,
                                      jnp.asarray(lr, jnp.float32))
        new_params = apply_updates(params, updates)
        return new_params, new_opt, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            logits, _, cache = model.apply(params, batch["tokens"],
                                           frame_embeds=batch["frame_embeds"],
                                           mode="prefill")
        elif cfg.family == "vlm":
            b, s = batch["tokens"].shape
            pthw = mrope_positions(b, s, cfg.vision_patches)
            logits, _, cache = model.apply(params, batch["tokens"],
                                           positions_thw=pthw,
                                           vision_embeds=batch["vision_embeds"],
                                           mode="prefill")
        else:
            logits, _, cache = model.apply(params, batch["tokens"],
                                           mode="prefill")
        # return only the last-position logits (serving) + the cache
        return logits[:, -1, :], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One-token decode against a pre-filled KV/state cache."""
    model = build_model(cfg)

    def serve_step(params, cache, batch):
        tokens = batch["tokens"]
        idx = batch["cache_index"]
        if cfg.is_encoder_decoder:
            logits, new_cache = model.decode_step(
                params, cache, tokens, idx, batch["enc_states"])
        elif cfg.family == "vlm":
            b = tokens.shape[0]
            pthw = mrope_decode_positions(b, idx, cfg.vision_patches)
            logits, new_cache = model.decode_step(params, cache, tokens, idx,
                                                  positions_thw=pthw)
        else:
            logits, new_cache = model.decode_step(params, cache, tokens, idx)
        return logits[:, -1, :], new_cache

    return serve_step


def make_fl_round_step(cfg: ModelConfig, num_clients_per_round: int,
                       *, lr: float = 1e-2, local_steps: int = 4) -> Callable:
    """Client-parallel FL round (the paper's Algorithm 1 inner loop) as one
    SPMD program: K clients run local SGD in parallel (client axis sharded
    over the mesh's data axis via batch sharding), then the unbiased
    aggregation (eq. 4) reduces their deltas into the global model.

    batch leaves: tokens/labels [K, local_batch, S]; coeffs [K] = w/(K q).
    """
    model = build_model(cfg)
    opt = SGD(momentum=0.9)

    def local_loss(params, tokens, labels):
        logits, aux, _ = model.apply(params, tokens)
        return jnp.mean(token_nll(logits, labels)) + \
            cfg.router_aux_loss_coef * aux

    def one_client(params, tokens, labels):
        state = opt.init(params)

        def step(carry, _):
            p, s = carry
            loss, g = jax.value_and_grad(local_loss)(p, tokens, labels)
            upd, s = opt.update(g, s, p, jnp.asarray(lr, jnp.float32))
            return (apply_updates(p, upd), s), loss

        (p_new, _), losses = jax.lax.scan(step, (params, state), None,
                                          length=local_steps)
        delta = jax.tree_util.tree_map(lambda a, b: a - b, p_new, params)
        return delta, jnp.mean(losses)

    def fl_round_step(params, batch):
        deltas, losses = jax.vmap(one_client, in_axes=(None, 0, 0))(
            params, batch["tokens"], batch["labels"])
        coeffs = batch["coeffs"]                      # [K] = w_n / (K q_n)
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p.astype(jnp.float32) +
                          jnp.tensordot(coeffs, d.astype(jnp.float32),
                                        axes=1)).astype(p.dtype),
            params, deltas)
        return new_params, {"loss": jnp.mean(losses)}

    return fl_round_step
