"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs   / (chips * 197e12)
    memory     = HLO_bytes   / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

``cost_analysis`` of the SPMD-partitioned executable reports **per-device**
flops/bytes, so global = per_device * chips and the division by chips
cancels; we compute from the per-device numbers directly (equivalent to the
brief's formulas).  collective_bytes sums the *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the post-partitioning HLO, reconstructed from result shapes + replica
group sizes (operands are not typed inline in optimized HLO text).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?(?:,\s*)?)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    operand_bytes: int
    ici_traffic_bytes: int       # ring-algorithm per-chip traffic estimate


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shapes_blob, kind = m.group(1), m.group(2)
        if "-done" in line:
            continue
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(shapes_blob))
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if kind == "all-gather":
            operand = result_bytes // max(g, 1)
            traffic = result_bytes * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            operand = result_bytes * g
            traffic = result_bytes * (g - 1)
        elif kind == "all-reduce":
            operand = result_bytes
            traffic = 2 * result_bytes * (g - 1) // max(g, 1)
        elif kind == "all-to-all":
            operand = result_bytes
            traffic = result_bytes * (g - 1) // max(g, 1)
        else:                      # collective-permute
            operand = result_bytes
            traffic = result_bytes
        ops.append(CollectiveOp(kind, result_bytes, g, operand, traffic))
    return ops


def roofline_terms(hlo_analysis: Dict, xla_cost: Dict[str, float],
                   *, chips: int, model_flops: float = 0.0) -> Dict:
    """``hlo_analysis``: loop-aware per-device numbers from
    ``repro.launch.hlo_cost.analyze`` (XLA's own cost_analysis counts while
    bodies once — see that module); ``xla_cost`` kept for cross-checking."""
    flops = float(hlo_analysis["flops"])
    bytes_accessed = float(hlo_analysis["bytes"])
    coll_operand = float(hlo_analysis["collective_operand_bytes"])
    coll_traffic = float(hlo_analysis["collective_traffic_bytes"])

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_operand / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "collective_traffic_s": coll_traffic / ICI_BW,
             "hlo_flops_per_device": flops,
             "hlo_bytes_per_device": bytes_accessed,
             "collective_operand_bytes": coll_operand,
             "collective_traffic_bytes": coll_traffic,
             "collective_counts": hlo_analysis.get("collective_counts", {}),
             "collective_bytes_by_kind":
                 hlo_analysis.get("collective_bytes_by_kind", {}),
             "xla_raw_flops": float(xla_cost.get("flops", 0.0)),
             "xla_raw_bytes": float(xla_cost.get("bytes accessed", 0.0))}
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant
    if model_flops:
        terms["model_flops"] = model_flops
        global_hlo = flops * chips
        terms["model_flops_ratio"] = (model_flops / global_hlo
                                      if global_hlo else 0.0)
    return terms


def _breakdown(collectives: List[CollectiveOp]) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    for op in collectives:
        d = out.setdefault(op.kind, {"count": 0, "operand_bytes": 0,
                                     "traffic_bytes": 0})
        d["count"] += 1
        d["operand_bytes"] += op.operand_bytes
        d["traffic_bytes"] += op.ici_traffic_bytes
    return out


def train_model_flops(param_count: int, active_param_count: int,
                      tokens: int) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE)."""
    return 6.0 * active_param_count * tokens


def decode_model_flops(active_param_count: int, batch: int) -> float:
    """One decode step: 2 N_active per token."""
    return 2.0 * active_param_count * batch


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>12s} "
           f"{'useful%':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        t = r["terms"]
        useful = t.get("model_flops_ratio", 0.0) * 100
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"{t['compute_s']:10.4f} {t['memory_s']:10.4f} "
            f"{t['collective_s']:10.4f} {t['dominant']:>12s} {useful:8.1f}")
    return "\n".join(lines)
