"""Production mesh builders (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests (axis names match production)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_fl_mesh(num_shards: int | None = None):
    """1-D ``('data',)`` mesh for FL client-axis OR scenario-axis sharding.

    Two consumers share the axis name contract (the name, not the mesh
    shape, is the contract):

    * the FL round engine ``shard_map``s the K sampled clients (and the
      ClientBank's N axis) over ``data`` — intra-rollout scaling;
    * the ScenarioArena (``repro.sim.Arena(mesh=...)``) ``shard_map``s
      its *scenario* axis over ``data`` — whole rollouts per shard, no
      cross-shard collectives, the strong-scaling axis for Sec.-VII
      sweep grids.  The arena's engine must then be mesh-free (the two
      shardings compose by handing each consumer its own axis of a
      larger mesh, not by nesting shard_maps).

    On a pod, pass the ``data`` axis of :func:`make_production_mesh`
    instead.
    """
    n = len(jax.devices()) if num_shards is None else num_shards
    return jax.make_mesh((n,), ("data",))


# Roofline hardware constants (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
