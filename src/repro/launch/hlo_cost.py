"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
regardless of trip count — so a model that scans over 64 layers reports
~1/64 of its real FLOPs. This module re-derives FLOPs, bytes-accessed and
collective traffic from the post-partitioning HLO text, multiplying loop
bodies by their ``known_trip_count`` backend-config annotation (emitted by
XLA for counted loops, i.e. every lax.scan / fori_loop).

Method: parse the module into computations; build a name->shape table from
instruction definitions (result shapes are inline in optimized HLO); cost
each instruction:

  * dot            — 2 * prod(result_dims) * prod(contracting_dims)
  * convolution    — 2 * prod(result_dims) * prod(kernel_spatial) * C_in
  * elementwise / reduce / select ... — 1 flop per result element
    (transcendentals: weighted a bit higher, matching XLA's convention)
  * every op       — bytes = operand bytes + result bytes
  * fusion         — cost of its fused computation, result bytes of the root
  * while          — (body + condition) * trip_count
  * call / custom-call / collectives — recorded; collective operand bytes
    tallied per kind (loop multipliers applied)

This is an estimate (fusion double-counts some intermediate bytes that never
hit HBM), so EXPERIMENTS.md reports both this and XLA's raw numbers; FLOPs
from this analyzer are exact for matmul-dominated graphs.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"([a-zA-Z][\w\-]*)\(")   # first ident directly before (
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:?[=\s]*\{?[\\"]*n[\\"]*:?[\\"]*(\d+)')
_CALLEE_RE = re.compile(r"(?:body|to_apply|calls|condition|fused_computation)="
                        r"%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "select", "compare", "and", "or", "xor", "not", "sign",
    "floor", "ceil", "round-nearest-afz", "clamp", "remainder",
}
_TRANSCENDENTAL = {"exponential", "log", "log-plus-one", "tanh", "rsqrt",
                   "sqrt", "power", "expm1", "logistic", "sine", "cosine",
                   "cbrt", "atan2", "erf"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "reduce-scatter-start",
                "all-to-all-start"}
_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_elems_bytes(blob: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all shapes found in a type blob."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(blob):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES.get(dt, 4)
    return elems, byts


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_elems: int
    result_bytes: int
    operands: List[str]
    callees: List[str]
    trip_count: int
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_traffic_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        self.collective_operand_bytes += o.collective_operand_bytes
        self.collective_traffic_bytes += o.collective_traffic_bytes
        for k, v in o.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        for k, v in o.collective_bytes_by_kind.items():
            self.collective_bytes_by_kind[k] = \
                self.collective_bytes_by_kind.get(k, 0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.transcendentals * m,
                    self.collective_operand_bytes * m,
                    self.collective_traffic_bytes * m,
                    {k: v * m for k, v in self.collective_counts.items()},
                    {k: v * m for k, v in
                     self.collective_bytes_by_kind.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instruction]] = {}
        self.shape_table: Dict[str, Tuple[int, int]] = {}
        self.dims_table: Dict[str, List[int]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.entry = self._find_entry(hlo_text)

    # -- parsing ----------------------------------------------------------

    def _parse(self, text: str):
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if not stripped:
                continue
            if not line.startswith((" ", "\t")) and stripped.endswith("{"):
                hdr = _COMP_HDR_RE.match(stripped)
                if hdr:
                    current = hdr.group(1)
                    self.computations[current] = []
                    continue
            if stripped == "}":
                current = None
                continue
            if current is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            om = _OP_RE.search(rhs)
            if not om:
                continue
            type_blob, opcode = rhs[:om.start()], om.group(1)
            elems, byts = _shape_elems_bytes(type_blob)
            self.shape_table[name] = (elems, byts)
            first = _SHAPE_RE.search(type_blob)
            if first:
                self.dims_table[name] = [int(x) for x in
                                         first.group(2).split(",") if x]
            # operands: %refs inside the call parens (before attributes)
            paren = rhs[om.end():]
            depth = 1
            end = 0
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_blob = paren[:end]
            attrs = paren[end:]
            operands = _OPERAND_RE.findall(operand_blob)
            callees = _CALLEE_RE.findall(attrs)
            trip = 1
            tm = _TRIP_RE.search(attrs)
            if tm:
                trip = int(tm.group(1))
            self.computations[current].append(Instruction(
                name=name, opcode=opcode, result_elems=elems,
                result_bytes=byts, operands=operands, callees=callees,
                trip_count=trip, line=stripped))

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        if m:
            return m.group(1)
        # fall back: the largest computation
        return max(self.computations, key=lambda k: len(self.computations[k]))

    # -- costing ----------------------------------------------------------

    def _operand_bytes(self, inst: Instruction) -> int:
        total = 0
        for op in inst.operands:
            if op in self.shape_table:
                total += self.shape_table[op][1]
        return total

    def _dot_flops(self, inst: Instruction) -> float:
        # 2 * result_elems * prod(contracting dims of lhs)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
        lhs = inst.operands[0] if inst.operands else None
        if cm and lhs in self.dims_table:
            lhs_dims = self.dims_table[lhs]
            cdims = [int(x) for x in cm.group(1).split(",") if x != ""]
            k = 1
            for c in cdims:
                if c < len(lhs_dims):
                    k *= lhs_dims[c]
            return 2.0 * inst.result_elems * k
        if lhs in self.shape_table:
            lhs_elems = self.shape_table[lhs][0]
            rhs = inst.operands[1] if len(inst.operands) > 1 else None
            rhs_elems = self.shape_table.get(rhs, (1, 0))[0]
            re_ = max(inst.result_elems, 1)
            # lhs*rhs/result = (M*K)*(K*N)/(M*N) = K^2 (batch dims cancel)
            k2 = (lhs_elems * rhs_elems) / re_
            return 2.0 * re_ * max(k2, 1.0) ** 0.5
        return 2.0 * inst.result_elems

    def _collective(self, inst: Instruction, cost: Cost):
        kind = inst.opcode.replace("-start", "")
        g = 1
        gm = re.search(r"replica_groups=\{\{([0-9,]+)\}", inst.line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = re.search(r"replica_groups=\[(\d+),(\d+)\]", inst.line)
            if gi:
                g = int(gi.group(2))
        rb = inst.result_bytes
        if kind == "all-gather":
            operand = rb // max(g, 1)
            traffic = rb * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            operand = rb * g
            traffic = rb * (g - 1)
        elif kind == "all-reduce":
            operand = rb
            traffic = 2 * rb * (g - 1) // max(g, 1)
        elif kind == "all-to-all":
            operand = rb
            traffic = rb * (g - 1) // max(g, 1)
        else:
            operand = rb
            traffic = rb
        cost.collective_operand_bytes += operand
        cost.collective_traffic_bytes += traffic
        cost.collective_counts[kind] = cost.collective_counts.get(kind, 0) + 1
        cost.collective_bytes_by_kind[kind] = \
            cost.collective_bytes_by_kind.get(kind, 0) + operand

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()          # break cycles defensively
        total = Cost()
        for inst in self.computations.get(name, []):
            c = Cost()
            op = inst.opcode
            if op == "dot":
                c.flops = self._dot_flops(inst)
                c.bytes = self._operand_bytes(inst) + inst.result_bytes
            elif op == "convolution":
                c.flops = self._conv_flops(inst)
                c.bytes = self._operand_bytes(inst) + inst.result_bytes
            elif op == "while":
                body = Cost()
                for callee in inst.callees:
                    body += self.computation_cost(callee)
                c = body.scaled(inst.trip_count)
            elif op in ("fusion", "call", "conditional", "map", "async-start"):
                for callee in inst.callees:
                    c += self.computation_cost(callee)
                c.bytes += self._operand_bytes(inst) + inst.result_bytes
            elif op in _COLLECTIVES:
                self._collective(inst, c)
                c.bytes = self._operand_bytes(inst) + inst.result_bytes
            elif op in _TRANSCENDENTAL:
                c.flops = float(inst.result_elems)
                c.transcendentals = float(inst.result_elems)
                c.bytes = self._operand_bytes(inst) + inst.result_bytes
            elif op in _ELEMENTWISE or op in (
                    "reduce", "reduce-window", "broadcast", "iota",
                    "exponential-minus-one"):
                c.flops = float(inst.result_elems)
                c.bytes = self._operand_bytes(inst) + inst.result_bytes
            elif op in _NO_BYTES:
                pass
            elif op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced/gathered elements, not the operand
                c.bytes = 2.0 * inst.result_bytes
            elif op in ("dynamic-update-slice", "scatter", "scatter-add"):
                # in-place when aliased: read+write of the update window
                upd = inst.operands[1] if len(inst.operands) > 1 else None
                upd_bytes = self.shape_table.get(upd, (0, inst.result_bytes))[1]
                c.bytes = 2.0 * upd_bytes
            else:
                # data movement (reshape/transpose/copy/convert/...)
                c.bytes = self._operand_bytes(inst) + inst.result_bytes
            total += c
        self._memo[name] = total
        return total

    def _conv_flops(self, inst: Instruction) -> float:
        wm = re.search(r"window=\{size=([0-9x]+)", inst.line)
        k = 1
        if wm:
            for s in wm.group(1).split("x"):
                k *= int(s)
        # approximate C_in from operand/result ratio
        cin = 1
        shapes = _SHAPE_RE.findall(inst.line)
        if len(shapes) >= 3:
            kern_dims = [int(x) for x in shapes[2][1].split(",") if x]
            if len(kern_dims) >= 2:
                cin = kern_dims[-2]
        return 2.0 * inst.result_elems * k * cin

    def entry_cost(self) -> Cost:
        # fusions/whiles referenced from entry pull in their computations;
        # computations reached only via entry are not double counted because
        # we never sum computations standalone.
        return self.computation_cost(self.entry)


def analyze_by_opcode(hlo_text: str, top: int = 15) -> List[Tuple[str, float, float]]:
    """(opcode, flops, bytes) totals with loop multipliers — debugging aid."""
    model = HloCostModel(hlo_text)
    totals: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0.0])

    def walk(name: str, mult: float, seen):
        for inst in model.computations.get(name, []):
            if inst.opcode == "while":
                for callee in inst.callees:
                    walk(callee, mult * inst.trip_count, seen)
            elif inst.opcode in ("fusion", "call", "conditional"):
                for callee in inst.callees:
                    walk(callee, mult, seen)
                totals[inst.opcode][1] += mult * (
                    model._operand_bytes(inst) + inst.result_bytes)
            else:
                c_flops = 0.0
                if inst.opcode == "dot":
                    c_flops = model._dot_flops(inst)
                elif inst.opcode in _ELEMENTWISE | _TRANSCENDENTAL or \
                        inst.opcode in ("reduce", "broadcast", "iota"):
                    c_flops = float(inst.result_elems)
                totals[inst.opcode][0] += mult * c_flops
                if inst.opcode not in _NO_BYTES:
                    totals[inst.opcode][1] += mult * (
                        model._operand_bytes(inst) + inst.result_bytes)

    walk(model.entry, 1.0, set())
    rows = sorted(((k, v[0], v[1]) for k, v in totals.items()),
                  key=lambda r: -max(r[1] / 1e12, r[2] / 1e9))
    return rows[:top]


def analyze(hlo_text: str) -> Dict:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "collective_operand_bytes": c.collective_operand_bytes,
        "collective_traffic_bytes": c.collective_traffic_bytes,
        "collective_counts": dict(c.collective_counts),
        "collective_bytes_by_kind": dict(c.collective_bytes_by_kind),
    }
