import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh with 512 placeholder host devices, print
memory_analysis / cost_analysis, and dump roofline JSON.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count on first initialisation.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_spec
from repro.configs.shapes import SHAPES, covered_shapes
from repro.dist import sharding as shd
from repro.launch import hlo_cost
from repro.launch import roofline as rf
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.optim import SGD

# microbatch counts for the train_4k shape (memory fit on 16 GB v5e;
# measured in EXPERIMENTS.md §Perf — the terms are flat in microbatch count
# while activation temp memory scales ~1/mb)
TRAIN_MICROBATCH = {
    "grok-1-314b": 8,
    "granite-20b": 4,
    "gemma2-27b": 4,
    "yi-9b": 4,
    "qwen2-vl-7b": 4,
    "granite-moe-3b-a800m": 4,
    "gemma-2b": 2,
    "recurrentgemma-2b": 2,
    "whisper-tiny": 2,
}


def _mesh_tag(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              microbatch: Optional[int] = None,
              ablate: tuple = (),
              verbose: bool = True) -> Dict:
    """``ablate`` re-enables pre-optimization behaviour for §Perf baselines:
    "moe_sort" (GShard capacity dispatch), "ring_cache" (full-length local
    KV), "act_constraints" (no activation sharding annotations)."""
    import dataclasses as _dc
    spec = get_spec(arch)
    shape = SHAPES[shape_name]
    cfg = steps_lib.dryrun_config(spec.config, multi_pod=multi_pod)
    if "quantized_kv" in ablate:      # opt-IN feature, not an ablation
        cfg = _dc.replace(cfg, quantized_kv=True)
    if "moe_sort" in ablate:
        cfg = _dc.replace(cfg, moe_dispatch="capacity")
    if "ring_cache" in ablate:
        cfg = _dc.replace(cfg, local_ring_cache=False)
    if "act_constraints" in ablate:
        cfg = _dc.replace(cfg, batch_axes=())
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for n in mesh.axis_names:
        chips *= mesh.shape[n]

    t0 = time.time()
    param_shapes = steps_lib.param_specs(cfg)
    param_shardings = shd.params_shardings(param_shapes, mesh)
    data_specs = steps_lib.input_specs(arch, shape, cfg)

    def batch_shardings(specs):
        out = {}
        for k, v in specs.items():
            if v.ndim == 0:
                out[k] = shd.replicated(mesh)
            else:
                out[k] = shd.batch_sharding(v.shape[0], mesh, v.ndim - 1)
        return out

    with mesh:
        if shape.kind == "train":
            mb = microbatch if microbatch is not None else \
                TRAIN_MICROBATCH.get(arch, 1)
            step = steps_lib.make_train_step(cfg, remat=True, microbatch=mb)
            opt_shapes = jax.eval_shape(SGD(momentum=0.9).init, param_shapes)
            opt_shardings = shd.params_shardings(opt_shapes, mesh)
            jitted = jax.jit(step, in_shardings=(
                param_shardings, opt_shardings, batch_shardings(data_specs)),
                out_shardings=(param_shardings, opt_shardings, None))
            lowered = jitted.lower(param_shapes, opt_shapes, data_specs)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(
                param_shardings, batch_shardings(data_specs)))
            lowered = jitted.lower(param_shapes, data_specs)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            step = steps_lib.make_serve_step(cfg)
            cache_shapes = steps_lib.cache_specs(arch, shape, cfg)
            cache_shardings = shd.cache_shardings(cache_shapes, mesh)
            jitted = jax.jit(step, in_shardings=(
                param_shardings, cache_shardings, batch_shardings(data_specs)),
                out_shardings=(None, cache_shardings),
                donate_argnums=(1,))        # in-place cache update
            lowered = jitted.lower(param_shapes, cache_shapes, data_specs)
            tokens = shape.global_batch
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:
        pass
    hlo = compiled.as_text()
    analysis = hlo_cost.analyze(hlo)

    if shape.kind == "train":
        model_flops = rf.train_model_flops(cfg.param_count(),
                                           cfg.active_param_count(), tokens)
    elif shape.kind == "prefill":
        model_flops = 2.0 * cfg.active_param_count() * tokens
    else:
        model_flops = rf.decode_model_flops(cfg.active_param_count(), tokens)

    terms = rf.roofline_terms(analysis, cost, chips=chips,
                              model_flops=model_flops)
    result = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "kind": shape.kind, "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "terms": terms,
        "memory_analysis": _mem_dict(mem),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {_mesh_tag(multi_pod)}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        if mem is not None:
            print(f"  memory_analysis: {_mem_dict(mem)}")
        print(f"  cost_analysis: flops={terms['hlo_flops_per_device']:.3e} "
              f"bytes={terms['hlo_bytes_per_device']:.3e} (per device)")
        print(f"  roofline: compute {terms['compute_s']:.4f}s | memory "
              f"{terms['memory_s']:.4f}s | collective "
              f"{terms['collective_s']:.4f}s -> dominant {terms['dominant']}"
              f" | useful-flops ratio "
              f"{terms.get('model_flops_ratio', 0):.3f}")
    return result


def _mem_dict(mem) -> Optional[Dict]:
    if mem is None:
        return None
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out or {"repr": str(mem)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--all", action="store_true",
                    help="every covered (arch x shape)")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ablate", default="",
                    help="comma list: moe_sort,ring_cache,act_constraints")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for arch, spec in ARCHS.items():
            for shape in covered_shapes(spec):
                combos.append((arch, shape.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    results, failures = [], []
    for arch, shape in combos:
        for mp in meshes:
            try:
                results.append(lower_one(
                    arch, shape, multi_pod=mp, microbatch=args.microbatch,
                    ablate=tuple(filter(None, args.ablate.split(",")))))
            except Exception as e:  # noqa: BLE001 — report, keep going
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape,
                                 "mesh": _mesh_tag(mp), "error": repr(e)})

    if results:
        print()
        print(rf.format_table(results))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
        print(f"\nwrote {args.out}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print(f"  {f_['arch']} x {f_['shape']} x {f_['mesh']}: "
                  f"{f_['error']}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
