"""Pallas TPU kernel for the LROA unbiased aggregation (paper eq. (4)).

    theta^{t+1} = theta^t + sum_{k} coeff_k * delta_k,
    coeff_k = w_{n_k} / (K q_{n_k})

This is the FL server's hot path at datacenter scale: K client deltas of d
model parameters each (d up to billions) reduced into the global model. The
fused kernel streams [K, block] delta tiles through VMEM and performs the
weighted reduction in one pass — K+1 reads + 1 write per element instead of
the K round trips of a naive loop over clients.

grid = (num_blocks,); coefficients ride along in SMEM (scalar prefetch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _aggregate_kernel(coeff_ref, theta_ref, delta_ref, out_ref):
    deltas = delta_ref[...].astype(jnp.float32)          # [K, block]
    coeffs = coeff_ref[...].astype(jnp.float32)          # [K]
    upd = jnp.einsum("k,kn->n", coeffs, deltas)
    out_ref[...] = (theta_ref[...].astype(jnp.float32) +
                    upd).astype(out_ref.dtype)


def fl_aggregate_tpu(theta: Array, deltas: Array, coeffs: Array, *,
                     block: int = 65_536, interpret: bool = False) -> Array:
    """theta: [N]; deltas: [K, N]; coeffs: [K] -> updated theta [N]."""
    (n,) = theta.shape
    k = deltas.shape[0]
    pad = (-n) % block
    if pad:
        theta = jnp.pad(theta, (0, pad))
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    nb = theta.shape[0] // block

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i, coeff: (i,)),
            pl.BlockSpec((k, block), lambda i, coeff: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i, coeff: (i,)),
    )
    out = pl.pallas_call(
        _aggregate_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(theta.shape, theta.dtype),
        interpret=interpret,
    )(coeffs, theta, deltas)
    return out[:n]
