"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

The SSD algorithm (arXiv:2405.21060) splits the sequence into chunks; within
a chunk the state-space recurrence collapses into a decay-masked attention-
like matmul (MXU-friendly), and only a small [nh, hd, N] state crosses chunk
boundaries. This kernel fuses the intra-chunk part:

    scores  = C B^T                      (MXU, [L, L])
    w       = scores * exp(cum_i-cum_j) * dt_j * tril
    y_diag  = w X                        (MXU, [L, hd])
    state_c = (X * dt * exp(cum_L-cum))^T B   (MXU, [hd, N])

grid = (batch, heads, chunks); one chunk per step. B/C are shared across
heads (single-group Mamba-2), pulled per (batch, chunk). VMEM per step:
L*N * 2 + L*hd + L*L + hd*N floats — 256x128 chunks ≈ 0.6 MB.

The cross-chunk recurrence (tiny, sequential) stays in jnp —
``repro.models.ssm.ssd_chunked`` is the full reference implementation.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ssd_chunk_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref,
                      y_ref, state_ref, *, chunk: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [L, hd]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [L]
    a_log = alog_ref[0].astype(jnp.float32)            # scalar
    b_in = b_ref[0].astype(jnp.float32)                # [L, N]
    c_in = c_ref[0].astype(jnp.float32)                # [L, N]

    a = -jnp.exp(a_log)
    da = dt * a                                        # [L]
    cum = jnp.cumsum(da)                               # [L]
    seg = cum[:, None] - cum[None, :]                  # [i, j]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = col <= row
    decay = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(c_in, b_in, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)              # [L]
    wx = x * (dt * decay_to_end)[:, None]              # [L, hd]
    state = jax.lax.dot_general(wx, b_in, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    state_ref[0, 0, 0] = state.astype(state_ref.dtype)  # [hd, N]


def ssd_chunk_tpu(x: Array, dt: Array, a_log: Array, b_in: Array,
                  c_in: Array, *, chunk: int,
                  interpret: bool = False) -> Tuple[Array, Array]:
    """Intra-chunk SSD over a full sequence.

    x: [B, S, nh, hd]; dt: [B, S, nh]; a_log: [nh]; b_in/c_in: [B, S, N].
    S must be a chunk multiple (the model layer pads).
    Returns (y_diag [B, S, nh, hd], states [B, nc, nh, hd, N]).
    """
    bsz, s, nh, hd = x.shape
    n = b_in.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_chunk_kernel, chunk=chunk)
    y, states = pl.pallas_call(
        kernel,
        grid=(bsz, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, n), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, hd, n), lambda b, h, c: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((bsz, nc, nh, hd, n), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(x, dt, a_log, b_in, c_in)
    return y, states
