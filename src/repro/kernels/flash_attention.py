"""Pallas TPU flash-attention kernel (forward).

TPU-native adaptation of the FlashAttention-2 inner loop:
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dimension
    is innermost ("arbitrary" semantics) so the VMEM accumulator carries
    across kv steps; q/kv blocks are MXU-aligned (multiples of 128 on the
    sequence dims, head_dim lives in lanes).
  * BlockSpec index maps pull one [block_q, D] query tile and one
    [block_kv, D] key/value tile into VMEM per step; GQA is handled in the
    index map (kv head = q head // group) — no materialised head repeat.
  * online softmax state (m, l, acc) lives in VMEM scratch; logits soft-cap
    and causal/sliding-window masks are applied in-register.

VMEM working set per step: bq*D + 2*bk*D + bq*bk (f32) — e.g. 512x128
blocks => ~1.2 MB, comfortably under the ~16 MB/core budget, leaving room
for double buffering of the k/v streams.

Validated against ``repro.kernels.ref.mha_reference`` in interpret mode
(CPU). The pure-jnp scan implementation (`repro.models.flash`) is the XLA
fallback used by the mesh dry-run.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -2.0e38


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: int,
                      softcap: float, block_q: int, block_kv: int,
                      seq_q: int, seq_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, D]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # [bq, bk]
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 1)
    mask = kpos < seq_kv
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                                  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                          # [bq, bk]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


def flash_attention_tpu(q: Array, k: Array, v: Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_kv: int = 128,
                        interpret: bool = False) -> Array:
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Sk, D]. Returns [B, H, Sq, D].

    GQA resolved in the k/v BlockSpec index maps (h -> h // group).
    Sequence lengths are padded to block multiples; padded kv positions are
    masked by the in-kernel ``kpos < seq_kv`` predicate.
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, max(_round_up(sq, 16), 16))
    block_kv = min(block_kv, max(_round_up(sk, 16), 16))

    q_pad = _pad_seq(q, block_q)
    k_pad = _pad_seq(k, block_kv)
    v_pad = _pad_seq(v, block_kv)
    nq_blocks = q_pad.shape[2] // block_q
    nk_blocks = k_pad.shape[2] // block_kv

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv, seq_q=sq,
        seq_kv=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq_blocks, nk_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q_pad.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_pad, k_pad, v_pad)
    return out[:, :, :sq, :]


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_seq(x: Array, block: int) -> Array:
    s = x.shape[2]
    pad = (-s) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x
