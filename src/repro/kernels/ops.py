"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: the Pallas TPU kernels run natively on TPU backends and in
interpret mode elsewhere when forced; the default on non-TPU platforms is
the pure-jnp reference (XLA), keeping CPU tests fast while exercising the
identical call signatures.  `force_interpret=True` runs the real kernel body
in Python (used by the per-kernel allclose test sweeps).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.fl_aggregate import fl_aggregate_tpu
from repro.kernels.ssd_scan import ssd_chunk_tpu

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas_kernel(impl: str) -> bool:
    """THE kernel-dispatch predicate: run the Pallas kernel body (natively
    on TPU; forced interpret elsewhere via ``impl='pallas'``).  Every
    wrapper here and the fused-aggregation dispatch in ``repro.fl.server``
    share it, so a policy change (e.g. a GPU kernel path) lands everywhere
    at once."""
    return impl == "pallas" or (impl == "auto" and _on_tpu())


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "impl"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    scale: Optional[float] = None,
                    impl: str = "auto") -> Array:
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Sk, D] -> [B, H, Sq, D]."""
    use_kernel = use_pallas_kernel(impl)
    interpret = impl == "pallas" and not _on_tpu()
    if use_kernel:
        return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   interpret=interpret)
    return ref.mha_reference(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_chunk(x: Array, dt: Array, a_log: Array, b_in: Array, c_in: Array,
              *, chunk: int, impl: str = "auto") -> Tuple[Array, Array]:
    use_kernel = use_pallas_kernel(impl)
    interpret = impl == "pallas" and not _on_tpu()
    if use_kernel:
        return ssd_chunk_tpu(x, dt, a_log, b_in, c_in, chunk=chunk,
                             interpret=interpret)
    # jnp fallback: vmap the per-chunk oracle
    bsz, s, nh, hd = x.shape
    nc = s // chunk

    def per_chunk(xc, dtc, bc, cc):
        return ref.ssd_chunk_reference(xc, dtc, a_log, bc, cc)

    xc = x.reshape(bsz, nc, chunk, nh, hd)
    dtc = dt.reshape(bsz, nc, chunk, nh)
    bc = b_in.reshape(bsz, nc, chunk, -1)
    cc = c_in.reshape(bsz, nc, chunk, -1)
    y, states = jax.vmap(jax.vmap(per_chunk))(xc, dtc, bc, cc)
    return y.reshape(bsz, s, nh, hd), states


@functools.partial(jax.jit, static_argnames=("impl",))
def fl_aggregate(theta: Array, deltas: Array, coeffs: Array,
                 impl: str = "auto") -> Array:
    """Fused eq.-(4) aggregation over flattened parameters."""
    use_kernel = use_pallas_kernel(impl)
    interpret = impl == "pallas" and not _on_tpu()
    if use_kernel:
        return fl_aggregate_tpu(theta, deltas, coeffs, interpret=interpret)
    return ref.aggregate_reference(theta, deltas, coeffs)


@functools.partial(jax.jit, static_argnames=("impl",))
def fl_delta_reduce(deltas: Array, coeffs: Array, impl: str = "auto"
                    ) -> Array:
    """Partial eq.-(4) reduce: ``sum_k coeff_k * delta_k`` (no theta add).

    The per-shard term of the mesh-sharded aggregation: each shard reduces
    its slice of the client axis with one streaming pass, the caller
    ``psum``s the partials across the mesh, and theta is added once on the
    replicated result (``repro.fl.server.aggregate_fused_psum``).  On TPU
    this reuses the ``fl_aggregate`` Pallas kernel against a zero theta;
    elsewhere it is a single tensordot.
    """
    use_kernel = use_pallas_kernel(impl)
    interpret = impl == "pallas" and not _on_tpu()
    if use_kernel:
        zero = jnp.zeros(deltas.shape[1:], jnp.float32)
        return fl_aggregate_tpu(zero, deltas, coeffs, interpret=interpret)
    return jnp.tensordot(coeffs.astype(jnp.float32),
                         deltas.astype(jnp.float32), axes=1)


def fl_aggregate_pytree(global_params, stacked_deltas, coeffs,
                        impl: str = "auto"):
    """eq. (4) over a full parameter pytree (stacked client axis K).

    Per-leaf variant (one kernel launch per leaf).  The canonical
    fused-aggregation entry point is ``repro.fl.server.aggregate_fused``,
    which ravels the whole model into ONE kernel call via ``ParamRavel``
    and is what the round engine uses; prefer it for new code (this
    per-leaf form is kept for leaf-shaped benchmarking/tests).
    """
    def one(p, d):
        flat_p = p.reshape(-1)
        flat_d = d.reshape(d.shape[0], -1)
        return fl_aggregate(flat_p, flat_d, coeffs, impl=impl).reshape(p.shape)

    return jax.tree_util.tree_map(one, global_params, stacked_deltas)
