"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical specification the kernels are tested
against (`tests/test_kernels.py` sweeps shapes/dtypes and asserts allclose).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -2.0e38


def mha_reference(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int = 0, softcap: float = 0.0,
                  scale: Optional[float] = None) -> Array:
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Sk, D] (GQA when Hkv < H)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
    logits = jnp.einsum("bngsd,bntd->bngst", qg,
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[2])
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngst,bntd->bngsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def ssd_chunk_reference(x: Array, dt: Array, a_log: Array, b_in: Array,
                        c_in: Array) -> Tuple[Array, Array]:
    """Intra-chunk SSD oracle (one chunk, zero initial state).

    x: [L, nh, hd]; dt: [L, nh]; a_log: [nh]; b_in/c_in: [L, N].
    Returns (y_diag [L, nh, hd], state [nh, hd, N]) where state is the
    end-of-chunk summary sum_j exp(cum_L - cum_j) dt_j (x_j ⊗ B_j).
    """
    l, nh, hd = x.shape
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = dt.astype(jnp.float32) * a                         # [L, nh]
    cum = jnp.cumsum(da, axis=0)                            # [L, nh]
    seg = cum[:, None, :] - cum[None, :, :]                 # [i, j, nh]
    tri = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("in,jn->ij", c_in.astype(jnp.float32),
                        b_in.astype(jnp.float32))
    w = scores[:, :, None] * decay * dt[None].astype(jnp.float32)
    y = jnp.einsum("ijh,jhd->ihd", w, x.astype(jnp.float32))
    decay_to_end = jnp.exp(cum[-1:, :] - cum)               # [L, nh]
    wx = x.astype(jnp.float32) * (dt.astype(jnp.float32) *
                                  decay_to_end)[..., None]
    state = jnp.einsum("lhd,ln->hdn", wx, b_in.astype(jnp.float32))
    return y.astype(x.dtype), state


def aggregate_reference(theta: Array, deltas: Array, coeffs: Array) -> Array:
    """theta: [N]; deltas: [K, N]; coeffs: [K] — eq. (4) fused update."""
    upd = jnp.tensordot(coeffs.astype(jnp.float32),
                        deltas.astype(jnp.float32), axes=1)
    return (theta.astype(jnp.float32) + upd).astype(theta.dtype)
