"""repro.kernels — Pallas TPU kernels for the perf-critical layers.

* flash_attention — FlashAttention-2 forward (GQA/window/softcap)
* ssd_chunk       — Mamba-2 SSD intra-chunk fused matmuls
* fl_aggregate    — LROA unbiased aggregation, eq. (4)

Each kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); tests sweep shapes/dtypes in interpret mode.
"""

from repro.kernels.ops import (flash_attention, ssd_chunk, fl_aggregate,
                               fl_aggregate_pytree, fl_delta_reduce)
