"""Metrics registry — named counters / gauges / histograms absorbing the
stack's scattered runtime tallies.

Before this module, each layer grew its own ad-hoc counters:
``Arena.traces`` (scan-body retraces), the arena's device-input cache
hits/misses, ``RolloutReport.meta``'s per-run ``dispatches`` /
``executables_built``, ``SweepService.stats``, and ``NpzChunkStore``
save/load tallies.  They now all write through ONE
:class:`MetricsRegistry` per arena/service (the public attributes —
``Arena.traces``, ``SweepService.stats``, ``NpzChunkStore.saves`` —
remain as *views* over the registry, so every existing assertion keeps
working), which means a single ``snapshot()`` captures the whole
system's runtime shape and ``tools/obs_report.py`` can render it.

Naming scheme (dotted ``layer.noun[.verb]``, pinned in
docs/architecture.md):

* ``arena.traces`` — scan-body (re)traces
* ``arena.dispatches`` / ``arena.executables_built`` — cumulative run
  totals (per-run deltas stay in ``RolloutReport.meta``; the additive
  per-bucket contract is still cross-checked by
  ``RolloutReport.dispatch_accounting``)
* ``arena.input_cache.hits`` / ``arena.input_cache.misses`` —
  device-input caches (lane constants, channels, lr schedules)
* ``arena.chunk.dispatch_s`` / ``arena.chunk.reduce_s`` — streaming
  per-chunk dispatch-call and host-reduction latencies (histograms;
  the watchdog's stall percentiles read these)
* ``service.batches`` / ``service.scenarios`` / ``service.seconds`` /
  ``service.coalesced_lanes`` / ``service.queue_depth``
* ``store.saves`` / ``store.loads``
* ``pool.admits`` / ``pool.evicts`` / ``pool.uploads`` /
  ``pool.traces`` / ``pool.resident`` / ``pool.quant.abs_err`` — the
  streaming :class:`~repro.fl.client_bank.BankPool`'s churn tallies,
  scatter (re)trace count (1 after warmup, forever — the zero-retrace
  contract), resident-count gauge, and per-admit int8 quantization
  error histogram; ``BankPool.admits`` etc. are views over these

Counters are exact ints, gauges hold the last value, histograms keep a
bounded reservoir (newest kept) plus exact running count/sum so
percentiles degrade gracefully while totals never do.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic integer counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({n}))")
        self.value += int(n)
        return self.value


class Gauge:
    """Last-value gauge (e.g. cache sizes, queue depth)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value

    def add(self, v: float) -> float:
        self.value = float(self.value) + float(v)
        return self.value


class Histogram:
    """Bounded-reservoir histogram with exact running count/sum.

    The reservoir keeps the newest ``capacity`` observations (a deque,
    not a sampling scheme — the streaming path wants *recent* latency
    percentiles, and the exact count/sum keep long-run totals honest
    regardless of eviction)."""

    __slots__ = ("name", "values", "count", "total")

    def __init__(self, name: str, capacity: int = 2048):
        self.name = name
        self.values: deque = deque(maxlen=capacity)
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.values.append(v)
        self.count += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentiles(self, qs: Iterable[float] = (50.0, 90.0, 99.0)
                    ) -> Dict[float, float]:
        """Nearest-rank percentiles over the (recent) reservoir."""
        out: Dict[float, float] = {}
        vals = sorted(self.values)
        for q in qs:
            if not vals:
                out[float(q)] = math.nan
                continue
            rank = max(0, min(len(vals) - 1,
                              int(math.ceil(q / 100.0 * len(vals))) - 1))
            out[float(q)] = vals[rank]
        return out


class MetricsRegistry:
    """One namespace of counters/gauges/histograms for a subsystem tree
    (an arena plus the service and stores built on it share one
    registry).  Accessors create on first use, so instrumented code
    never has to pre-declare."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- accessors ----------------------------------------------------------

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, capacity: int = 2048) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, capacity)
        return h

    # -- views --------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Flat JSON-shaped view of everything: counters/gauges by name,
        histograms as ``{count, sum, mean, p50, p90, p99}``."""
        out: Dict[str, Any] = {}
        for name, c in sorted(self._counters.items()):
            out[name] = c.value
        for name, g in sorted(self._gauges.items()):
            out[name] = g.value
        for name, h in sorted(self._histograms.items()):
            ps = h.percentiles()
            out[name] = {"count": h.count, "sum": h.total,
                         "mean": h.mean, "p50": ps[50.0],
                         "p90": ps[90.0], "p99": ps[99.0]}
        return out

    def get(self, name: str, default: Optional[float] = 0) -> Any:
        """Read a metric's current value without creating it."""
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            return self._gauges[name].value
        if name in self._histograms:
            return self._histograms[name]
        return default

    def names(self) -> List[str]:
        return sorted([*self._counters, *self._gauges, *self._histograms])
