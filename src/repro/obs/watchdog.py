"""Retrace/compile watchdog — the silent-failure sentinel for the warmed
arena path.

The whole PR-5/6/7 performance story rests on one invariant: after
``Arena.warmup``, same-shape runs never trace or compile again.  A
violated invariant does not crash — it silently multiplies latency
(a scan-body retrace at production shapes costs seconds to minutes) and
is invisible unless someone happens to diff ``Arena.traces``.  The
watchdog turns that diff into an automatic contract:

* :meth:`Watchdog.arm` (called by ``Arena.warmup`` when a watchdog is
  attached) snapshots the trace counter and the executable-cache keys.
* After every subsequent ``Arena.run``, the arena reports back
  (:meth:`observe_run`).  Any new scan-body trace or executable-cache
  key is a violation: the watchdog emits a structured
  ``watchdog.retrace`` event carrying the offending cache-key diff
  (which (bank layout, K_max, shards, eval, dropout) tuples appeared),
  records it in :attr:`violations`, and — in ``strict`` mode — raises
  :class:`RetraceError`.  Non-strict mode warns via ``warnings`` so
  un-observed deployments still surface the regression once.
* The baseline then advances, so one regression is reported once, not
  on every later run.

The watchdog also owns the streaming-path stall view: the arena records
each chunk's dispatch-call and host-reduce latency into the shared
metrics registry (``arena.chunk.dispatch_s`` / ``arena.chunk.reduce_s``)
— :meth:`stall_report` reduces them to percentiles, making an in-flight
window stall (a dispatch call that blocks because the pipeline is
``in_flight`` deep) visible as a fat p99 instead of a mystery.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional

from repro.obs import trace

__all__ = ["RetraceError", "Watchdog"]


class RetraceError(RuntimeError):
    """A strict watchdog saw a post-warmup scan-body retrace or cold
    compile."""


class Watchdog:
    """Arms on warmup, checks every run.  ``strict=True`` raises on a
    violation; otherwise a structured event + one Python warning.

    Attach with :meth:`attach` (or pass ``watchdog=`` to the arena's
    constructor-site code): the arena calls ``arm``/``observe_run`` at
    the right moments itself, so instrumented call sites need nothing.
    """

    def __init__(self, strict: bool = False):
        self.strict = strict
        self.armed = False
        self._traces = 0
        self._fn_keys: set = set()
        #: structured violation records (newest last): ``{"retraces",
        #: "new_executables", "run_meta"}``
        self.violations: List[Dict[str, Any]] = []

    def attach(self, arena) -> "Watchdog":
        """Bind to ``arena`` (one watchdog per arena); returns self."""
        arena.watchdog = self
        return self

    # -- the contract --------------------------------------------------------

    def arm(self, arena) -> None:
        """Snapshot the warmed state: any trace/compile beyond THIS
        point is unexpected."""
        self.armed = True
        self._traces = int(arena.traces)
        self._fn_keys = set(arena._fns)

    def observe_run(self, arena, run_meta: Optional[Dict[str, Any]] = None
                    ) -> Optional[Dict[str, Any]]:
        """Called by the arena after each ``run``.  Returns the
        violation record if one fired, else None."""
        if not self.armed:
            return None
        new_traces = int(arena.traces) - self._traces
        new_keys = sorted(set(arena._fns) - self._fn_keys, key=repr)
        if new_traces <= 0 and not new_keys:
            return None
        violation = {
            "retraces": int(new_traces),
            "new_executables": [repr(k) for k in new_keys],
            "run_meta": {k: run_meta[k] for k in
                         ("k_mode", "k_max", "dispatches",
                          "executables_built")
                         if run_meta and k in run_meta},
        }
        self.violations.append(violation)
        trace.event("watchdog.retrace", **violation)
        # advance the baseline: one regression = one report
        self._traces = int(arena.traces)
        self._fn_keys = set(arena._fns)
        if self.strict:
            raise RetraceError(
                f"post-warmup retrace: {new_traces} new scan-body "
                f"trace(s), {len(new_keys)} new executable cache "
                f"key(s) {violation['new_executables']} — the warmed "
                f"zero-retrace contract is broken (shape or eval "
                f"config drifted from the warmup call)")
        warnings.warn(
            f"obs.Watchdog: post-warmup retrace ({new_traces} new "
            f"trace(s), new cache keys {violation['new_executables']})",
            RuntimeWarning, stacklevel=2)
        return violation

    # -- streaming stall view ------------------------------------------------

    @staticmethod
    def stall_report(metrics) -> Dict[str, Dict[str, float]]:
        """Dispatch/reduce latency percentiles of the streaming path
        from the shared registry — ``{phase: {p50, p90, p99, mean,
        count}}``.  A dispatch p99 far above p50 means the in-flight
        window blocked (device fell behind the host's dispatch rate)."""
        out: Dict[str, Dict[str, float]] = {}
        for phase, name in (("dispatch", "arena.chunk.dispatch_s"),
                            ("reduce", "arena.chunk.reduce_s")):
            h = metrics.get(name, default=None)
            if h is None or not getattr(h, "count", 0):
                continue
            ps = h.percentiles()
            out[phase] = {"p50": ps[50.0], "p90": ps[90.0],
                          "p99": ps[99.0], "mean": h.mean,
                          "count": h.count}
        return out
