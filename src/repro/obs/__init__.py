"""repro.obs — the flight recorder: dependency-free observability for
the engine/arena/service stack.

Three instruments, one contract (no-ops unless enabled, never inside a
jit):

* :mod:`repro.obs.trace` — nestable host-side spans (``arena.plan`` /
  ``arena.compile`` / ``arena.dispatch`` / ``arena.reduce`` /
  ``service.*`` / ``store.*``) with pluggable sinks: an in-memory ring,
  an append-only JSONL flight-recorder file, and a Chrome-trace
  (Perfetto) exporter, plus an optional ``jax.profiler`` annotation
  bridge.
* :mod:`repro.obs.metrics` — one named counter/gauge/histogram registry
  per arena absorbing the formerly scattered tallies (``Arena.traces``,
  cache hit/miss counters, ``SweepService.stats``, chunk-store
  save/load counts), all of which remain as views over it.
* :mod:`repro.obs.watchdog` — the retrace/compile sentinel: armed by
  ``Arena.warmup``, it turns any post-warmup scan-body retrace or cold
  compile into a structured event (or a raise, in strict mode) with the
  offending executable-cache-key diff, and reduces the streaming path's
  per-chunk dispatch/reduce latencies to stall percentiles.

``tools/obs_report.py`` renders a JSONL flight-recorder file into the
per-phase time breakdown and health summary.
"""

from repro.obs import trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import (JsonlSink, MemorySink, export_chrome_trace,
                             install_sink, installed, load_jsonl,
                             remove_sink, span, to_chrome_trace)
from repro.obs.watchdog import RetraceError, Watchdog

__all__ = ["trace", "span", "MemorySink", "JsonlSink", "installed",
           "install_sink", "remove_sink", "load_jsonl", "to_chrome_trace",
           "export_chrome_trace", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "RetraceError", "Watchdog"]
