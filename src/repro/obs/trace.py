"""Span tracer — nestable wall-clock spans over the HOST-side control
plane, with pluggable sinks.

The runtime stack (engine -> dispatch planner -> arena -> sweep service)
is judged by the same kind of signals the paper applies to its clients:
per-phase latency, queue depths, silent regressions.  This module gives
every hot layer one instrument::

    from repro.obs import trace
    with trace.span("arena.dispatch", chunk=3, k_pad=8):
        outs = fn(*args)

Design constraints (the observability contract, pinned by
``tests/test_obs.py``):

* **No-op without a sink.**  ``span(...)`` returns a shared singleton
  no-op context manager when no sink is installed — no allocation, no
  clock read, no attribute dict.  Instrumented code pays a dict lookup
  and a truth test, nothing more, so the tracer can live on hot paths
  permanently.
* **Never inside a jit.**  Spans time Python-side orchestration (plan,
  compile, dispatch-call, host reduce).  Nothing here is traceable and
  nothing is ever called from inside a traced function — jax dispatch
  being async, a span around an executable call measures *dispatch*
  latency unless the caller blocks (the arena's reduce spans wrap the
  blocking ``np.asarray``, which is the honest device-time proxy).
* **Structured records.**  A completed span emits one flat dict:
  ``{"name", "ts", "dur", "id", "parent", "depth", "attrs"}`` with
  ``ts``/``dur`` in seconds relative to the module epoch.  Sinks receive
  the dict AFTER the span closes (children before parents, Chrome-trace
  style).
* **Pluggable sinks.**  :class:`MemorySink` (bounded ring),
  :class:`JsonlSink` (one JSON object per line, append-only — the
  ``runlogs/`` flight-recorder format ``tools/obs_report.py`` renders),
  or anything with an ``emit(record) -> None``.  ``installed()``
  context-manages a sink's lifetime for tests and benches.
* **jax.profiler bridge.**  ``profiler_bridge(True)`` additionally
  enters a ``jax.profiler.TraceAnnotation`` per span, so a captured
  device profile (``jax.profiler.trace``) shows the same taxonomy; off
  by default because annotations cost even when no profile is active.

Span taxonomy (see docs/architecture.md "Observability"): dotted
``layer.phase`` names — ``arena.plan`` / ``arena.probe`` /
``arena.compile`` / ``arena.upload`` / ``arena.dispatch`` /
``arena.reduce`` / ``arena.eval`` / ``arena.run`` / ``arena.warmup`` /
``service.batch`` / ``service.reduce`` / ``store.save`` /
``store.load`` / ``engine.round`` / ``trainer.round``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

__all__ = ["span", "event", "install_sink", "remove_sink", "clear_sinks",
           "installed", "profiler_bridge", "MemorySink", "JsonlSink",
           "to_chrome_trace", "export_chrome_trace", "load_jsonl"]

# module epoch: every record's ts is relative to this, so one run's
# records are mutually comparable and small enough for exact float math
_EPOCH = time.perf_counter()

_SINKS: List[Any] = []
_PROFILER_BRIDGE = False

# span ids are process-global and monotonically increasing; the active
# span stack is thread-local so concurrent host threads nest correctly
_LOCK = threading.Lock()
_NEXT_ID = [0]
_TLS = threading.local()


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def _emit(record: Dict[str, Any]) -> None:
    for sink in list(_SINKS):
        sink.emit(record)


class _NoopSpan:
    """The shared do-nothing span — returned whenever no sink is
    installed, so un-observed runs pay (almost) nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "id", "parent", "depth", "t0",
                 "_annotation")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self._annotation = None

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (e.g. how many
        executables a plan produced)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        st = _stack()
        with _LOCK:
            self.id = _NEXT_ID[0]
            _NEXT_ID[0] += 1
        self.parent = st[-1].id if st else None
        self.depth = len(st)
        st.append(self)
        if _PROFILER_BRIDGE:        # pragma: no cover - needs profiler
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation(self.name)
                self._annotation.__enter__()
            except Exception:
                self._annotation = None
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        if self._annotation is not None:  # pragma: no cover
            self._annotation.__exit__(*exc)
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        _emit({"name": self.name, "ts": self.t0 - _EPOCH,
               "dur": t1 - self.t0, "id": self.id, "parent": self.parent,
               "depth": self.depth, "attrs": self.attrs})
        return False


def span(name: str, **attrs) -> Any:
    """A context manager timing one named phase.  Returns the shared
    no-op singleton when no sink is installed — the zero-overhead
    contract — otherwise a live :class:`_Span` recording wall time,
    ``attrs``, and its position in the active span tree."""
    if not _SINKS:
        return _NOOP
    return _Span(name, attrs)


def event(name: str, **attrs) -> None:
    """An instantaneous structured record (``dur`` 0, no stack entry) —
    the watchdog's warning channel.  No-op without a sink."""
    if not _SINKS:
        return
    st = _stack()
    with _LOCK:
        eid = _NEXT_ID[0]
        _NEXT_ID[0] += 1
    _emit({"name": name, "ts": time.perf_counter() - _EPOCH, "dur": 0.0,
           "id": eid, "parent": st[-1].id if st else None,
           "depth": len(st), "attrs": attrs})


# -- sinks -------------------------------------------------------------------


class MemorySink:
    """Bounded in-memory ring of completed span records (newest kept)."""

    def __init__(self, capacity: int = 4096):
        self.records: deque = deque(maxlen=capacity)

    def emit(self, record: Dict[str, Any]) -> None:
        self.records.append(record)

    def by_name(self, name: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["name"] == name]


class JsonlSink:
    """Appends one JSON object per completed span to ``path`` — the
    flight-recorder file format (``runlogs/<run>.jsonl``) that
    ``tools/obs_report.py`` renders and :func:`load_jsonl` reads back.
    Values in ``attrs`` must be JSON-serialisable; numpy scalars are
    coerced via their ``item()``."""

    def __init__(self, path: str, flush_every: int = 64):
        directory = os.path.dirname(path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        self.path = path
        self._fh = open(path, "a")
        self._since_flush = 0
        self._flush_every = max(1, int(flush_every))

    @staticmethod
    def _jsonable(value: Any) -> Any:
        if hasattr(value, "item") and not isinstance(value, (str, bytes)):
            try:
                return value.item()
            except Exception:
                return repr(value)
        if isinstance(value, (list, tuple)):
            return [JsonlSink._jsonable(v) for v in value]
        if isinstance(value, dict):
            return {str(k): JsonlSink._jsonable(v)
                    for k, v in value.items()}
        if isinstance(value, (str, int, float, bool)) or value is None:
            return value
        return repr(value)

    def emit(self, record: Dict[str, Any]) -> None:
        rec = dict(record)
        rec["attrs"] = self._jsonable(record.get("attrs", {}))
        self._fh.write(json.dumps(rec) + "\n")
        self._since_flush += 1
        if self._since_flush >= self._flush_every:
            self._fh.flush()
            self._since_flush = 0

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def install_sink(sink: Any) -> Any:
    """Register ``sink`` (anything with ``emit(record)``); returns it."""
    _SINKS.append(sink)
    return sink


def remove_sink(sink: Any) -> None:
    if sink in _SINKS:
        _SINKS.remove(sink)


def clear_sinks() -> None:
    del _SINKS[:]


@contextmanager
def installed(sink: Any):
    """``with trace.installed(MemorySink()) as sink: ...`` — sink bound
    for the block, removed (and JsonlSinks closed) on exit."""
    install_sink(sink)
    try:
        yield sink
    finally:
        remove_sink(sink)
        if hasattr(sink, "close"):
            sink.close()


def profiler_bridge(enabled: bool) -> None:
    """Mirror every live span as a ``jax.profiler.TraceAnnotation`` so a
    captured device profile (Perfetto / TensorBoard) carries the same
    span taxonomy.  Off by default — annotations are not free even
    without an active profile, and the no-sink fast path must stay
    untouched (the bridge only fires on spans a sink already made
    live)."""
    global _PROFILER_BRIDGE
    _PROFILER_BRIDGE = bool(enabled)


# -- chrome trace export -----------------------------------------------------


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Read a :class:`JsonlSink` file back into span records (blank
    lines skipped — a crashed writer's torn last line raises, matching
    the flight-recorder expectation that the log is append-only and
    line-atomic)."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def to_chrome_trace(records: List[Dict[str, Any]],
                    process_name: str = "repro") -> Dict[str, Any]:
    """Span records -> Chrome Trace Event JSON (the ``chrome://tracing``
    / Perfetto ``traceEvents`` array of complete ``"X"`` events, ts/dur
    in microseconds).  Instant records (``dur == 0``) become ``"i"``
    events."""
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name}}]
    for r in records:
        common = {"name": r["name"], "pid": 0, "tid": 0,
                  "ts": round(float(r["ts"]) * 1e6, 3),
                  "args": dict(r.get("attrs", {}))}
        if r.get("dur", 0.0) > 0.0:
            events.append({**common, "ph": "X",
                           "dur": round(float(r["dur"]) * 1e6, 3)})
        else:
            events.append({**common, "ph": "i", "s": "t"})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(records: List[Dict[str, Any]], path: str,
                        process_name: str = "repro") -> str:
    directory = os.path.dirname(path)
    if directory:
        os.makedirs(directory, exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_chrome_trace(records, process_name), f)
    return path
