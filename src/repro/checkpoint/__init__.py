"""repro.checkpoint — npz pytree checkpointing."""

from repro.checkpoint.checkpoint import (save_checkpoint, restore_checkpoint,
                                         restore_arrays, checkpoint_exists,
                                         delete_checkpoint, latest_step)
