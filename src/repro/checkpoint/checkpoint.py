"""Dependency-free pytree checkpointing (npz + json manifest).

Flattens a pytree of arrays into key-addressed npz entries; the tree
structure and scalar metadata (step, round, RNG seeds, queue states) go into
a sidecar manifest. Atomic writes (tmp + rename) so an interrupted run never
leaves a corrupt latest checkpoint.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or arr.dtype.itemsize == 0:
            arr = np.asarray(jax.numpy.asarray(leaf).astype("float32"))
        elif str(arr.dtype) in ("bfloat16",):
            # npz cannot serialise ml_dtypes; widen losslessly to f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(directory: str, name: str, tree: PyTree,
                    metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    path = os.path.join(directory, f"{name}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    manifest = {"treedef": str(treedef), "keys": sorted(arrays),
                "metadata": metadata or {}}
    mpath = os.path.join(directory, f"{name}.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(mpath + ".tmp", mpath)
    return path


def restore_checkpoint(directory: str, name: str, like: PyTree
                       ) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    path = os.path.join(directory, f"{name}.npz")
    with np.load(path) as data:
        arrays = {k: data[k] for k in data.files}
    ref = _flatten(like)
    if set(arrays) != set(ref):
        missing = set(ref) - set(arrays)
        extra = set(arrays) - set(ref)
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_ref, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    new_leaves = []
    for (pth, leaf) in flat_paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pth)
        arr = arrays[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        # cast back through jnp (handles bfloat16 and friends)
        new_leaves.append(
            jax.numpy.asarray(arr).astype(jax.numpy.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    mpath = os.path.join(directory, f"{name}.json")
    metadata = {}
    if os.path.exists(mpath):
        with open(mpath) as f:
            metadata = json.load(f).get("metadata", {})
    return tree, metadata


def restore_arrays(directory: str, name: str
                   ) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Flat ``{key: array}`` view of a checkpoint plus its metadata —
    for consumers whose tree IS a flat dict (e.g. per-round metric
    columns) or who rebuild structure themselves, so no ``like`` tree is
    needed.  Keys are the ``_flatten`` path strings; a flat dict saved
    by :func:`save_checkpoint` round-trips exactly."""
    path = os.path.join(directory, f"{name}.npz")
    with np.load(path) as data:
        arrays = {k: data[k] for k in data.files}
    metadata = {}
    mpath = os.path.join(directory, f"{name}.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            metadata = json.load(f).get("metadata", {})
    return arrays, metadata


def checkpoint_exists(directory: str, name: str) -> bool:
    """Whether a complete ``save_checkpoint(directory, name, ...)`` pair
    (npz + manifest) is present."""
    return (os.path.exists(os.path.join(directory, f"{name}.npz")) and
            os.path.exists(os.path.join(directory, f"{name}.json")))


def delete_checkpoint(directory: str, name: str) -> None:
    """Remove a checkpoint's npz + manifest if present (idempotent)."""
    for suffix in (".npz", ".json"):
        path = os.path.join(directory, f"{name}{suffix}")
        if os.path.exists(path):
            os.unlink(path)


def latest_step(directory: str, prefix: str = "step_") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for fn in os.listdir(directory):
        if fn.startswith(prefix) and fn.endswith(".npz"):
            try:
                steps.append(int(fn[len(prefix):-4]))
            except ValueError:
                pass
    return max(steps) if steps else None
