"""Mamba-2 (SSD — state-space duality) block, arXiv:2405.21060.

Implements the chunked SSD algorithm: within a chunk the recurrence is
evaluated as a masked (decay-weighted) attention-like matmul that maps onto
the MXU; across chunks a small state recurrence [nh, hd, state] is scanned.
Single-step decode updates the state in O(d * state) — this is what makes
``long_500k`` trivially feasible for this family.

Structure per block (simplified single-group Mamba-2):
  in_proj -> (z, x, B, C, dt) ; causal depthwise conv on (x|B|C) ;
  SSD(x, dt, A, B, C) ; gated RMSNorm with silu(z) ; out_proj.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Array]


class SSMCache(NamedTuple):
    """Decode-time cache: recurrent state + conv tail."""
    state: Array       # [B, nh, hd, N]
    conv: Array        # [B, conv_width - 1, conv_channels]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    inner = cfg.ssm_expand * cfg.d_model
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state_dim
    assert nh * hd == inner, (nh, hd, inner)
    conv_ch = inner + 2 * st
    return inner, nh, hd, st, conv_ch


def init_ssd(rng: Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    inner, nh, hd, st, conv_ch = _dims(cfg)
    k = jax.random.split(rng, 5)
    return {
        # order: z | x | B | C | dt
        "in_proj": L.dense_init(k[0], d, 2 * inner + 2 * st + nh, dtype),
        "conv_w": (0.1 * jax.random.normal(
            k[1], (cfg.ssm_conv_width, conv_ch), jnp.float32)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, nh))).astype(dtype),
        "norm": jnp.zeros((inner,), dtype),
        "out_proj": L.dense_init(k[2], inner, d, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 tail: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv along time. x: [B,S,C]; w: [W,C].

    Returns (y, new_tail) where tail carries the last W-1 inputs for decode.
    """
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    new_tail = xp[:, -(width - 1):, :] if width > 1 else tail
    return jax.nn.silu(y + b), new_tail


def ssd_chunked(x: Array, dt: Array, a_log: Array, b_in: Array, c_in: Array,
                chunk: int, initial_state: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x: [B,S,nh,hd], dt: [B,S,nh] (post-softplus), b_in/c_in: [B,S,N].
    Returns (y [B,S,nh,hd], final_state [B,nh,hd,N]).
    """
    bsz, s_orig, nh, hd = x.shape
    n = b_in.shape[-1]
    # pad the tail to a chunk multiple: dt == 0 on padding makes the padded
    # steps exact no-ops (decay 1, zero input), so y[:s] and the final state
    # are unaffected.
    pad = (-s_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                  # [nh], negative
    da = dt.astype(jnp.float32) * a                          # [B,S,nh]

    xc = x.reshape(bsz, nc, chunk, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, nh).astype(jnp.float32)
    dac = da.reshape(bsz, nc, chunk, nh)
    bc = b_in.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    cum = jnp.cumsum(dac, axis=2)                            # [B,Nc,Lc,nh]
    # intra-chunk ("diagonal") term: decay-masked attention
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # [B,Nc,i,j,nh]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # [B,Nc,i,j]
    w = scores[..., None] * decay * dtc[:, :, None, :, :]    # [B,Nc,i,j,nh]
    y_diag = jnp.einsum("bcijh,bcjhd->bcihd", w, xc)

    # chunk summary states: S_c = sum_j exp(cum_end - cum_j) dt_j (x_j ⊗ B_j)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # [B,Nc,Lc,nh]
    weighted_x = xc * (dtc * decay_to_end)[..., None]        # [B,Nc,Lc,nh,hd]
    s_chunk = jnp.einsum("bclhd,bcln->bchdn", weighted_x, bc)

    # inter-chunk recurrence over Nc
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # [B,Nc,nh]
    if initial_state is None:
        initial_state = jnp.zeros((bsz, nh, hd, n), jnp.float32)

    def step(h, inputs):
        dec, s_c = inputs                                    # [B,nh], [B,nh,hd,N]
        h_out = h                                            # state BEFORE chunk
        h_new = dec[:, :, None, None] * h + s_c
        return h_new, h_out

    final, h_before = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                  # [B,Nc,nh,hd,N]

    # off-diagonal contribution: y_off[i] = C_i · (exp(cum_i) * H_prev)
    in_decay = jnp.exp(cum)                                  # [B,Nc,Lc,nh]
    y_off = jnp.einsum("bcln,bchdn->bclhd", cc, h_before) * in_decay[..., None]

    y = (y_diag + y_off).reshape(bsz, s, nh, hd)[:, :s_orig]
    return y.astype(x.dtype), final


def ssd_decode_step(x: Array, dt: Array, a_log: Array, b_in: Array,
                    c_in: Array, state: Array) -> Tuple[Array, Array]:
    """One-token SSD update. x: [B,nh,hd], dt: [B,nh], b/c: [B,N]."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * a)              # [B,nh]
    add = (dt[..., None].astype(jnp.float32) * x.astype(jnp.float32)
           )[..., None] * b_in[:, None, None, :].astype(jnp.float32)
    new_state = decay[:, :, None, None] * state + add        # [B,nh,hd,N]
    y = jnp.einsum("bhdn,bn->bhd", new_state,
                   c_in.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def apply_ssd(params: Params, x: Array, cfg: ModelConfig,
              cache: Optional[SSMCache] = None
              ) -> Tuple[Array, Optional[SSMCache]]:
    """Full Mamba-2 block. Train/prefill when cache is None; decode (S==1)
    otherwise."""
    bsz, s, d = x.shape
    inner, nh, hd, st, conv_ch = _dims(cfg)
    proj = x @ params["in_proj"]
    z, xin, b_in, c_in, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + st, 2 * inner + 2 * st], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])             # [B,S,nh]

    conv_in = jnp.concatenate([xin, b_in, c_in], axis=-1)
    tail = cache.conv if cache is not None else None
    conv_out, new_tail = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], tail)
    xin, b_in, c_in = jnp.split(conv_out, [inner, inner + st], axis=-1)

    if cache is None:
        xh = xin.reshape(bsz, s, nh, hd)
        y, final_state = ssd_chunked(xh, dt, params["a_log"], b_in, c_in,
                                     min(cfg.ssm_chunk, s))
        new_cache = None
    else:
        xh = xin.reshape(bsz, nh, hd)
        y, new_state = ssd_decode_step(xh, dt[:, 0], params["a_log"],
                                       b_in[:, 0], c_in[:, 0], cache.state)
        y = y[:, None]                                       # [B,1,nh,hd]
        new_cache = SSMCache(state=new_state, conv=new_tail)

    y = y + params["d_skip"][None, None, :, None] * (
        xin.reshape(bsz, s, nh, hd) if cache is None
        else xh[:, None])
    y = y.reshape(bsz, s, inner)
    y = L.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if cache is not None:
        return out, new_cache
    return out, None


def init_ssm_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> SSMCache:
    inner, nh, hd, st, conv_ch = _dims(cfg)
    return SSMCache(
        state=jnp.zeros((batch, nh, hd, st), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype))
