"""Shared neural-net layers: norms, initialisers, embeddings, RoPE / M-RoPE,
gated MLPs. Pure functions over explicit parameter dicts (no framework)."""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Array]


# --------------------------------------------------------------------------
# Initialisers
# --------------------------------------------------------------------------

def dense_init(rng: Array, in_dim: int, out_dim: int,
               dtype=jnp.float32, scale: Optional[float] = None) -> Array:
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (std * jax.random.truncated_normal(
        rng, -2.0, 2.0, (in_dim, out_dim), jnp.float32)).astype(dtype)


def embed_init(rng: Array, vocab: int, dim: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32)
            / math.sqrt(dim)).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: Array, weight: Array, eps: float = 1e-6,
             plus_one: bool = True) -> Array:
    """RMSNorm with (1 + w) parameterisation (gemma convention)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + weight) if plus_one else weight
    return (x * w.astype(jnp.float32)).astype(dtype)


def layer_norm(x: Array, weight: Array, bias: Array,
               eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def apply_norm(x: Array, params: Params, kind: str, eps: float) -> Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"], eps)
    return layer_norm(x, params["scale"], params["bias"], eps)


def init_norm(rng: Array, dim: int, kind: str, dtype=jnp.float32) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype),
            "bias": jnp.zeros((dim,), dtype)}


# --------------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL's multimodal M-RoPE)
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                      # [head_dim/2]


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B,S,D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions_thw: Array, theta: float,
                sections: Tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, D]; positions_thw: [3, B, S] — temporal/height/width position
    ids. The D/2 frequency slots are split into ``sections`` (t, h, w); each
    section rotates by its own positional stream. Text tokens carry identical
    t=h=w ids, recovering vanilla RoPE exactly.
    """
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_frequencies(x.shape[-1], theta)          # [D/2]
    # Build per-slot angles by selecting the positional stream per section.
    split_points = []
    acc = 0
    for s in sections[:-1]:
        acc += s
        split_points.append(acc)
    section_id = jnp.zeros((d_half,), jnp.int32)
    for i, sp in enumerate(split_points):
        section_id = section_id + (jnp.arange(d_half) >= sp).astype(jnp.int32)
    # positions_thw: [3, B, S] -> gather per slot -> [B, S, D/2]
    pos = jnp.take(positions_thw, section_id, axis=0)      # [D/2 -> selects]
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)     # [B, S, D/2]
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope_tables(positions: Array, head_dim: int,
                theta: float) -> Tuple[Array, Array]:
    """Precompute (cos, sin) [B, S, D/2] once per step — layer-invariant, so
    hoisting this out of the layer scan removes per-layer trig + gathers
    (a measured collective/memory win, EXPERIMENTS.md §Perf)."""
    freqs = rope_frequencies(head_dim, theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def mrope_tables(positions_thw: Array, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]) -> Tuple[Array, Array]:
    """M-RoPE (cos, sin) tables [B, S, D/2] from [3, B, S] position ids."""
    d_half = head_dim // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_frequencies(head_dim, theta)
    section_id = jnp.zeros((d_half,), jnp.int32)
    acc = 0
    for s in sections[:-1]:
        acc += s
        section_id = section_id + (jnp.arange(d_half) >= acc).astype(jnp.int32)
    pos = jnp.take(positions_thw, section_id, axis=0)      # [D/2, B, S]
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)     # [B, S, D/2]
    angles = pos * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rotary(x: Array, cos: Array, sin: Array) -> Array:
    """x: [B, S, H, D]; cos/sin: [B, S, D/2]."""
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> Array:
    """Whisper-style fixed sinusoidal positional embeddings [S, D]."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10_000.0) * idx / max(dim // 2 - 1, 1))
    angles = pos * inv
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def constrain(x: Array, batch_axes, tail) -> Array:
    """with_sharding_constraint(P(batch_axes, *tail)) when axes are set.

    MaxText-style activation annotations: without them GSPMD sometimes keeps
    FSDP-sharded weights sharded on the contracting dim and all-reduces
    activation-sized partial sums over the data axis (measured: 300 s of
    collectives per step on qwen2-vl train_4k — EXPERIMENTS.md §Perf)."""
    if not batch_axes:
        return x
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    return _jax.lax.with_sharding_constraint(x, P(tuple(batch_axes), *tail))


def _act(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def init_mlp(rng: Array, d_model: int, d_ff: int, gated: bool,
             dtype=jnp.float32) -> Params:
    k = jax.random.split(rng, 3)
    p = {"w_up": dense_init(k[0], d_model, d_ff, dtype),
         "w_down": dense_init(k[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(k[2], d_model, d_ff, dtype)
    return p


def apply_mlp(params: Params, x: Array, activation: str, gated: bool,
              batch_axes=(), model_axis: str = "model") -> Array:
    up = constrain(x @ params["w_up"], batch_axes, (None, model_axis))
    if gated:
        gate = constrain(x @ params["w_gate"], batch_axes,
                         (None, model_axis))
        up = _act(gate, activation) * up
    else:
        up = _act(up, activation)
    return constrain(up @ params["w_down"], batch_axes, (None, None))


def softcap(x: Array, cap: float) -> Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def token_nll(logits: Array, labels: Array) -> Array:
    """Per-token cross-entropy that stays vocab-parallel.

    Uses logsumexp + masked-reduce instead of ``take_along_axis``: a gather
    along a sharded vocab axis forces GSPMD to all-gather the full logits
    (e.g. 67 GB/device at [16, 4096, 256000] f32), whereas select+reduce
    partial-sums locally and all-reduces only [B, S] scalars.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None].astype(jnp.int32),
                             logits, 0.0), axis=-1)
    return lse - gold
