"""repro.models — architecture zoo: dense/MoE/SSM/hybrid decoders,
whisper enc-dec, Qwen2-VL backbone, CNN/ResNet FL tasks."""

from repro.models.config import ModelConfig
from repro.models.transformer import TransformerLM
from repro.models.encdec import EncoderDecoderLM
from repro.models.cnn import CNNTask, ResNetTask, MLPTask
from repro.models.flash import flash_attention, flash_decode, FlashConfig
from repro.models.vlm import mrope_positions, mrope_decode_positions
