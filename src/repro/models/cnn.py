"""Image-classification tasks for the FL experiments (paper Sec. VII-A).

* ``CNNTask`` — a small conv net in the spirit of the paper's FEMNIST CNN
  (two conv blocks + two dense layers).
* ``ResNetTask`` — a compact pre-activation residual network standing in for
  ResNet-18 on CIFAR-sized inputs (the paper's CIFAR-10 model), implemented
  without batch-norm (group-norm-free RMS scaling) so client updates are
  aggregation-safe (no running statistics to merge — a known FL pitfall).
* ``MLPTask`` — cheapest smoke-test task.

All implement the ``repro.fl.client.Task`` protocol: init / loss_fn /
metrics over {"x": images NHWC, "y": int labels}.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array
PyTree = Any


def _conv_init(rng: Array, kh: int, kw: int, cin: int, cout: int) -> Array:
    fan_in = kh * kw * cin
    return (jax.random.truncated_normal(rng, -2, 2, (kh, kw, cin, cout),
                                        jnp.float32) / jnp.sqrt(fan_in))


def _conv(x: Array, w: Array, stride: int = 1) -> Array:
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _xent(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(
        logp, labels[:, None].astype(jnp.int32), axis=-1))


def _accuracy(logits: Array, labels: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class CNNTask:
    """conv(32) -> conv(64) -> dense(128) -> dense(classes), silu + pooling."""
    image_shape: Tuple[int, int, int] = (28, 28, 1)
    num_classes: int = 62
    width: int = 32

    def init(self, rng: Array) -> PyTree:
        h, w, c = self.image_shape
        k = jax.random.split(rng, 4)
        wd = self.width
        flat = (h // 4) * (w // 4) * 2 * wd
        return {
            "c1": _conv_init(k[0], 3, 3, c, wd),
            "c2": _conv_init(k[1], 3, 3, wd, 2 * wd),
            "d1": L.dense_init(k[2], flat, 128),
            "b1": jnp.zeros((128,), jnp.float32),
            "d2": L.dense_init(k[3], 128, self.num_classes),
            "b2": jnp.zeros((self.num_classes,), jnp.float32),
        }

    def logits(self, params: PyTree, x: Array) -> Array:
        x = jax.nn.silu(_conv(x, params["c1"]))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        x = jax.nn.silu(_conv(x, params["c2"]))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.silu(x @ params["d1"] + params["b1"])
        return x @ params["d2"] + params["b2"]

    def loss_fn(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        return _xent(self.logits(params, batch["x"]), batch["y"])

    def metrics(self, params: PyTree, batch: Dict[str, Array]) -> Dict:
        lg = self.logits(params, batch["x"])
        return {"accuracy": _accuracy(lg, batch["y"]),
                "loss": _xent(lg, batch["y"])}


@dataclasses.dataclass(frozen=True)
class ResNetTask:
    """Pre-activation residual CNN (norm-free, FL-aggregation-safe)."""
    image_shape: Tuple[int, int, int] = (32, 32, 3)
    num_classes: int = 10
    width: int = 32
    blocks_per_stage: int = 2

    def init(self, rng: Array) -> PyTree:
        h, w, c = self.image_shape
        keys = iter(jax.random.split(rng, 64))
        p: Dict[str, Array] = {"stem": _conv_init(next(keys), 3, 3, c,
                                                  self.width)}
        cin = self.width
        for stage in range(3):
            cout = self.width * (2 ** stage)
            for b in range(self.blocks_per_stage):
                pre = f"s{stage}b{b}"
                p[f"{pre}_c1"] = _conv_init(next(keys), 3, 3, cin, cout)
                p[f"{pre}_c2"] = _conv_init(next(keys), 3, 3, cout, cout)
                if cin != cout:
                    p[f"{pre}_proj"] = _conv_init(next(keys), 1, 1, cin, cout)
                cin = cout
        p["head"] = L.dense_init(next(keys), cin, self.num_classes)
        p["head_b"] = jnp.zeros((self.num_classes,), jnp.float32)
        return p

    def logits(self, params: PyTree, x: Array) -> Array:
        x = _conv(x, params["stem"])
        cin = self.width
        for stage in range(3):
            cout = self.width * (2 ** stage)
            stride = 2 if stage > 0 else 1
            for b in range(self.blocks_per_stage):
                pre = f"s{stage}b{b}"
                st = stride if b == 0 else 1
                h = jax.nn.silu(x)
                h = _conv(h, params[f"{pre}_c1"], st)
                h = jax.nn.silu(h)
                h = _conv(h, params[f"{pre}_c2"])
                short = x
                if f"{pre}_proj" in params:
                    short = _conv(x, params[f"{pre}_proj"], st)
                elif st > 1:
                    short = x[:, ::st, ::st, :]
                x = short + 0.5 * h
                cin = cout
        x = jnp.mean(jax.nn.silu(x), axis=(1, 2))
        return x @ params["head"] + params["head_b"]

    def loss_fn(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        return _xent(self.logits(params, batch["x"]), batch["y"])

    def metrics(self, params: PyTree, batch: Dict[str, Array]) -> Dict:
        lg = self.logits(params, batch["x"])
        return {"accuracy": _accuracy(lg, batch["y"]),
                "loss": _xent(lg, batch["y"])}


@dataclasses.dataclass(frozen=True)
class MLPTask:
    input_dim: int = 3072
    num_classes: int = 10
    hidden: int = 128

    def init(self, rng: Array) -> PyTree:
        k = jax.random.split(rng, 2)
        return {"w1": L.dense_init(k[0], self.input_dim, self.hidden),
                "b1": jnp.zeros((self.hidden,), jnp.float32),
                "w2": L.dense_init(k[1], self.hidden, self.num_classes),
                "b2": jnp.zeros((self.num_classes,), jnp.float32)}

    def logits(self, params: PyTree, x: Array) -> Array:
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.silu(x @ params["w1"] + params["b1"])
        return x @ params["w2"] + params["b2"]

    def loss_fn(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        return _xent(self.logits(params, batch["x"]), batch["y"])

    def metrics(self, params: PyTree, batch: Dict[str, Array]) -> Dict:
        lg = self.logits(params, batch["x"])
        return {"accuracy": _accuracy(lg, batch["y"]),
                "loss": _xent(lg, batch["y"])}
