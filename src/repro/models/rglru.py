"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          # input gate
    a_t = a^(c * r_t),  a = sigmoid(lambda_param),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence is evaluated with ``jax.lax.associative_scan`` (log-
depth on TPU) for train/prefill and as a single fused step for decode —
constant-size state makes ``long_500k`` feasible for this family.

Block structure (Griffin recurrent block):
    in: x -> [branch y: linear -> gelu] ; [branch u: linear -> causal conv ->
    RG-LRU] ; out = W_out (y * u)
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Array]

_C = 8.0


class RGLRUCache(NamedTuple):
    h: Array          # [B, width]
    conv: Array       # [B, conv_width - 1, width]


def init_rglru(rng: Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    width = cfg.rglru_width or d
    k = jax.random.split(rng, 6)
    # init lambda so a in ~(0.9, 0.999): sigmoid(lam)^c in that band
    u = jax.random.uniform(k[0], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / _C)) / (1.0 - u ** (1.0 / _C)))
    return {
        "w_y": L.dense_init(k[1], d, width, dtype),          # gelu branch
        "w_u": L.dense_init(k[2], d, width, dtype),          # recurrent branch
        "conv_w": (0.1 * jax.random.normal(
            k[3], (cfg.rglru_conv_width, width), jnp.float32)).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": L.dense_init(k[4], width, width, dtype),
        "b_a": jnp.zeros((width,), dtype),
        "w_x": L.dense_init(k[5], width, width, dtype),
        "b_x": jnp.zeros((width,), dtype),
        "lam": lam.astype(dtype),
        "w_out": L.dense_init(jax.random.fold_in(rng, 7), width, d, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 tail: Optional[Array]) -> Tuple[Array, Array]:
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    new_tail = xp[:, -(width - 1):, :] if width > 1 else tail
    return y + b, new_tail


def rglru_scan(u: Array, params: Params, h0: Optional[Array] = None
               ) -> Tuple[Array, Array]:
    """Linear recurrence via associative scan. u: [B,S,W] -> (y, h_last)."""
    r = jax.nn.sigmoid(u @ params["w_a"] + params["b_a"])
    i = jax.nn.sigmoid(u @ params["w_x"] + params["b_x"])
    log_a0 = jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    log_a = _C * r.astype(jnp.float32) * log_a0               # [B,S,W]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * \
        (i.astype(jnp.float32) * u.astype(jnp.float32))

    if h0 is not None:
        # fold the initial state into the first step's additive term
        gated = gated.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(u.dtype), h[:, -1, :]


def rglru_step(u: Array, params: Params, h: Array) -> Tuple[Array, Array]:
    """Single decode step. u: [B,W], h: [B,W]."""
    r = jax.nn.sigmoid(u @ params["w_a"] + params["b_a"])
    i = jax.nn.sigmoid(u @ params["w_x"] + params["b_x"])
    log_a0 = jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    a = jnp.exp(_C * r.astype(jnp.float32) * log_a0)
    h_new = a * h.astype(jnp.float32) + \
        jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * \
        (i.astype(jnp.float32) * u.astype(jnp.float32))
    return h_new.astype(u.dtype), h_new


def apply_rglru(params: Params, x: Array, cfg: ModelConfig,
                cache: Optional[RGLRUCache] = None
                ) -> Tuple[Array, Optional[RGLRUCache]]:
    """Griffin recurrent block; decode when cache is not None (S == 1)."""
    y = jax.nn.gelu(x @ params["w_y"], approximate=True)
    u = x @ params["w_u"]
    tail = cache.conv if cache is not None else None
    u, new_tail = _causal_conv(u, params["conv_w"], params["conv_b"], tail)

    if cache is None:
        hseq, _ = rglru_scan(u, params)
        out = (y * hseq) @ params["w_out"]
        return out, None

    h_new, _ = rglru_step(u[:, 0, :], params, cache.h)
    out = (y[:, 0, :] * h_new)[:, None, :] @ params["w_out"]
    return out, RGLRUCache(h=h_new, conv=new_tail)


def init_rglru_cache(batch: int, cfg: ModelConfig,
                     dtype=jnp.float32) -> RGLRUCache:
    width = cfg.rglru_width or cfg.d_model
    return RGLRUCache(
        h=jnp.zeros((batch, width), jnp.float32),
        conv=jnp.zeros((batch, cfg.rglru_conv_width - 1, width), dtype))
