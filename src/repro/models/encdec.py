"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the mel-spectrogram + conv feature extractor is a STUB:
the model consumes precomputed frame embeddings ``[B, T_enc, d]`` from
``input_specs()``. Everything downstream is real: a bidirectional encoder
with fixed sinusoidal positions, and a causal decoder with learned positions,
self-attention KV caches and per-layer cross-attention over encoder states.

Whisper uses plain MHA + LayerNorm + non-gated GELU MLPs; we honour that via
the config (norm="layernorm", gated_mlp=False, rope_type="none").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any


def _init_enc_layer(rng: Array, cfg: ModelConfig, dtype) -> PyTree:
    k = jax.random.split(rng, 4)
    return {
        "attn_norm": L.init_norm(k[0], cfg.d_model, cfg.norm, dtype),
        "attn": attn.init_attention(k[1], cfg, dtype),
        "mlp_norm": L.init_norm(k[2], cfg.d_model, cfg.norm, dtype),
        "mlp": L.init_mlp(k[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def _init_dec_layer(rng: Array, cfg: ModelConfig, dtype) -> PyTree:
    k = jax.random.split(rng, 6)
    return {
        "self_norm": L.init_norm(k[0], cfg.d_model, cfg.norm, dtype),
        "self_attn": attn.init_attention(k[1], cfg, dtype),
        "cross_norm": L.init_norm(k[2], cfg.d_model, cfg.norm, dtype),
        "cross_attn": attn.init_attention(k[3], cfg, dtype),
        "mlp_norm": L.init_norm(k[4], cfg.d_model, cfg.norm, dtype),
        "mlp": L.init_mlp(k[5], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
    }


@dataclasses.dataclass(frozen=True)
class EncoderDecoderLM:
    cfg: ModelConfig

    def init(self, rng: Array) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(rng, 6)
        enc_rngs = jax.random.split(keys[0], cfg.encoder_layers)
        dec_rngs = jax.random.split(keys[1], cfg.num_layers)
        return {
            "embed": L.embed_init(keys[2], cfg.padded_vocab, cfg.d_model,
                                  dtype),
            "dec_pos": (0.01 * jax.random.normal(
                keys[3], (cfg.max_position if cfg.max_position < 1 << 16
                          else 1 << 16, cfg.d_model),
                jnp.float32)).astype(dtype),
            "enc_layers": jax.vmap(
                lambda r: _init_enc_layer(r, cfg, dtype))(enc_rngs),
            "dec_layers": jax.vmap(
                lambda r: _init_dec_layer(r, cfg, dtype))(dec_rngs),
            "enc_final_norm": L.init_norm(keys[4], cfg.d_model, cfg.norm,
                                          dtype),
            "final_norm": L.init_norm(keys[5], cfg.d_model, cfg.norm, dtype),
        }

    # -- encoder -----------------------------------------------------------

    def encode(self, params: PyTree, frame_embeds: Array) -> Array:
        """frame_embeds: [B, T_enc, d] (stubbed conv frontend output)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, t, d = frame_embeds.shape
        x = frame_embeds.astype(dtype) + \
            L.sinusoidal_positions(t, d).astype(dtype)[None]

        def body(x, p):
            h = L.apply_norm(x, p["attn_norm"], cfg.norm, cfg.norm_eps)
            out, _ = attn.attention(p["attn"], h, cfg, causal=False,
                                    positions=None)
            x = x + out
            h = L.apply_norm(x, p["mlp_norm"], cfg.norm, cfg.norm_eps)
            x = x + L.apply_mlp(p["mlp"], h, cfg.activation, cfg.gated_mlp,
                               cfg.batch_axes, cfg.model_axis)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.apply_norm(x, params["enc_final_norm"], cfg.norm,
                            cfg.norm_eps)

    # -- decoder -----------------------------------------------------------

    def _dec_embed(self, params, tokens, position_offset=0):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
        s = tokens.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], position_offset, s, axis=0)
        return x + pos.astype(dtype)[None]

    def decode(self, params: PyTree, tokens: Array, enc_states: Array, *,
               mode: str = "train",
               self_cache: Optional[PyTree] = None,
               cache_index: Optional[Array] = None
               ) -> Tuple[Array, Optional[PyTree]]:
        cfg = self.cfg
        b, s = tokens.shape
        offset = cache_index if mode == "decode" else 0
        x = self._dec_embed(params, tokens,
                            offset if mode == "decode" else 0)

        def body(carry, slices):
            x = carry
            p, cache = slices
            h = L.apply_norm(x, p["self_norm"], cfg.norm, cfg.norm_eps)
            kv_cache = cache if mode == "decode" else None
            out, new_cache = attn.attention(
                p["self_attn"], h, cfg, kv_cache=kv_cache,
                cache_index=cache_index, positions=None)
            if mode == "prefill":
                k = attn._split_heads(h @ p["self_attn"]["wk"],
                                      cfg.num_kv_heads)
                v = attn._split_heads(h @ p["self_attn"]["wv"],
                                      cfg.num_kv_heads)
                new_cache = {"k": k, "v": v}
            x = x + out
            h = L.apply_norm(x, p["cross_norm"], cfg.norm, cfg.norm_eps)
            out, _ = attn.attention(p["cross_attn"], h, cfg,
                                    kv_source=enc_states, causal=False)
            x = x + out
            h = L.apply_norm(x, p["mlp_norm"], cfg.norm, cfg.norm_eps)
            x = x + L.apply_mlp(p["mlp"], h, cfg.activation, cfg.gated_mlp,
                               cfg.batch_axes, cfg.model_axis)
            return x, (new_cache if new_cache is not None
                       else jnp.zeros((), jnp.float32))

        n_dec = cfg.num_layers
        dummy = jnp.zeros((n_dec,), jnp.float32)
        xs = (params["dec_layers"],
              self_cache if mode == "decode" else dummy)
        x, caches = jax.lax.scan(body, x, xs)
        x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            params["embed"].astype(jnp.float32))
        if cfg.padded_vocab != cfg.vocab_size:
            iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(iota < cfg.vocab_size, logits, -1e30)
        return logits, (caches if mode in ("prefill", "decode") else None)

    # -- task API ------------------------------------------------------------

    def apply(self, params: PyTree, tokens: Array, *,
              frame_embeds: Array, mode: str = "train"):
        enc = self.encode(params, frame_embeds)
        logits, cache = self.decode(params, tokens, enc, mode=mode)
        return logits, jnp.zeros((), jnp.float32), cache

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.float32):
        cfg = self.cfg
        one = attn.init_kv_cache(batch, seq_len, cfg, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), one)

    def decode_step(self, params: PyTree, cache: PyTree, tokens: Array,
                    cache_index: Array, enc_states: Array):
        logits, new_cache = self.decode(
            params, tokens, enc_states, mode="decode", self_cache=cache,
            cache_index=cache_index)
        return logits, new_cache

    def loss(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        logits, _, _ = self.apply(params, batch["tokens"],
                                  frame_embeds=batch["frame_embeds"])
        return jnp.mean(L.token_nll(logits, batch["labels"]))
