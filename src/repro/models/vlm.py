"""Qwen2-VL backbone helpers (arXiv:2409.12191).

The vision encoder (ViT + merger) is STUBBED per the assignment: callers
supply patch embeddings ``[B, P, d_model]`` which overwrite the first P token
slots (see ``TransformerLM._embed``). What we implement faithfully is the
language decoder with **M-RoPE**: 3-D (temporal, height, width) position ids,
where vision patches advance (h, w) over the dynamic-resolution grid at a
fixed temporal position, and text tokens resume ordinary sequential positions
after the vision span.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def mrope_positions(batch: int, seq_len: int, num_patches: int,
                    grid_hw: Tuple[int, int] | None = None) -> Array:
    """Build [3, B, S] (t, h, w) position ids, vision-prefix layout.

    Vision patches occupy positions [0, P): t = 0, (h, w) walk the patch
    grid. Text tokens occupy [P, S): t = h = w = t0 + i (vanilla RoPE
    behaviour), with t0 = max(grid) + 1 as in the Qwen2-VL reference.
    """
    if num_patches == 0:
        pos = jnp.arange(seq_len, dtype=jnp.int32)
        pos = jnp.broadcast_to(pos[None], (batch, seq_len))
        return jnp.stack([pos, pos, pos], axis=0)

    if grid_hw is None:
        side = int(math.ceil(math.sqrt(num_patches)))
        grid_hw = (side, side)
    gh, gw = grid_hw

    idx = jnp.arange(seq_len, dtype=jnp.int32)
    is_vision = idx < num_patches
    vh = jnp.minimum(idx // gw, gh - 1)
    vw = idx % gw
    t0 = max(gh, gw)                     # text positions start past the grid
    text_pos = t0 + (idx - num_patches)

    t = jnp.where(is_vision, 0, text_pos)
    h = jnp.where(is_vision, vh, text_pos)
    w = jnp.where(is_vision, vw, text_pos)
    pos = jnp.stack([t, h, w], axis=0)                     # [3, S]
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq_len))


def mrope_decode_positions(batch: int, cache_index: Array,
                           num_patches: int,
                           grid_hw: Tuple[int, int] | None = None) -> Array:
    """[3, B, 1] positions for a single decode step at ``cache_index``."""
    if grid_hw is None:
        side = int(math.ceil(math.sqrt(max(num_patches, 1))))
        grid_hw = (side, side)
    t0 = max(grid_hw)
    pos = t0 + (cache_index - num_patches)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None],
                           (batch, 1))
    return jnp.stack([pos, pos, pos], axis=0)
