"""Blockwise (flash) attention in pure JAX with a manual two-pass VJP.

Never materialises the [Sq, Sk] score matrix: the forward pass runs an
online-softmax over KV blocks inside a scan over Q blocks; the backward pass
recomputes per-block probabilities from the saved log-sum-exp (the standard
FlashAttention-2 recipe). This is the memory-roofline-critical path for
``train_4k`` and ``prefill_32k`` — the naive path would need O(B·H·S²)
bytes (e.g. 34 GB/layer/device for yi-9b at S=4096, b_local=16).

Supports: GQA, causal masking, sliding windows, logit soft-capping and a
decode mode (Sq == 1 against a long cache). Used as the XLA lowering for the
mesh dry-run and as the numerical oracle for the Pallas TPU kernel
(`repro.kernels.flash_attention`).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class FlashConfig:
    block_q: int = 512
    block_kv: int = 512
    causal: bool = True
    window: int = 0              # 0 => unbounded
    softcap: float = 0.0
    scale: float = 1.0
    q_offset: int = 0            # decode: query position offset
    kv_valid_len: int = -1       # decode: valid cache length (-1 => all)


def _pad_to(x: Array, axis: int, multiple: int) -> Tuple[Array, int]:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, pad


def _block_mask(qpos: Array, kpos: Array, cfg: FlashConfig) -> Array:
    """[bq, bkv] boolean mask from absolute positions."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if cfg.causal:
        m &= kpos[None, :] <= qpos[:, None]
    if cfg.window > 0:
        m &= kpos[None, :] > qpos[:, None] - cfg.window
    if cfg.kv_valid_len >= 0:
        m &= kpos[None, :] < cfg.kv_valid_len
    return m


def _scores(qb: Array, kb: Array, cfg: FlashConfig) -> Array:
    """qb: [B,bq,nq,D], kb: [B,bkv,nkv,D] -> raw logits [B,nq,bq,bkv]."""
    b, bq, nq, d = qb.shape
    nkv = kb.shape[2]
    group = nq // nkv
    qg = qb.reshape(b, bq, nkv, group, d)
    s = jnp.einsum("bsngd,btnd->bngst", qg.astype(jnp.float32),
                   kb.astype(jnp.float32)) * cfg.scale
    return s.reshape(b, nq, bq, kb.shape[1])


def _cap(logits: Array, cfg: FlashConfig) -> Array:
    if cfg.softcap > 0:
        return cfg.softcap * jnp.tanh(logits / cfg.softcap)
    return logits


def _pv(p: Array, vb: Array) -> Array:
    """p: [B,nq,bq,bkv], vb: [B,bkv,nkv,D] -> [B,bq,nq,D]."""
    b, nq, bq, bkv = p.shape
    nkv = vb.shape[2]
    group = nq // nkv
    pg = p.reshape(b, nkv, group, bq, bkv)
    out = jnp.einsum("bngst,btnd->bsngd", pg, vb.astype(jnp.float32))
    return out.reshape(b, bq, nq, vb.shape[3])


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _forward(q: Array, k: Array, v: Array, cfg: FlashConfig
             ) -> Tuple[Array, Array]:
    """Returns (out [B,Sq,nq,D], lse [B,nq,Sq])."""
    b, sq, nq, d = q.shape
    sk = k.shape[1]
    qp, pad_q = _pad_to(q, 1, cfg.block_q)
    kp, pad_k = _pad_to(k, 1, cfg.block_kv)
    vp, _ = _pad_to(v, 1, cfg.block_kv)
    nqb = qp.shape[1] // cfg.block_q
    nkb = kp.shape[1] // cfg.block_kv

    qblocks = jnp.moveaxis(
        qp.reshape(b, nqb, cfg.block_q, nq, d), 1, 0)
    kblocks = jnp.moveaxis(
        kp.reshape(b, nkb, cfg.block_kv, k.shape[2], d), 1, 0)
    vblocks = jnp.moveaxis(
        vp.reshape(b, nkb, cfg.block_kv, v.shape[2], d), 1, 0)

    kv_len_cap = sk if cfg.kv_valid_len < 0 else min(cfg.kv_valid_len, sk)

    def q_step(_, qi_and_block):
        qi, qb = qi_and_block
        qpos = cfg.q_offset + qi * cfg.block_q + jnp.arange(cfg.block_q)

        def kv_step(carry, ki_and_blocks):
            m, l, acc = carry
            ki, kb, vb = ki_and_blocks
            kpos = ki * cfg.block_kv + jnp.arange(cfg.block_kv)
            logits = _cap(_scores(qb, kb, cfg), cfg)
            mask = _block_mask(qpos, kpos,
                               dataclasses.replace(
                                   cfg, q_offset=0,
                                   kv_valid_len=kv_len_cap))
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * jnp.moveaxis(alpha, 1, 2)[..., None] + _pv(p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nq, cfg.block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nq, cfg.block_q), jnp.float32)
        a0 = jnp.zeros((b, cfg.block_q, nq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nkb), kblocks, vblocks))
        l_safe = jnp.maximum(l, 1e-30)
        out_b = acc / jnp.moveaxis(l_safe, 1, 2)[..., None]
        lse_b = m + jnp.log(l_safe)
        return None, (out_b, lse_b)

    _, (out_blocks, lse_blocks) = jax.lax.scan(
        q_step, None, (jnp.arange(nqb), qblocks))
    out = jnp.moveaxis(out_blocks, 0, 1).reshape(b, nqb * cfg.block_q, nq, d)
    lse = jnp.moveaxis(lse_blocks, 0, 2)          # [B,nq,nqb,bq]
    lse = lse.reshape(b, nq, nqb * cfg.block_q)
    return out[:, :sq].astype(q.dtype), lse[:, :, :sq]


# --------------------------------------------------------------------------
# backward (FlashAttention-2 two-pass)
# --------------------------------------------------------------------------

def _backward(q, k, v, out, lse, dout, cfg: FlashConfig):
    b, sq, nq, d = q.shape
    sk = k.shape[1]
    nkv = k.shape[2]
    group = nq // nkv

    qp, _ = _pad_to(q, 1, cfg.block_q)
    op, _ = _pad_to(out.astype(jnp.float32), 1, cfg.block_q)
    dop, _ = _pad_to(dout.astype(jnp.float32), 1, cfg.block_q)
    lsep, _ = _pad_to(lse, 2, cfg.block_q)
    kp, _ = _pad_to(k, 1, cfg.block_kv)
    vp, _ = _pad_to(v, 1, cfg.block_kv)
    nqb = qp.shape[1] // cfg.block_q
    nkb = kp.shape[1] // cfg.block_kv

    delta = jnp.sum(op * dop, axis=-1)            # [B, Sq_pad, nq]
    kv_len_cap = sk if cfg.kv_valid_len < 0 else min(cfg.kv_valid_len, sk)
    mask_cfg = dataclasses.replace(cfg, q_offset=0, kv_valid_len=kv_len_cap)

    qblocks = jnp.moveaxis(qp.reshape(b, nqb, cfg.block_q, nq, d), 1, 0)
    doblocks = jnp.moveaxis(dop.reshape(b, nqb, cfg.block_q, nq, d), 1, 0)
    dblocks = jnp.moveaxis(delta.reshape(b, nqb, cfg.block_q, nq), 1, 0)
    lseblocks = jnp.moveaxis(
        lsep.reshape(b, nq, nqb, cfg.block_q), 2, 0)   # [nqb,B,nq,bq]
    kblocks = jnp.moveaxis(kp.reshape(b, nkb, cfg.block_kv, nkv, d), 1, 0)
    vblocks = jnp.moveaxis(vp.reshape(b, nkb, cfg.block_kv, nkv, d), 1, 0)

    def kv_step(_, ki_and_blocks):
        ki, kb, vb = ki_and_blocks
        kpos = ki * cfg.block_kv + jnp.arange(cfg.block_kv)

        def q_step(carry, qi_and_blocks):
            dk, dv = carry
            qi, qb, dob, db, lseb = qi_and_blocks
            qpos = cfg.q_offset + qi * cfg.block_q + jnp.arange(cfg.block_q)
            raw = _scores(qb, kb, cfg)                    # [B,nq,bq,bkv]
            capped = _cap(raw, cfg)
            mask = _block_mask(qpos, kpos, mask_cfg)
            capped = jnp.where(mask[None, None], capped, NEG_INF)
            p = jnp.exp(capped - lseb[..., None])         # [B,nq,bq,bkv]
            # dp = dout @ v^T  (GQA-aware)
            dog = dob.reshape(b, cfg.block_q, nkv, group, d)
            dp = jnp.einsum("bsngd,btnd->bngst", dog,
                            vb.astype(jnp.float32))
            dp = dp.reshape(b, nq, cfg.block_q, cfg.block_kv)
            dcapped = p * (dp - jnp.moveaxis(db, 1, 2)[..., None])
            if cfg.softcap > 0:
                tanh_term = capped / cfg.softcap
                draw = dcapped * (1.0 - jnp.square(tanh_term))
                draw = jnp.where(mask[None, None], draw, 0.0)
            else:
                draw = jnp.where(mask[None, None], dcapped, 0.0)
            draw = draw * cfg.scale
            # dv_kb += p^T dout ; dk_kb += draw^T q
            pg = p.reshape(b, nkv, group, cfg.block_q, cfg.block_kv)
            dv_add = jnp.einsum("bngst,bsngd->btnd", pg, dog)
            drawg = draw.reshape(b, nkv, group, cfg.block_q, cfg.block_kv)
            qg = qb.reshape(b, cfg.block_q, nkv, group, d).astype(jnp.float32)
            dk_add = jnp.einsum("bngst,bsngd->btnd", drawg, qg)
            # dq for this q block against this kv block
            dq_add = jnp.einsum("bngst,btnd->bsngd", drawg,
                                kb.astype(jnp.float32))
            dq_add = dq_add.reshape(b, cfg.block_q, nq, d)
            return (dk + dk_add, dv + dv_add), dq_add

        dk0 = jnp.zeros((b, cfg.block_kv, nkv, d), jnp.float32)
        dv0 = jnp.zeros((b, cfg.block_kv, nkv, d), jnp.float32)
        (dk, dv), dq_parts = jax.lax.scan(
            q_step, (dk0, dv0),
            (jnp.arange(nqb), qblocks, doblocks, dblocks, lseblocks))
        return None, (dk, dv, dq_parts)

    _, (dk_blocks, dv_blocks, dq_parts) = jax.lax.scan(
        kv_step, None, (jnp.arange(nkb), kblocks, vblocks))
    # dq_parts: [nkb, nqb, B, bq, nq, D] -> sum over kv blocks
    dq = jnp.sum(dq_parts, axis=0)
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, nqb * cfg.block_q, nq, d)
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, nkb * cfg.block_kv, nkv, d)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, nkb * cfg.block_kv, nkv, d)
    return (dq[:, :sq].astype(q.dtype), dk[:, :sk].astype(k.dtype),
            dv[:, :sk].astype(v.dtype))


# --------------------------------------------------------------------------
# public API with custom VJP
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q: Array, k: Array, v: Array, cfg: FlashConfig) -> Array:
    """out = softmax(mask(cap(q k^T * scale))) v, blockwise. [B,S,H,D] in/out."""
    out, _ = _forward(q, k, v, cfg)
    return out


def _fa_fwd(q, k, v, cfg):
    out, lse = _forward(q, k, v, cfg)
    return out, (q, k, v, out, lse)


def _fa_bwd(cfg, res, dout):
    q, k, v, out, lse = res
    return _backward(q, k, v, out, lse, dout, cfg)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_decode(q: Array, k_cache: Array, v_cache: Array, *,
                 scale: float, cache_index: Array, window: int = 0,
                 softcap: float = 0.0, block_kv: int = 512,
                 k_scale: Optional[Array] = None,
                 v_scale: Optional[Array] = None) -> Array:
    """Single-token decode against a long cache, scanning KV blocks.

    q: [B,1,nq,D]; caches [B,S,nkv,D]; cache_index: traced scalar — masking
    uses it dynamically so the whole cache is scanned but invalid slots
    contribute zero mass (flash-decode; no dynamic shapes needed).

    int8 caches: pass per-(token, head) ``k_scale``/``v_scale`` [B,S,nkv];
    blocks are dequantised in-register so only int8 bytes stream from HBM.
    """
    b, _, nq, d = q.shape
    sk = k_cache.shape[1]
    nkv = k_cache.shape[2]
    kp, _ = _pad_to(k_cache, 1, block_kv)
    vp, _ = _pad_to(v_cache, 1, block_kv)
    nkb = kp.shape[1] // block_kv
    kblocks = jnp.moveaxis(kp.reshape(b, nkb, block_kv, nkv, d), 1, 0)
    vblocks = jnp.moveaxis(vp.reshape(b, nkb, block_kv, nkv, d), 1, 0)
    quant = k_scale is not None
    if quant:
        ksp, _ = _pad_to(k_scale[..., None], 1, block_kv)
        vsp, _ = _pad_to(v_scale[..., None], 1, block_kv)
        ksblocks = jnp.moveaxis(
            ksp.reshape(b, nkb, block_kv, nkv, 1), 1, 0)
        vsblocks = jnp.moveaxis(
            vsp.reshape(b, nkb, block_kv, nkv, 1), 1, 0)
    else:
        ksblocks = jnp.zeros((nkb, 1, 1, 1, 1), jnp.float32)
        vsblocks = ksblocks
    cfg = FlashConfig(block_q=1, block_kv=block_kv, causal=False,
                      window=window, softcap=softcap, scale=scale)

    qpos = cache_index                                   # scalar

    def kv_step(carry, ki_and_blocks):
        m, l, acc = carry
        ki, kb, vb, ksb, vsb = ki_and_blocks
        if quant:
            kb = kb.astype(jnp.float32) * ksb
            vb = vb.astype(jnp.float32) * vsb
        kpos = ki * block_kv + jnp.arange(block_kv)
        logits = _cap(_scores(q, kb, cfg), cfg)          # [B,nq,1,bkv]
        mask = kpos[None, :] <= qpos                     # [1,bkv]
        if window > 0:
            mask &= kpos[None, :] > qpos - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * jnp.moveaxis(alpha, 1, 2)[..., None] + _pv(p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, 1), jnp.float32)
    a0 = jnp.zeros((b, 1, nq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        kv_step, (m0, l0, a0),
        (jnp.arange(nkb), kblocks, vblocks, ksblocks, vsblocks))
    out = acc / jnp.moveaxis(jnp.maximum(l, 1e-30), 1, 2)[..., None]
    return out.astype(q.dtype)
