"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM backbones;
family-specific fields are ignored by families that do not use them. The
per-architecture instantiations live in ``repro.configs``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // num_heads

    # --- block pattern -----------------------------------------------------
    # Repeating per-layer block pattern; (num_layers - len(suffix)) must be
    # divisible by its length. Entries: "global" (full attn), "local"
    # (sliding window), "recurrent" (RG-LRU), "ssd" (Mamba-2 SSD block).
    # ``block_pattern_suffix`` holds trailing layers that do not fit the
    # repeat (e.g. recurrentgemma's 26 = 8 x (r,r,l) + (r,r)) so the scanned
    # HLO stays O(pattern) instead of O(num_layers) — compile-time critical.
    block_pattern: Tuple[str, ...] = ("global",)
    block_pattern_suffix: Tuple[str, ...] = ()
    window_size: int = 4096           # for "local" blocks

    # --- attention ----------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_type: str = "rope"           # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w head_dim split
    attn_logit_softcap: float = 0.0   # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)

    # --- FFN ----------------------------------------------------------------
    activation: str = "silu"          # silu | gelu
    gated_mlp: bool = True            # GeGLU / SwiGLU

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # token groups for the sort dispatch. Grouping keeps the argsort /
    # scatter / gather *local to a data shard* (set = number of data shards
    # by the launcher): without it GSPMD lowers cross-shard token gathers
    # into O(T^2) masked contractions — see EXPERIMENTS.md §Perf.
    moe_groups: int = 1
    moe_dispatch: str = "sort"        # sort | capacity (ablation toggle)
    local_ring_cache: bool = True     # window-sized local KV (ablation)
    quantized_kv: bool = False        # int8 global-layer KV caches (+scales)

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state_dim: int = 128
    ssm_expand: int = 2
    ssm_heads: int = 24               # v-heads of SSD
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv_width: int = 4

    # --- recurrent (RG-LRU / Griffin) ----------------------------------------
    rglru_width: Optional[int] = None  # default d_model
    rglru_conv_width: int = 4

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500       # whisper: 30 s of audio frames
    frontend_dim: Optional[int] = None  # stubbed frontend embedding width

    # --- VLM ------------------------------------------------------------------
    vision_patches: int = 0           # stub patch-embedding count per sample
    vision_dim: Optional[int] = None

    # --- misc ------------------------------------------------------------------
    attn_impl: str = "auto"           # auto | naive | flash
    flash_block_q: int = 512
    flash_block_kv: int = 512

    # --- distribution hints ---------------------------------------------------
    # When non-empty, the model inserts with_sharding_constraint on the
    # large activations (residual stream, logits). Set by the launcher to
    # the mesh's data axes; empty for single-device runs.
    batch_axes: Tuple[str, ...] = ()
    model_axis: str = "model"

    # pad the embedding/vocab dim to this multiple for shardability (0 =
    # exact vocab). Padded logit slots are masked to -inf so the softmax
    # is unchanged; labels never index them. Standard MaxText practice —
    # set by the launcher for vocabs not divisible by the model axis.
    vocab_pad_multiple: int = 0

    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embedding_scale: bool = False     # gemma: scale embeddings by sqrt(d)
    post_attn_norm: bool = False      # gemma2 sandwich norms
    post_ffn_norm: bool = False
    dtype: str = "float32"            # activation/computation dtype
    param_dtype: str = "float32"
    max_position: int = 1 << 20

    def __post_init__(self):
        body = self.num_layers - len(self.block_pattern_suffix)
        if body < 0 or body % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} minus suffix "
                f"{len(self.block_pattern_suffix)} not divisible by "
                f"block pattern length {len(self.block_pattern)}")
        if self.family == "moe" and (self.num_experts <= 0
                                     or self.experts_per_token <= 0):
            raise ValueError(f"{self.name}: MoE family needs experts")

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_multiple <= 0:
            return self.vocab_size
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def num_groups(self) -> int:
        return (self.num_layers - len(self.block_pattern_suffix)) \
            // len(self.block_pattern)

    @property
    def all_blocks(self) -> Tuple[str, ...]:
        return self.block_pattern * self.num_groups + \
            self.block_pattern_suffix

    @property
    def is_attention_free(self) -> bool:
        return all(b == "ssd" for b in self.all_blocks)

    @property
    def supports_long_context(self) -> bool:
        """True if no block needs quadratic global attention over the cache.

        Pure SSM and recurrent+local hybrids decode in O(window); gemma2's
        alternating local/global still holds a full global KV cache but the
        per-step decode cost is linear in cache length (flash-decode), so we
        treat 'has at least one sub-quadratic mechanism AND explicit support
        flag' in the arch config — see repro.configs.
        """
        return all(b in ("ssd", "recurrent", "local")
                   for b in self.all_blocks)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + norms), exact for
        our parameterisation; used for model-size M in the LROA system model
        and for MODEL_FLOPS in the roofline."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = v * d                       # embedding
        if not self.tie_embeddings:
            total += v * d

        def attn_params() -> int:
            return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        def mlp_params(ff: int) -> int:
            return d * ff * (3 if self.gated_mlp else 2)

        def moe_params() -> int:
            expert = mlp_params(self.d_ff)
            return self.num_experts * expert + d * self.num_experts

        def ssd_params() -> int:
            inner = self.ssm_expand * d
            nh, st = self.ssm_heads, self.ssm_state_dim
            in_proj = d * (2 * inner + 2 * st + nh)
            conv = (inner + 2 * st) * self.ssm_conv_width
            out = inner * d
            return in_proj + conv + out + 2 * nh + inner

        def rglru_params() -> int:
            width = self.rglru_width or d
            return (d * width * 2 + width * d + width * self.rglru_conv_width
                    + 2 * width * width + 2 * width)

        def block_params(kind: str) -> int:
            per = 2 * d                       # pre-norms (attn/mix + mlp)
            if kind in ("global", "local"):
                per += attn_params()
                per += moe_params() if self.family == "moe" \
                    else mlp_params(self.d_ff)
            elif kind == "recurrent":
                per += rglru_params()
                per += mlp_params(self.d_ff)
            elif kind == "ssd":
                per += ssd_params()
            else:
                raise ValueError(kind)
            return per

        total += sum(block_params(kind) for kind in self.all_blocks)
        total += d                            # final norm
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (2 * d + attn_params()
                                         + mlp_params(self.d_ff))
            cross = self.num_layers * (d + attn_params())
            total += enc + cross + self.encoder_seq_len * d  # enc pos-embed
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        expert = d * self.d_ff * (3 if self.gated_mlp else 2)
        inactive = (self.num_experts - self.experts_per_token) * expert
        return int(self.param_count() - self.num_layers * inactive)
