"""Mixture-of-Experts layer: top-k router + capacity-based one-hot dispatch
(GShard/Switch style) with an auxiliary load-balance loss.

The one-hot einsum dispatch is deliberately chosen over gather/sort because
it partitions cleanly under GSPMD: expert weights ``[E, d, ff]`` shard over
the ``model`` ("expert") axis and the dispatch einsums lower to all-to-all
style collectives on the token axis. A dense no-capacity path
(``dispatch="dense"``) is kept as the correctness oracle; EXPERIMENTS.md
§Perf studies the capacity factor as a compute-roofline lever.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array
Params = Dict[str, Array]


def init_moe(rng: Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k = jax.random.split(rng, 4)
    p = {
        "router": L.dense_init(k[0], d, e, dtype),
        "w_up": (L.dense_init(k[1], d, e * ff, dtype)
                 .reshape(d, e, ff).transpose(1, 0, 2)),    # [E, d, ff]
        "w_down": (L.dense_init(k[2], ff, e * d, dtype)
                   .reshape(ff, e, d).transpose(1, 0, 2)),  # [E, ff, d]
    }
    if cfg.gated_mlp:
        p["w_gate"] = (L.dense_init(k[3], d, e * ff, dtype)
                       .reshape(d, e, ff).transpose(1, 0, 2))
    return p


def router_probs(params: Params, x: Array) -> Array:
    """Softmax router logits over experts. x: [..., d] -> [..., E] (f32)."""
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs: Array, expert_mask: Array) -> Array:
    """Switch-style aux loss: E * sum_e (fraction routed) * (mean prob)."""
    e = probs.shape[-1]
    density = jnp.mean(expert_mask.astype(jnp.float32), axis=tuple(
        range(expert_mask.ndim - 1)))          # [E] fraction of slots
    mean_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(density * mean_prob)


def _expert_ffn(params: Params, xe: Array, cfg: ModelConfig) -> Array:
    """Batched per-expert FFN. xe: [E, C, d] -> [E, C, d]."""
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
        up = jax.nn.silu(gate) * up
    else:
        up = jax.nn.silu(up)
    return jnp.einsum("ecf,efd->ecd", up, params["w_down"])


def apply_moe(params: Params, x: Array, cfg: ModelConfig,
              dispatch: str = "sort") -> Tuple[Array, Array]:
    """Returns (output [B,S,d], aux_loss scalar).

    dispatch modes:
      * "sort"     — production path: stable-sort token slots by expert,
        scatter into per-expert capacity buffers, batched expert matmuls,
        gather back. O(T·d) memory; identical keep-set to "capacity".
      * "capacity" — GShard one-hot einsum dispatch; O(T·k·E·C) dispatch
        tensor. Exact same semantics; used as the small-shape oracle.
      * "dense"    — every expert computes every token (drop-free oracle;
        also the decode path where dropping is unacceptable).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, topk = cfg.num_experts, cfg.experts_per_token

    probs = router_probs(params, xt)                       # [T, E]
    top_p, top_idx = jax.lax.top_k(probs, topk)            # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise

    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # [T, k, E]
    aux = load_balance_loss(probs, jnp.max(onehot, axis=1))

    if dispatch == "sort":
        g = max(1, min(cfg.moe_groups, t))
        while t % g:                                        # g must divide T
            g -= 1
        tg = t // g
        capacity = int(max(1, round(cfg.moe_capacity_factor * tg * topk / e)))
        xg = xt.reshape(g, tg, d)
        idx_g = top_idx.reshape(g, tg * topk)               # slot -> expert
        gate_g = top_p.reshape(g, tg * topk)

        def group_dispatch(xt_g, flat_expert, gate):
            """All ops are local to one token group (one data shard)."""
            order = jnp.argsort(flat_expert, stable=True)
            sorted_expert = jnp.take(flat_expert, order)
            sorted_token = order // topk
            onehot_e = jax.nn.one_hot(flat_expert, e, dtype=jnp.float32)
            counts = jnp.sum(onehot_e, axis=0).astype(jnp.int32)   # [E]
            starts = jnp.cumsum(counts) - counts
            pos_in_expert = jnp.arange(tg * topk) - jnp.take(starts,
                                                             sorted_expert)
            keep = pos_in_expert < capacity
            buf_idx = jnp.where(
                keep, sorted_expert * capacity + pos_in_expert, e * capacity)
            gathered = jnp.take(xt_g, sorted_token, axis=0)
            buf = jnp.zeros((e * capacity + 1, d), xt_g.dtype)
            buf = buf.at[buf_idx].set(
                jnp.where(keep[:, None], gathered, 0.0))
            return (buf[:-1].reshape(e, capacity, d), buf_idx, keep,
                    jnp.take(gate, order), sorted_token)

        xe, buf_idx, keep, gate_s, sorted_token = jax.vmap(group_dispatch)(
            xg, idx_g, gate_g)                              # xe [G,E,C,d]
        # keep expert buffers group-sharded (data) and ff tensor-sharded
        # (model) — without the constraints GSPMD has been observed to
        # replicate the [G,E,C,ff] intermediates (EXPERIMENTS.md §Perf).
        ba, ma = cfg.batch_axes, cfg.model_axis
        xe = L.constrain(xe, ba, (None, None, None))
        ye = L.constrain(jnp.einsum("gecd,edf->gecf", xe, params["w_up"]),
                         ba, (None, None, ma))
        if cfg.gated_mlp:
            yg = L.constrain(
                jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]),
                ba, (None, None, ma))
            ye = jax.nn.silu(yg) * ye
        else:
            ye = jax.nn.silu(ye)
        ye = L.constrain(jnp.einsum("gecf,efd->gecd", ye, params["w_down"]),
                         ba, (None, None, None))

        def group_combine(ye_g, buf_idx, keep, gate, sorted_token):
            out_slots = jnp.take(ye_g.reshape(e * capacity, d),
                                 jnp.minimum(buf_idx, e * capacity - 1),
                                 axis=0)
            out_slots = out_slots * (gate * keep)[:, None]
            return jnp.zeros((tg, d), out_slots.dtype).at[sorted_token].add(
                out_slots)

        y = jax.vmap(group_combine)(ye, buf_idx, keep, gate_s, sorted_token)
        return y.reshape(b, s, d).astype(x.dtype), aux

    if dispatch == "dense":
        # Oracle: every expert computes every token, combine by router mass.
        weights = jnp.einsum("tke,tk->te", onehot, top_p)   # [T, E]
        up = jnp.einsum("td,edf->tef", xt, params["w_up"])
        if cfg.gated_mlp:
            gate = jnp.einsum("td,edf->tef", xt, params["w_gate"])
            up = jax.nn.silu(gate) * up
        else:
            up = jax.nn.silu(up)
        out = jnp.einsum("tef,efd->ted", up, params["w_down"])
        y = jnp.einsum("ted,te->td", out, weights)
        return y.reshape(b, s, d).astype(x.dtype), aux

    # --- capacity dispatch (GShard): each expert processes <= C tokens -----
    capacity = int(max(1, round(cfg.moe_capacity_factor * t * topk / e)))
    # position of each (token, slot) within its expert's buffer
    flat_onehot = onehot.reshape(t * topk, e)
    pos_in_expert = (jnp.cumsum(flat_onehot, axis=0) - 1.0)  # [T*k, E]
    pos = jnp.sum(pos_in_expert * flat_onehot, axis=-1)      # [T*k]
    keep = pos < capacity
    cap_onehot = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                                dtype=jnp.float32) * keep[:, None]
    # dispatch tensor [T, k, E, C]
    disp = (flat_onehot[:, :, None] * cap_onehot[:, None, :]
            ).reshape(t, topk, e, capacity)
    combine = disp * top_p[:, :, None, None]                 # router-weighted

    xe = jnp.einsum("tkec,td->ecd", disp, xt)                # [E, C, d]
    ye = _expert_ffn(params, xe, cfg)                        # [E, C, d]
    y = jnp.einsum("tkec,ecd->td", combine, ye)              # [T, d]
    return y.reshape(b, s, d).astype(x.dtype), aux
