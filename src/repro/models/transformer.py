"""Decoder-only LM assembly for all families.

Key idioms (MaxText-style):
  * **Stacked layer parameters + lax.scan over groups** — parameters for each
    position of the repeating ``block_pattern`` are stacked on a leading
    ``num_groups`` axis, and the forward pass scans over groups. The HLO is
    O(1) in depth: essential for compiling 64-layer 314B-param graphs in the
    multi-pod dry-run and for clean roofline accounting.
  * Heterogeneous patterns (gemma2 local/global, recurrentgemma rec/rec/local)
    unroll the (short) pattern inside the scanned group body.
  * One code path serves train, prefill (returns filled caches), and decode
    (single token, in-place cache update).

Params layout:
  {"embed": [V, d], "blocks": {"b0": stacked-tree, "b1": ...},
   "final_norm": {...}, optional "lm_head": [d, V], optional enc-dec extras}
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any


# --------------------------------------------------------------------------
# Per-kind block: init
# --------------------------------------------------------------------------

def _init_block(rng: Array, cfg: ModelConfig, kind: str,
                dtype) -> Dict[str, PyTree]:
    k = jax.random.split(rng, 4)
    p: Dict[str, PyTree] = {"pre_norm": L.init_norm(k[0], cfg.d_model,
                                                    cfg.norm, dtype)}
    if kind in ("global", "local"):
        p["attn"] = attn.init_attention(k[1], cfg, dtype)
        p["mlp_norm"] = L.init_norm(k[2], cfg.d_model, cfg.norm, dtype)
        if cfg.family == "moe":
            p["moe"] = moe_lib.init_moe(k[3], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(k[3], cfg.d_model, cfg.d_ff,
                                  cfg.gated_mlp, dtype)
        if cfg.post_attn_norm:
            p["post_attn_norm"] = L.init_norm(
                jax.random.fold_in(rng, 11), cfg.d_model, cfg.norm, dtype)
        if cfg.post_ffn_norm:
            p["post_ffn_norm"] = L.init_norm(
                jax.random.fold_in(rng, 12), cfg.d_model, cfg.norm, dtype)
    elif kind == "recurrent":
        p["rglru"] = rglru_lib.init_rglru(k[1], cfg, dtype)
        p["mlp_norm"] = L.init_norm(k[2], cfg.d_model, cfg.norm, dtype)
        p["mlp"] = L.init_mlp(k[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                              dtype)
    elif kind == "ssd":
        p["ssd"] = ssm_lib.init_ssd(k[1], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


# --------------------------------------------------------------------------
# Per-kind block: apply (one group slice)
# --------------------------------------------------------------------------

def _apply_block(p: Dict[str, PyTree], x: Array, cfg: ModelConfig, kind: str,
                 *, rope, cache, cache_index,
                 mode: str) -> Tuple[Array, Optional[PyTree], Array]:
    """Returns (x, new_cache, aux_loss). ``rope``: precomputed (cos, sin)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(x, p["pre_norm"], cfg.norm, cfg.norm_eps)

    if kind in ("global", "local"):
        kv_cache = cache if mode == "decode" else None
        out, new_cache = attn.attention(
            p["attn"], h, cfg, kind=kind, rope=rope, kv_cache=kv_cache,
            cache_index=cache_index)
        if mode == "prefill":
            # materialise this layer's K/V for the serving cache
            src = h
            k = attn._split_heads(src @ p["attn"]["wk"], cfg.num_kv_heads)
            v = attn._split_heads(src @ p["attn"]["wv"], cfg.num_kv_heads)
            if rope is not None:
                k = L.apply_rotary(k, *rope)
            if kind == "local" and cfg.local_ring_cache:
                # place the last `window` positions into the ring buffer
                s = k.shape[1]
                ring = min(s, cfg.window_size)
                tail = slice(s - ring, s)
                ring_pos = (jnp.arange(s - ring, s)) % ring
                k_ring = jnp.zeros((k.shape[0], ring) + k.shape[2:],
                                   k.dtype).at[:, ring_pos].set(k[:, tail])
                v_ring = jnp.zeros((v.shape[0], ring) + v.shape[2:],
                                   v.dtype).at[:, ring_pos].set(v[:, tail])
                new_cache = {"k": k_ring, "v": v_ring}
            elif kind == "global" and cfg.quantized_kv:
                kq, ks = attn.quantize_kv(k)
                vq, vs = attn.quantize_kv(v)
                new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": k, "v": v}
        if cfg.post_attn_norm:
            out = L.apply_norm(out, p["post_attn_norm"], cfg.norm,
                               cfg.norm_eps)
        x = x + out
        h2 = L.apply_norm(x, p["mlp_norm"], cfg.norm, cfg.norm_eps)
        if cfg.family == "moe":
            # decode uses the exact dense-dispatch path: at single-token batch
            # sizes the capacity buckets would drop tokens, and serving must
            # be drop-free; train/prefill use the grouped sort dispatch
            # (GShard capacity semantics, O(T d) memory — see moe.apply_moe).
            dispatch = "dense" if mode == "decode" else cfg.moe_dispatch
            out2, aux = moe_lib.apply_moe(p["moe"], h2, cfg, dispatch)
        else:
            out2 = L.apply_mlp(p["mlp"], h2, cfg.activation, cfg.gated_mlp,
                               cfg.batch_axes, cfg.model_axis)
        if cfg.post_ffn_norm:
            out2 = L.apply_norm(out2, p["post_ffn_norm"], cfg.norm,
                                cfg.norm_eps)
        x = x + out2
        return x, new_cache, aux

    if kind == "recurrent":
        rcache = cache if mode == "decode" else None
        out, new_cache = rglru_lib.apply_rglru(p["rglru"], h, cfg, rcache)
        if mode == "prefill":
            # run the scan but also keep the final state for decode
            y = jax.nn.gelu(h @ p["rglru"]["w_y"], approximate=True)
            u0 = h @ p["rglru"]["w_u"]
            u, tail = rglru_lib._causal_conv(
                u0, p["rglru"]["conv_w"], p["rglru"]["conv_b"], None)
            hseq, h_last = rglru_lib.rglru_scan(u, p["rglru"])
            out = (y * hseq) @ p["rglru"]["w_out"]
            new_cache = rglru_lib.RGLRUCache(h=h_last, conv=tail)
        x = x + out
        h2 = L.apply_norm(x, p["mlp_norm"], cfg.norm, cfg.norm_eps)
        x = x + L.apply_mlp(p["mlp"], h2, cfg.activation, cfg.gated_mlp,
                               cfg.batch_axes, cfg.model_axis)
        return x, new_cache, aux

    if kind == "ssd":
        scache = cache if mode == "decode" else None
        out, new_cache = ssm_lib.apply_ssd(p["ssd"], h, cfg, scache)
        if mode == "prefill":
            bsz, s, _ = h.shape
            inner, nh, hd, st, _ = ssm_lib._dims(cfg)
            proj = h @ p["ssd"]["in_proj"]
            z, xin, b_in, c_in, dt = jnp.split(
                proj, [inner, 2 * inner, 2 * inner + st,
                       2 * inner + 2 * st], axis=-1)
            dt = jax.nn.softplus(dt + p["ssd"]["dt_bias"])
            conv_in = jnp.concatenate([xin, b_in, c_in], axis=-1)
            conv_out, tail = ssm_lib._causal_conv(
                conv_in, p["ssd"]["conv_w"], p["ssd"]["conv_b"], None)
            xin2, b2, c2 = jnp.split(conv_out, [inner, inner + st], axis=-1)
            _, final = ssm_lib.ssd_chunked(
                xin2.reshape(bsz, s, nh, hd), dt, p["ssd"]["a_log"], b2, c2,
                min(cfg.ssm_chunk, s))
            new_cache = ssm_lib.SSMCache(state=final, conv=tail)
        x = x + out
        return x, new_cache, aux

    raise ValueError(kind)


# --------------------------------------------------------------------------
# Cache containers
# --------------------------------------------------------------------------

def init_block_cache(batch: int, seq_len: int, cfg: ModelConfig, kind: str,
                     dtype) -> PyTree:
    if kind == "local":
        # ring buffer: window-sized cache regardless of context length
        # (the §Perf memory-term lever — EXPERIMENTS.md)
        ring = min(seq_len, cfg.window_size) if cfg.local_ring_cache \
            else seq_len
        return attn.init_kv_cache(batch, ring, cfg, dtype)
    if kind == "global":
        return attn.init_kv_cache(batch, seq_len, cfg, dtype,
                                  quantized=cfg.quantized_kv)
    if kind == "recurrent":
        return rglru_lib.init_rglru_cache(batch, cfg, dtype)
    if kind == "ssd":
        return ssm_lib.init_ssm_cache(batch, cfg, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# The model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerLM:
    cfg: ModelConfig
    remat: bool = False

    # -- params ------------------------------------------------------------

    def init(self, rng: Array) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(rng, 4)
        params: Dict[str, PyTree] = {
            "embed": L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                  dtype),
            "final_norm": L.init_norm(keys[1], cfg.d_model, cfg.norm, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(keys[2], cfg.d_model,
                                             cfg.padded_vocab, dtype)
        blocks: Dict[str, PyTree] = {}
        for j, kind in enumerate(cfg.block_pattern):
            grp_rngs = jax.random.split(
                jax.random.fold_in(keys[3], j), cfg.num_groups)
            blocks[f"b{j}"] = jax.vmap(
                lambda r: _init_block(r, cfg, kind, dtype))(grp_rngs)
        params["blocks"] = blocks
        if cfg.block_pattern_suffix:
            params["suffix_blocks"] = {
                f"s{j}": _init_block(
                    jax.random.fold_in(keys[3], 1000 + j), cfg, kind, dtype)
                for j, kind in enumerate(cfg.block_pattern_suffix)}
        return params

    # -- caches --------------------------------------------------------------

    def init_cache(self, batch: int, seq_len: int,
                   dtype=jnp.float32) -> PyTree:
        cfg = self.cfg

        def stacked(kind):
            one = init_block_cache(batch, seq_len, cfg, kind, dtype)
            return jax.tree_util.tree_map(
                lambda x: jnp.zeros((cfg.num_groups,) + x.shape, x.dtype), one)

        cache = {f"b{j}": stacked(kind)
                 for j, kind in enumerate(cfg.block_pattern)}
        for j, kind in enumerate(cfg.block_pattern_suffix):
            cache[f"s{j}"] = init_block_cache(batch, seq_len, cfg, kind,
                                              dtype)
        return cache

    # -- forward ---------------------------------------------------------------

    def _scan_blocks(self, params, x, *, rope, cache, cache_index,
                     mode: str):
        cfg = self.cfg
        pattern = cfg.block_pattern

        def group_body(carry, slices):
            x, aux = carry
            block_params, block_cache = slices
            new_caches = {}
            for j, kind in enumerate(pattern):
                c_j = block_cache[f"b{j}"] if mode == "decode" else None
                fn = partial(_apply_block, cfg=cfg, kind=kind, rope=rope,
                             cache_index=cache_index, mode=mode)
                if self.remat and mode == "train":
                    wrapped = jax.checkpoint(
                        lambda p_, x_, fn=fn: fn(p_, x_, cache=None),
                        prevent_cse=False)
                    x, nc, a = wrapped(block_params[f"b{j}"], x)
                else:
                    x, nc, a = fn(block_params[f"b{j}"], x, cache=c_j)
                aux = aux + a
                new_caches[f"b{j}"] = nc if nc is not None else \
                    jnp.zeros((), jnp.float32)
            return (x, aux), new_caches

        aux0 = jnp.zeros((), jnp.float32)
        scan_cache = None
        if cache is not None:
            scan_cache = {k: v for k, v in cache.items()
                          if k.startswith("b")}
        xs = (params["blocks"], scan_cache if scan_cache is not None
              else {f"b{j}": jnp.zeros((cfg.num_groups,), jnp.float32)
                    for j in range(len(pattern))})
        (x, aux), caches_out = jax.lax.scan(group_body, (x, aux0), xs)

        # trailing suffix blocks (unrolled; num_layers not divisible by the
        # pattern, e.g. recurrentgemma's final two recurrent layers)
        for j, kind in enumerate(cfg.block_pattern_suffix):
            key = f"s{j}"
            c_j = cache[key] if mode == "decode" else None
            x, nc, a = _apply_block(
                params["suffix_blocks"][key], x, cfg=cfg, kind=kind,
                rope=rope, cache=c_j, cache_index=cache_index, mode=mode)
            aux = aux + a
            if mode in ("prefill", "decode"):
                caches_out[key] = nc if nc is not None else \
                    jnp.zeros((), jnp.float32)
        return x, aux, caches_out

    def _embed(self, params, tokens, vision_embeds=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
        x = self._constrain(x, (None, None))
        if cfg.embedding_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, dtype))
        if vision_embeds is not None:
            ve = vision_embeds.astype(dtype)
            if cfg.embedding_scale:
                ve = ve * jnp.sqrt(jnp.asarray(cfg.d_model, dtype))
            x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))
        return x

    def _constrain(self, x, spec_tail):
        """with_sharding_constraint when the launcher set batch_axes."""
        cfg = self.cfg
        if not cfg.batch_axes:
            return x
        from jax.sharding import PartitionSpec as P
        spec = P(tuple(cfg.batch_axes), *spec_tail)
        return jax.lax.with_sharding_constraint(x, spec)

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                                params["embed"].astype(jnp.float32))
        else:
            logits = x.astype(jnp.float32) @ params["lm_head"].astype(
                jnp.float32)
        # keep the [B, S, V] tensor vocab-parallel: replicated it is tens of
        # GB per device at 256k vocab (see EXPERIMENTS.md §Perf)
        logits = self._constrain(logits, (None, cfg.model_axis))
        if cfg.final_logit_softcap > 0:
            logits = L.softcap(logits, cfg.final_logit_softcap)
        if cfg.padded_vocab != cfg.vocab_size:
            iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(iota < cfg.vocab_size, logits, -1e30)
        return logits

    def apply(self, params: PyTree, tokens: Array, *,
              positions: Optional[Array] = None,
              positions_thw: Optional[Array] = None,
              vision_embeds: Optional[Array] = None,
              mode: str = "train") -> Tuple[Array, Array, Optional[PyTree]]:
        """Full-sequence forward. Returns (logits, aux_loss, cache|None)."""
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        rope = attn.make_rope_tables(self.cfg, positions, positions_thw)
        x = self._embed(params, tokens, vision_embeds)
        x, aux, caches = self._scan_blocks(
            params, x, rope=rope, cache=None, cache_index=None, mode=mode)
        logits = self._logits(params, x)
        return logits, aux, (caches if mode == "prefill" else None)

    def decode_step(self, params: PyTree, cache: PyTree, tokens: Array,
                    cache_index: Array, *,
                    positions_thw: Optional[Array] = None
                    ) -> Tuple[Array, PyTree]:
        """One-token decode. tokens: [B, 1]; cache_index: scalar int32."""
        b, s = tokens.shape
        assert s == 1
        positions = jnp.full((b, 1), cache_index, jnp.int32)
        rope = attn.make_rope_tables(self.cfg, positions, positions_thw)
        x = self._embed(params, tokens)
        x, _, new_cache = self._scan_blocks(
            params, x, rope=rope, cache=cache, cache_index=cache_index,
            mode="decode")
        logits = self._logits(params, x)
        return logits, new_cache

    # -- losses -------------------------------------------------------------

    def loss(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        logits, aux, _ = self.apply(params, batch["tokens"])
        labels = batch["labels"]
        nll = L.token_nll(logits, labels)
        mask = batch.get("mask")
        if mask is not None:
            nll = nll * mask
            denom = jnp.maximum(jnp.sum(mask), 1.0)
        else:
            denom = nll.size
        ce = jnp.sum(nll) / denom
        return ce + self.cfg.router_aux_loss_coef * aux
