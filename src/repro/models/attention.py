"""Grouped-query attention with causal / sliding-window masking, logit
soft-capping (gemma2), KV caches for decode, and cross-attention (whisper).

The default math path is pure jnp (lowered by XLA — used for CPU tests and
the mesh dry-run); the Pallas flash kernel in ``repro.kernels`` is selected
via ``use_flash=True`` on TPU runs and validated against this path in tests.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.flash import (FlashConfig, flash_attention, flash_decode)

Array = jax.Array
Params = Dict[str, Array]

NEG_INF = -2.0e38


def init_attention(rng: Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    k = jax.random.split(rng, 4)
    return {
        "wq": L.dense_init(k[0], d, nq * hd, dtype),
        "wk": L.dense_init(k[1], d, nkv * hd, dtype),
        "wv": L.dense_init(k[2], d, nkv * hd, dtype),
        "wo": L.dense_init(k[3], nq * hd, d, dtype),
    }


def init_kv_cache(batch: int, seq_len: int, cfg: ModelConfig,
                  dtype=jnp.float32, quantized: bool = False
                  ) -> Dict[str, Array]:
    hd = cfg.resolved_head_dim
    shape = (batch, seq_len, cfg.num_kv_heads, hd)
    if quantized:
        # int8 symmetric per-(token, head) quantisation — halves cache
        # bytes vs bf16 (the long-context decode memory-term lever)
        sshape = (batch, seq_len, cfg.num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def quantize_kv(x: Array) -> Tuple[Array, Array]:
    """x: [B, S, H, D] -> (int8 values, f32 scales [B, S, H])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale[..., None]


def _split_heads(x: Array, num_heads: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, num_heads, -1)


def gqa_scores(q: Array, k: Array, scale: float) -> Array:
    """q: [B,Sq,nq,D], k: [B,Sk,nkv,D] -> logits [B,nq,Sq,Sk] (f32)."""
    b, sq, nq, d = q.shape
    nkv = k.shape[2]
    group = nq // nkv
    qg = q.reshape(b, sq, nkv, group, d)
    logits = jnp.einsum("bsngd,btnd->bngst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    return logits.reshape(b, nq, sq, k.shape[1])


def gqa_combine(probs: Array, v: Array) -> Array:
    """probs: [B,nq,Sq,Sk], v: [B,Sk,nkv,D] -> [B,Sq,nq,D]."""
    b, nq, sq, sk = probs.shape
    nkv = v.shape[2]
    group = nq // nkv
    pg = probs.reshape(b, nkv, group, sq, sk)
    out = jnp.einsum("bngst,btnd->bsngd", pg, v.astype(jnp.float32))
    return out.reshape(b, sq, nq, v.shape[3])


def make_mask(sq: int, sk: int, *, causal: bool, window: int,
              q_offset: Array | int = 0,
              kv_valid_len: Optional[Array] = None) -> Array:
    """Boolean [Sq, Sk] (or batched) mask; True = attendable.

    ``q_offset`` shifts query positions (decode: q_offset = cache position).
    ``window`` <= 0 disables sliding-window masking.
    """
    qpos = jnp.arange(sq) + q_offset            # [Sq]
    kpos = jnp.arange(sk)                       # [Sk]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_valid_len is not None:
        mask &= kpos[None, :] < kv_valid_len
    return mask


def make_rope_tables(cfg: ModelConfig, positions: Optional[Array],
                     positions_thw: Optional[Array]):
    """(cos, sin) [B, S, D/2] for this step — layer-invariant."""
    hd = cfg.resolved_head_dim
    if cfg.rope_type == "mrope":
        assert positions_thw is not None
        return L.mrope_tables(positions_thw, hd, cfg.rope_theta,
                              cfg.mrope_sections)
    if cfg.rope_type == "rope":
        assert positions is not None
        return L.rope_tables(positions, hd, cfg.rope_theta)
    return None


_FLASH_THRESHOLD = 1 << 21     # Sq*Sk above which "auto" picks the flash path


def _use_flash(cfg: ModelConfig, sq: int, sk: int) -> bool:
    if cfg.attn_impl == "flash":
        return True
    if cfg.attn_impl == "naive":
        return False
    return sq * sk > _FLASH_THRESHOLD


def attend(q: Array, k: Array, v: Array, mask: Optional[Array],
           scale: float, softcap: float = 0.0) -> Array:
    logits = gqa_scores(q, k, scale)
    if softcap > 0.0:
        logits = L.softcap(logits, softcap)
    if mask is not None:
        while mask.ndim < logits.ndim:
            mask = mask[None]
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = gqa_combine(probs, v)
    return out.astype(q.dtype)


def attention(params: Params, x: Array, cfg: ModelConfig, *,
              kind: str = "global",
              rope: Optional[Tuple[Array, Array]] = None,
              positions: Optional[Array] = None,
              positions_thw: Optional[Array] = None,
              kv_cache: Optional[Dict[str, Array]] = None,
              cache_index: Optional[Array] = None,
              kv_source: Optional[Array] = None,
              causal: bool = True) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """Full attention block body (projections + rope + SDPA + out-proj).

    Modes:
      * train/prefill: ``kv_cache is None`` — self-attention over x.
      * decode: ``kv_cache`` given, x has Sq==1; keys/values written at
        ``cache_index`` then attended over the full cache (flash-decode
        pattern). "local" blocks use a RING-BUFFER cache of ``window``
        slots (written at cache_index % ring_len) — O(window) memory
        regardless of context length.
      * cross: ``kv_source`` given (encoder states; no rope, not causal).

    ``rope``: precomputed (cos, sin) tables (layer-invariant — compute once
    per step and pass through the layer scan); falls back to computing from
    ``positions`` / ``positions_thw`` when absent.
    """
    hd = cfg.resolved_head_dim
    scale = cfg.query_scale if cfg.query_scale else hd ** -0.5
    window = cfg.window_size if kind == "local" else 0

    ba, ma = cfg.batch_axes, cfg.model_axis
    q = _split_heads(L.constrain(x @ params["wq"], ba, (None, ma)),
                     cfg.num_heads)
    src = kv_source if kv_source is not None else x
    k = _split_heads(L.constrain(src @ params["wk"], ba, (None, ma)),
                     cfg.num_kv_heads)
    v = _split_heads(L.constrain(src @ params["wv"], ba, (None, ma)),
                     cfg.num_kv_heads)

    if kv_source is None and cfg.rope_type != "none":
        if rope is None:
            rope = make_rope_tables(cfg, positions, positions_thw)
        q = L.apply_rotary(q, *rope)
        k = L.apply_rotary(k, *rope)

    new_cache = None
    if kv_cache is not None and kv_source is None:
        # decode: write this step's k/v, attend over the cache.
        assert cache_index is not None
        ring_len = kv_cache["k"].shape[1]
        if kind == "local" and ring_len <= cfg.window_size:
            # ring buffer: the cache holds exactly the last `ring_len`
            # positions; keys carry their rope so order is irrelevant.
            write_pos = jax.lax.rem(cache_index, ring_len)
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), write_pos,
                axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), write_pos,
                axis=1)
            new_cache = {"k": ck, "v": cv}
            valid = jnp.arange(ring_len)[None, :] < jnp.minimum(
                cache_index + 1, ring_len)
            out = attend(q, ck, cv, valid[None], scale,
                         cfg.attn_logit_softcap)
            b, s = out.shape[:2]
            return out.reshape(b, s, -1) @ params["wo"], new_cache
        if "k_scale" in kv_cache:                    # int8 quantised cache
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            dus = jax.lax.dynamic_update_slice_in_dim
            new_cache = {
                "k": dus(kv_cache["k"], kq, cache_index, axis=1),
                "v": dus(kv_cache["v"], vq, cache_index, axis=1),
                "k_scale": dus(kv_cache["k_scale"], ks, cache_index, axis=1),
                "v_scale": dus(kv_cache["v_scale"], vs, cache_index, axis=1),
            }
            if _use_flash(cfg, q.shape[1],
                          new_cache["k"].shape[1]) and q.shape[1] == 1:
                out = flash_decode(
                    q, new_cache["k"], new_cache["v"], scale=scale,
                    cache_index=cache_index, window=window,
                    softcap=cfg.attn_logit_softcap,
                    block_kv=cfg.flash_block_kv,
                    k_scale=new_cache["k_scale"],
                    v_scale=new_cache["v_scale"])
                b, s = out.shape[:2]
                return out.reshape(b, s, -1) @ params["wo"], new_cache
            k = dequantize_kv(new_cache["k"], new_cache["k_scale"])
            v = dequantize_kv(new_cache["v"], new_cache["v_scale"])
            mask = make_mask(q.shape[1], k.shape[1], causal=causal,
                             window=window, q_offset=cache_index,
                             kv_valid_len=cache_index + q.shape[1])
            out = attend(q, k, v, mask, scale, cfg.attn_logit_softcap)
            b, s = out.shape[:2]
            return out.reshape(b, s, -1) @ params["wo"], new_cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        if _use_flash(cfg, q.shape[1], k.shape[1]) and q.shape[1] == 1:
            out = flash_decode(q, k, v, scale=scale, cache_index=cache_index,
                               window=window, softcap=cfg.attn_logit_softcap,
                               block_kv=cfg.flash_block_kv)
            b, s = out.shape[:2]
            return out.reshape(b, s, -1) @ params["wo"], new_cache
        mask = make_mask(q.shape[1], k.shape[1], causal=causal, window=window,
                         q_offset=cache_index,
                         kv_valid_len=cache_index + q.shape[1])
    elif kv_source is not None:
        mask = None                              # cross-attention: full access
        if kv_cache is not None:                 # pre-computed cross cache
            k, v = kv_cache["k"], kv_cache["v"]
            new_cache = kv_cache
        if _use_flash(cfg, q.shape[1], k.shape[1]):
            fcfg = FlashConfig(
                block_q=min(cfg.flash_block_q, max(q.shape[1], 16)),
                block_kv=min(cfg.flash_block_kv, max(k.shape[1], 16)),
                causal=False, window=0, softcap=cfg.attn_logit_softcap,
                scale=scale)
            out = flash_attention(q, k, v, fcfg)
            b, s = out.shape[:2]
            return out.reshape(b, s, -1) @ params["wo"], new_cache
    else:
        if _use_flash(cfg, q.shape[1], k.shape[1]):
            fcfg = FlashConfig(
                block_q=min(cfg.flash_block_q, max(q.shape[1], 16)),
                block_kv=min(cfg.flash_block_kv, max(k.shape[1], 16)),
                causal=causal, window=window, softcap=cfg.attn_logit_softcap,
                scale=scale)
            out = flash_attention(q, k, v, fcfg)
            b, s = out.shape[:2]
            return out.reshape(b, s, -1) @ params["wo"], new_cache
        mask = make_mask(q.shape[1], k.shape[1], causal=causal, window=window)

    out = attend(q, k, v, mask, scale, cfg.attn_logit_softcap)
    b, s = out.shape[:2]
    out = out.reshape(b, s, -1) @ params["wo"]
    return out, new_cache
