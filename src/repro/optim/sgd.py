"""Minimal optimizer substrate (no external deps): SGD-momentum and AdamW.

API mirrors the (init, update) gradient-transformation style:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)
    params = apply_updates(params, updates)
All functions are pure pytree maps and jit-compatible.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


class SGDState(NamedTuple):
    momentum: PyTree


@dataclasses.dataclass(frozen=True)
class SGD:
    """SGD with (heavy-ball) momentum and optional weight decay.

    The paper's local optimizer: momentum 0.9, lr 0.05 (CIFAR-10) /
    0.1 (FEMNIST), halved at 50% and 75% of training.
    """
    momentum: float = 0.9
    weight_decay: float = 0.0
    nesterov: bool = False

    def init(self, params: PyTree) -> SGDState:
        return SGDState(jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(self, grads: PyTree, state: SGDState, params: PyTree,
               lr: jax.Array) -> Tuple[PyTree, SGDState]:
        if self.weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + self.weight_decay * p.astype(g.dtype),
                grads, params)
        new_m = jax.tree_util.tree_map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state.momentum, grads)
        if self.nesterov:
            eff = jax.tree_util.tree_map(
                lambda m, g: self.momentum * m + g.astype(jnp.float32),
                new_m, grads)
        else:
            eff = new_m
        updates = jax.tree_util.tree_map(lambda m: -lr * m, eff)
        return updates, SGDState(new_m)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params: PyTree) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(z, params),
                          jax.tree_util.tree_map(z, params))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree,
               lr: jax.Array) -> Tuple[PyTree, AdamWState]:
        step = state.step + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: self.b2 * v +
            (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return -lr * u

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamWState(step, mu, nu)
