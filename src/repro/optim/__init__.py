"""repro.optim — optimizer + schedule substrate (SGD-momentum, AdamW)."""

from repro.optim.sgd import (SGD, AdamW, SGDState, AdamWState, apply_updates,
                             global_norm, clip_by_global_norm)
from repro.optim.schedule import (constant, step_decay, paper_step_decay,
                                  cosine)
