"""Learning-rate schedules (pure functions of the step/round index)."""

from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def paper_step_decay(lr: float, total_rounds: int) -> Schedule:
    """The paper's schedule: halve at 50% and 75% of total rounds."""
    return step_decay(lr, [int(0.5 * total_rounds), int(0.75 * total_rounds)],
                      0.5)


def step_decay(lr: float, boundaries: Sequence[int], factor: float) -> Schedule:
    bounds = jnp.asarray(list(boundaries), jnp.int32)

    def fn(step):
        n = jnp.sum(step >= bounds)
        return lr * factor ** n.astype(jnp.float32)

    return fn


def cosine(lr: float, total_steps: int, warmup_steps: int = 0,
           final_fraction: float = 0.0) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_fraction * lr + (1 - final_fraction) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
