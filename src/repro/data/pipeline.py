"""Data pipeline: deterministic shuffled batch iterators + per-client views.

Kept dependency-free (numpy only) and deliberately simple: FL experiments
iterate small per-client shards; the large-model training path consumes
``synthetic_lm_tokens`` through ``batch_iterator`` with drop-remainder
semantics matching the global batch of the assigned input shapes.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int,
                   seed: int = 0, drop_remainder: bool = True
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite shuffled epochs of {x, y} batches."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        perm = rng.permutation(n)
        end = (n // batch_size) * batch_size if drop_remainder else n
        for s in range(0, max(end, batch_size), batch_size):
            idx = perm[s:s + batch_size]
            if drop_remainder and len(idx) < batch_size:
                break
            yield {"x": x[idx], "y": y[idx]}


def make_client_datasets(x: np.ndarray, y: np.ndarray,
                         partitions: Sequence[np.ndarray]
                         ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Materialise per-client (x, y) shards from partition index lists."""
    return [(x[idx], y[idx]) for idx in partitions]


def train_test_split(x: np.ndarray, y: np.ndarray, test_fraction: float = 0.1,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    perm = rng.permutation(n)
    cut = int(n * (1.0 - test_fraction))
    tr, te = perm[:cut], perm[cut:]
    return (x[tr], y[tr]), (x[te], y[te])


def lm_batches(tokens: np.ndarray, batch_size: int, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token-prediction batches: inputs = toks[:-1], labels = toks[1:]."""
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        perm = rng.permutation(n)
        for s in range(0, (n // batch_size) * batch_size, batch_size):
            idx = perm[s:s + batch_size]
            seq = tokens[idx]
            yield {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
