"""Data pipeline: deterministic shuffled batch iterators, per-client views,
and the host half of the ClientBank data plane (bucketing + cyclic tiling
into ``[N, B, ...]`` stacks — see ``repro.fl.client_bank`` for the
device-resident half).

Kept dependency-free (numpy only) and deliberately simple: FL experiments
iterate small per-client shards; the large-model training path consumes
``synthetic_lm_tokens`` through ``batch_iterator`` with drop-remainder
semantics matching the global batch of the assigned input shapes.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np


def bucket_num_batches(steps: int) -> int:
    """Round a per-epoch step count up to the next power of two."""
    return 1 << max(steps - 1, 0).bit_length()


def pad_client_data(x: np.ndarray, y: np.ndarray,
                    num_examples: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cyclically tile a client's (x, y) to exactly ``num_examples`` rows."""
    n = x.shape[0]
    if n == num_examples:
        return x, y
    idx = np.arange(num_examples) % n
    return x[idx], y[idx]


def bucket_examples(sizes: Sequence[int], batch_size: int) -> int:
    """Common bucketed example count B for a set of client dataset sizes.

    Sized from ``ceil(n_i / bs)`` rounded up to the next power of two, so
    ``B >= max_i n_i`` — the cyclic tiling then contains every client's
    every example.  The *applied* per-epoch step count stays the
    floor-based ``max(n_i // bs, 1)`` (see :func:`stack_client_arrays`).
    """
    steps = max(max(-(-int(s) // batch_size), 1) for s in sizes)
    return bucket_num_batches(steps) * batch_size


def stack_client_arrays(client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                        batch_size: int
                        ) -> Tuple[np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Tile every client to ONE common bucket -> ``[N, B, ...]`` stacks.

    The host half of the ``ClientBank`` data plane (`repro.fl.client_bank`):
    every client's (x, y) is cyclically tiled to the same bucket of ``B``
    examples and stacked along a leading client axis.  Returns
    ``(xs, ys, num_steps, num_examples)`` where ``num_steps[i]`` is client
    i's true per-epoch optimizer step count ``max(n_i // bs, 1)`` and
    ``num_examples[i]`` its true dataset size (the masks that keep padded
    clients from over-training or sampling their duplicated rows).
    """
    sizes = [int(x.shape[0]) for x, _ in client_data]
    b = bucket_examples(sizes, batch_size)
    xs, ys = [], []
    for x, y in client_data:
        px, py = pad_client_data(np.asarray(x), np.asarray(y), b)
        xs.append(px)
        ys.append(py)
    num_steps = np.asarray([max(n // batch_size, 1) for n in sizes],
                           np.int32)
    return (np.stack(xs), np.stack(ys), num_steps,
            np.asarray(sizes, np.int32))


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int,
                   seed: int = 0, drop_remainder: bool = True
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite shuffled epochs of {x, y} batches."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        perm = rng.permutation(n)
        end = (n // batch_size) * batch_size if drop_remainder else n
        for s in range(0, max(end, batch_size), batch_size):
            idx = perm[s:s + batch_size]
            if drop_remainder and len(idx) < batch_size:
                break
            yield {"x": x[idx], "y": y[idx]}


def make_client_datasets(x: np.ndarray, y: np.ndarray,
                         partitions: Sequence[np.ndarray]
                         ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Materialise per-client (x, y) shards from partition index lists."""
    return [(x[idx], y[idx]) for idx in partitions]


def train_test_split(x: np.ndarray, y: np.ndarray, test_fraction: float = 0.1,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    perm = rng.permutation(n)
    cut = int(n * (1.0 - test_fraction))
    tr, te = perm[:cut], perm[cut:]
    return (x[tr], y[tr]), (x[te], y[te])


def lm_batches(tokens: np.ndarray, batch_size: int, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token-prediction batches: inputs = toks[:-1], labels = toks[1:]."""
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        perm = rng.permutation(n)
        for s in range(0, (n // batch_size) * batch_size, batch_size):
            idx = perm[s:s + batch_size]
            seq = tokens[idx]
            yield {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
