"""Data pipeline: deterministic shuffled batch iterators, per-client views,
and the host half of the ClientBank data plane (bucketing + cyclic tiling
into ``[N, B, ...]`` stacks — see ``repro.fl.client_bank`` for the
device-resident half, and ``docs/architecture.md`` for the full story).

Bucket / tier invariants (the contract every consumer relies on)
----------------------------------------------------------------
* A client of ``n`` examples is bucketed to
  ``client_bucket_examples(n, bs) = next_pow2(ceil(n / bs)) * bs`` rows —
  sized from the *ceil* step count so the bucket always holds ``>= n``
  rows and the cyclic tiling (:func:`pad_client_data`) contains every
  example.  The *applied* per-epoch step count stays the floor-based
  Algorithm-1 count ``max(n // bs, 1)``.
* :func:`bucket_examples` is the single GLOBAL bucket (the max of the
  per-client buckets): one compiled data shape for the whole bank.
* :func:`assign_tiers` is the bucket LADDER: clients grouped by their
  per-client power-of-two bucket, optionally merged down to at most
  ``max_tiers`` rungs (each merge moves the cheapest rung up into the
  next one, so a merged client's tier bucket still holds ``>= n`` rows).
  One ``[N_t, B_t, ...]`` stack per tier bounds bank memory by roughly
  ``sum_i n_i`` instead of the global bucket's ``O(N * max_i n_i)``,
  while keeping one compiled data shape PER TIER.
* All of this is host-side numpy only — device placement belongs to
  ``repro.fl.client_bank``.

The large-model training path consumes ``synthetic_lm_tokens`` through
``batch_iterator`` with drop-remainder semantics matching the global batch
of the assigned input shapes.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def bucket_num_batches(steps: int) -> int:
    """Round a per-epoch step count up to the next power of two."""
    return 1 << max(steps - 1, 0).bit_length()


def pad_client_data(x: np.ndarray, y: np.ndarray,
                    num_examples: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cyclically tile a client's (x, y) to exactly ``num_examples`` rows."""
    n = x.shape[0]
    if n == num_examples:
        return x, y
    idx = np.arange(num_examples) % n
    return x[idx], y[idx]


def client_bucket_examples(num_examples: int, batch_size: int) -> int:
    """One client's own power-of-two bucket: ``next_pow2(ceil(n/bs)) * bs``.

    Sized from the *ceil* step count so the bucket holds ``>= n`` rows and
    the cyclic tiling contains every example; the applied per-epoch step
    count stays the floor-based ``max(n // bs, 1)``.
    """
    steps = max(-(-int(num_examples) // batch_size), 1)
    return bucket_num_batches(steps) * batch_size


def bucket_examples(sizes: Sequence[int], batch_size: int) -> int:
    """Common bucketed example count B for a set of client dataset sizes.

    The max of the per-client buckets (:func:`client_bucket_examples`), so
    ``B >= max_i n_i`` — the cyclic tiling then contains every client's
    every example.  The *applied* per-epoch step count stays the
    floor-based ``max(n_i // bs, 1)`` (see :func:`stack_client_arrays`).
    """
    return max(client_bucket_examples(s, batch_size) for s in sizes)


def assign_tiers(sizes: Sequence[int], batch_size: int,
                 max_tiers: int = 4) -> Tuple[np.ndarray, List[int]]:
    """Group clients into a ladder of power-of-two bucket tiers.

    Each client starts in the tier of its own bucket
    (:func:`client_bucket_examples`); if that yields more than
    ``max_tiers`` distinct rungs, the ladder is merged greedily: the rung
    whose promotion into the next-larger rung adds the least total padding
    (``count * (B_next - B)``) is folded upward until at most ``max_tiers``
    rungs remain.  Merging only ever moves clients to a LARGER bucket, so
    every tier bucket still holds ``>= n_i`` rows for its members and the
    whole bucketing contract (cyclic tiling, floor-based applied steps,
    ``num_examples`` epoch masking) applies per tier unchanged.

    Returns ``(tier_of, tier_buckets)``: ``tier_of[i]`` is client i's tier
    index into the ascending ``tier_buckets`` list.  Deterministic; a
    uniform ladder (all clients sharing one bucket) collapses to a single
    tier, which consumers treat exactly like the single global bucket.
    """
    if max_tiers < 1:
        raise ValueError(f"max_tiers must be >= 1, got {max_tiers}")
    per = np.asarray([client_bucket_examples(s, batch_size) for s in sizes],
                     np.int64)
    buckets = sorted(set(int(b) for b in per))
    while len(buckets) > max_tiers:
        counts = [int(np.sum(per == b)) for b in buckets]
        costs = [counts[j] * (buckets[j + 1] - buckets[j])
                 for j in range(len(buckets) - 1)]
        j = int(np.argmin(costs))           # ties -> lowest rung (stable)
        per[per == buckets[j]] = buckets[j + 1]
        del buckets[j]
    tier_of = np.searchsorted(np.asarray(buckets), per).astype(np.int32)
    return tier_of, buckets


def validate_client_data(client_data: Sequence[Tuple[np.ndarray, np.ndarray]]
                         ) -> None:
    """Reject malformed client datasets with an error naming the client.

    Checks at bank/pool construction (and pool admit) time — before this,
    a non-float or mismatched-dtype client array failed deep inside
    :func:`stack_client_arrays` with an opaque numpy shape/dtype error:

    * every client's ``x`` has a floating dtype (labels may be integral),
    * every client's ``x`` and ``y`` agree on the leading example count
      and hold at least one example,
    * dtypes and per-example feature shapes are identical across clients
      (the stacked ``[N, B, ...]`` form requires one shape/dtype).
    """
    if not len(client_data):
        raise ValueError("client_data is empty — a bank needs at least "
                         "one client")
    ref_x = ref_y = None
    for i, pair in enumerate(client_data):
        if len(pair) != 2:
            raise ValueError(f"client {i}: expected an (x, y) pair, got "
                             f"{len(pair)} elements")
        x, y = np.asarray(pair[0]), np.asarray(pair[1])
        if not np.issubdtype(x.dtype, np.floating):
            raise ValueError(
                f"client {i}: x dtype {x.dtype} is not a float dtype — "
                f"cast features to float32 before bank construction")
        if x.ndim < 1 or x.shape[0] < 1:
            raise ValueError(f"client {i}: needs at least one example, "
                             f"got x shape {x.shape}")
        if y.shape[:1] != x.shape[:1]:
            raise ValueError(
                f"client {i}: x has {x.shape[0]} examples but y has "
                f"shape {y.shape}")
        sig_x = (x.dtype, x.shape[1:])
        sig_y = (y.dtype, y.shape[1:])
        if ref_x is None:
            ref_x, ref_y = sig_x, sig_y
        elif sig_x != ref_x or sig_y != ref_y:
            raise ValueError(
                f"client {i}: dtype/feature-shape "
                f"(x {x.dtype} {x.shape[1:]}, y {y.dtype} {y.shape[1:]}) "
                f"does not match client 0's "
                f"(x {ref_x[0]} {ref_x[1]}, y {ref_y[0]} {ref_y[1]}) — "
                f"all clients must stack to one [N, B, ...] shape")


def quantize_stack(stack: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-client affine int8 quantization of a ``[N, B, ...]`` stack.

    Each client row (leading-axis slice) gets its own affine code over
    its value range: ``scale_i = (max_i - min_i) / 255`` (1.0 for a
    constant row) and a float zero offset, with codes stored int8.  The
    dequantization is ``x_hat = q.astype(f32) * scale + zero`` — exactly
    the elementwise graph the round engine's fused gather replays on
    device — and the QUANTIZATION ERROR CONTRACT is
    ``|x_hat - x| <= 0.5 * scale_i`` per element (half a code step; the
    f32 round-trip adds at most a few ulps on top).

    Returns ``(q int8 [N, B, ...], scale f32 [N], zero f32 [N])``.
    Deterministic — re-quantizing identical rows reproduces identical
    codes, which is what makes pool evict/re-admit round-trips exact.
    """
    stack = np.asarray(stack)
    n = stack.shape[0]
    flat = stack.reshape(n, -1).astype(np.float32)
    mn = flat.min(axis=1)
    mx = flat.max(axis=1)
    scale = (mx - mn) / np.float32(255.0)
    scale = np.where(scale > 0, scale, np.float32(1.0)).astype(np.float32)
    q = np.clip(np.rint((flat - mn[:, None]) / scale[:, None]),
                0, 255).astype(np.int16) - 128
    zero = (mn + np.float32(128.0) * scale).astype(np.float32)
    return (q.astype(np.int8).reshape(stack.shape), scale, zero)


def dequantize_stack(q: np.ndarray, scale: np.ndarray,
                     zero: np.ndarray) -> np.ndarray:
    """Host mirror of the in-gather dequantization: ``q * scale + zero``
    broadcast over each client row (f32)."""
    q = np.asarray(q)
    shape = (q.shape[0],) + (1,) * (q.ndim - 1)
    return (q.astype(np.float32) * scale.reshape(shape).astype(np.float32)
            + zero.reshape(shape).astype(np.float32))


def client_cluster_features(
        client_data: Sequence[Tuple[np.ndarray, np.ndarray]]
        ) -> np.ndarray:
    """Per-client summary features for hierarchical-aggregation k-means:
    mean and std of the flattened example features plus ``log1p(n_i)`` —
    host-side, O(sum_i n_i), computed once at bank construction (and per
    admit for the streaming pool)."""
    rows = []
    for x, _ in client_data:
        flat = np.asarray(x, np.float32).reshape(np.asarray(x).shape[0], -1)
        rows.append(np.concatenate([
            flat.mean(axis=0), flat.std(axis=0),
            [np.log1p(np.float32(flat.shape[0]))]]))
    return np.stack(rows).astype(np.float32)


def kmeans_clusters(features: np.ndarray, num_clusters: int,
                    iters: int = 25, seed: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Plain deterministic Lloyd k-means on ``[N, D]`` features.

    Host-side numpy only (the cluster routing is control-plane data, like
    tier assignment).  Returns ``(labels int32 [N], centroids f32
    [num_clusters, D])``.  ``num_clusters`` is clamped to N; an emptied
    cluster is re-seeded to the point farthest from its centroid, so
    every cluster id stays populated.
    """
    feats = np.asarray(features, np.float32)
    n = feats.shape[0]
    k = max(1, min(int(num_clusters), n))
    rng = np.random.default_rng(seed)
    centroids = feats[rng.choice(n, size=k, replace=False)].copy()
    labels = np.zeros(n, np.int32)
    for _ in range(max(int(iters), 1)):
        d2 = ((feats[:, None, :] - centroids[None, :, :]) ** 2).sum(axis=2)
        new_labels = d2.argmin(axis=1).astype(np.int32)
        for c in range(k):
            members = feats[new_labels == c]
            if members.size:
                centroids[c] = members.mean(axis=0)
            else:                    # re-seed an emptied cluster
                far = int(d2.min(axis=1).argmax())
                centroids[c] = feats[far]
                new_labels[far] = c
        if np.array_equal(new_labels, labels):
            labels = new_labels
            break
        labels = new_labels
    return labels, centroids


def assign_clusters(features: np.ndarray,
                    centroids: np.ndarray) -> np.ndarray:
    """Nearest-centroid assignment (the pool's admit-time routing —
    centroids are fitted once on the initial population and stay fixed,
    so an admitted client's cluster never depends on admission order)."""
    feats = np.atleast_2d(np.asarray(features, np.float32))
    d2 = ((feats[:, None, :] - centroids[None, :, :]) ** 2).sum(axis=2)
    return d2.argmin(axis=1).astype(np.int32)


def stack_client_arrays(client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                        batch_size: int
                        ) -> Tuple[np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Tile every client to ONE common bucket -> ``[N, B, ...]`` stacks.

    The host half of the ``ClientBank`` data plane (`repro.fl.client_bank`):
    every client's (x, y) is cyclically tiled to the same bucket of ``B``
    examples and stacked along a leading client axis.  Returns
    ``(xs, ys, num_steps, num_examples)`` where ``num_steps[i]`` is client
    i's true per-epoch optimizer step count ``max(n_i // bs, 1)`` and
    ``num_examples[i]`` its true dataset size (the masks that keep padded
    clients from over-training or sampling their duplicated rows).
    """
    sizes = [int(x.shape[0]) for x, _ in client_data]
    b = bucket_examples(sizes, batch_size)
    xs, ys = [], []
    for x, y in client_data:
        px, py = pad_client_data(np.asarray(x), np.asarray(y), b)
        xs.append(px)
        ys.append(py)
    num_steps = np.asarray([max(n // batch_size, 1) for n in sizes],
                           np.int32)
    return (np.stack(xs), np.stack(ys), num_steps,
            np.asarray(sizes, np.int32))


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int,
                   seed: int = 0, drop_remainder: bool = True
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite shuffled epochs of {x, y} batches."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        perm = rng.permutation(n)
        end = (n // batch_size) * batch_size if drop_remainder else n
        for s in range(0, max(end, batch_size), batch_size):
            idx = perm[s:s + batch_size]
            if drop_remainder and len(idx) < batch_size:
                break
            yield {"x": x[idx], "y": y[idx]}


def make_client_datasets(x: np.ndarray, y: np.ndarray,
                         partitions: Sequence[np.ndarray]
                         ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Materialise per-client (x, y) shards from partition index lists."""
    return [(x[idx], y[idx]) for idx in partitions]


def train_test_split(x: np.ndarray, y: np.ndarray, test_fraction: float = 0.1,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    perm = rng.permutation(n)
    cut = int(n * (1.0 - test_fraction))
    tr, te = perm[:cut], perm[cut:]
    return (x[tr], y[tr]), (x[te], y[te])


def lm_batches(tokens: np.ndarray, batch_size: int, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token-prediction batches: inputs = toks[:-1], labels = toks[1:]."""
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        perm = rng.permutation(n)
        for s in range(0, (n // batch_size) * batch_size, batch_size):
            idx = perm[s:s + batch_size]
            seq = tokens[idx]
            yield {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
