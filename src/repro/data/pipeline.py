"""Data pipeline: deterministic shuffled batch iterators, per-client views,
and the host half of the ClientBank data plane (bucketing + cyclic tiling
into ``[N, B, ...]`` stacks — see ``repro.fl.client_bank`` for the
device-resident half, and ``docs/architecture.md`` for the full story).

Bucket / tier invariants (the contract every consumer relies on)
----------------------------------------------------------------
* A client of ``n`` examples is bucketed to
  ``client_bucket_examples(n, bs) = next_pow2(ceil(n / bs)) * bs`` rows —
  sized from the *ceil* step count so the bucket always holds ``>= n``
  rows and the cyclic tiling (:func:`pad_client_data`) contains every
  example.  The *applied* per-epoch step count stays the floor-based
  Algorithm-1 count ``max(n // bs, 1)``.
* :func:`bucket_examples` is the single GLOBAL bucket (the max of the
  per-client buckets): one compiled data shape for the whole bank.
* :func:`assign_tiers` is the bucket LADDER: clients grouped by their
  per-client power-of-two bucket, optionally merged down to at most
  ``max_tiers`` rungs (each merge moves the cheapest rung up into the
  next one, so a merged client's tier bucket still holds ``>= n`` rows).
  One ``[N_t, B_t, ...]`` stack per tier bounds bank memory by roughly
  ``sum_i n_i`` instead of the global bucket's ``O(N * max_i n_i)``,
  while keeping one compiled data shape PER TIER.
* All of this is host-side numpy only — device placement belongs to
  ``repro.fl.client_bank``.

The large-model training path consumes ``synthetic_lm_tokens`` through
``batch_iterator`` with drop-remainder semantics matching the global batch
of the assigned input shapes.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np


def bucket_num_batches(steps: int) -> int:
    """Round a per-epoch step count up to the next power of two."""
    return 1 << max(steps - 1, 0).bit_length()


def pad_client_data(x: np.ndarray, y: np.ndarray,
                    num_examples: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cyclically tile a client's (x, y) to exactly ``num_examples`` rows."""
    n = x.shape[0]
    if n == num_examples:
        return x, y
    idx = np.arange(num_examples) % n
    return x[idx], y[idx]


def client_bucket_examples(num_examples: int, batch_size: int) -> int:
    """One client's own power-of-two bucket: ``next_pow2(ceil(n/bs)) * bs``.

    Sized from the *ceil* step count so the bucket holds ``>= n`` rows and
    the cyclic tiling contains every example; the applied per-epoch step
    count stays the floor-based ``max(n // bs, 1)``.
    """
    steps = max(-(-int(num_examples) // batch_size), 1)
    return bucket_num_batches(steps) * batch_size


def bucket_examples(sizes: Sequence[int], batch_size: int) -> int:
    """Common bucketed example count B for a set of client dataset sizes.

    The max of the per-client buckets (:func:`client_bucket_examples`), so
    ``B >= max_i n_i`` — the cyclic tiling then contains every client's
    every example.  The *applied* per-epoch step count stays the
    floor-based ``max(n_i // bs, 1)`` (see :func:`stack_client_arrays`).
    """
    return max(client_bucket_examples(s, batch_size) for s in sizes)


def assign_tiers(sizes: Sequence[int], batch_size: int,
                 max_tiers: int = 4) -> Tuple[np.ndarray, List[int]]:
    """Group clients into a ladder of power-of-two bucket tiers.

    Each client starts in the tier of its own bucket
    (:func:`client_bucket_examples`); if that yields more than
    ``max_tiers`` distinct rungs, the ladder is merged greedily: the rung
    whose promotion into the next-larger rung adds the least total padding
    (``count * (B_next - B)``) is folded upward until at most ``max_tiers``
    rungs remain.  Merging only ever moves clients to a LARGER bucket, so
    every tier bucket still holds ``>= n_i`` rows for its members and the
    whole bucketing contract (cyclic tiling, floor-based applied steps,
    ``num_examples`` epoch masking) applies per tier unchanged.

    Returns ``(tier_of, tier_buckets)``: ``tier_of[i]`` is client i's tier
    index into the ascending ``tier_buckets`` list.  Deterministic; a
    uniform ladder (all clients sharing one bucket) collapses to a single
    tier, which consumers treat exactly like the single global bucket.
    """
    if max_tiers < 1:
        raise ValueError(f"max_tiers must be >= 1, got {max_tiers}")
    per = np.asarray([client_bucket_examples(s, batch_size) for s in sizes],
                     np.int64)
    buckets = sorted(set(int(b) for b in per))
    while len(buckets) > max_tiers:
        counts = [int(np.sum(per == b)) for b in buckets]
        costs = [counts[j] * (buckets[j + 1] - buckets[j])
                 for j in range(len(buckets) - 1)]
        j = int(np.argmin(costs))           # ties -> lowest rung (stable)
        per[per == buckets[j]] = buckets[j + 1]
        del buckets[j]
    tier_of = np.searchsorted(np.asarray(buckets), per).astype(np.int32)
    return tier_of, buckets


def stack_client_arrays(client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                        batch_size: int
                        ) -> Tuple[np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Tile every client to ONE common bucket -> ``[N, B, ...]`` stacks.

    The host half of the ``ClientBank`` data plane (`repro.fl.client_bank`):
    every client's (x, y) is cyclically tiled to the same bucket of ``B``
    examples and stacked along a leading client axis.  Returns
    ``(xs, ys, num_steps, num_examples)`` where ``num_steps[i]`` is client
    i's true per-epoch optimizer step count ``max(n_i // bs, 1)`` and
    ``num_examples[i]`` its true dataset size (the masks that keep padded
    clients from over-training or sampling their duplicated rows).
    """
    sizes = [int(x.shape[0]) for x, _ in client_data]
    b = bucket_examples(sizes, batch_size)
    xs, ys = [], []
    for x, y in client_data:
        px, py = pad_client_data(np.asarray(x), np.asarray(y), b)
        xs.append(px)
        ys.append(py)
    num_steps = np.asarray([max(n // batch_size, 1) for n in sizes],
                           np.int32)
    return (np.stack(xs), np.stack(ys), num_steps,
            np.asarray(sizes, np.int32))


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int,
                   seed: int = 0, drop_remainder: bool = True
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite shuffled epochs of {x, y} batches."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        perm = rng.permutation(n)
        end = (n // batch_size) * batch_size if drop_remainder else n
        for s in range(0, max(end, batch_size), batch_size):
            idx = perm[s:s + batch_size]
            if drop_remainder and len(idx) < batch_size:
                break
            yield {"x": x[idx], "y": y[idx]}


def make_client_datasets(x: np.ndarray, y: np.ndarray,
                         partitions: Sequence[np.ndarray]
                         ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Materialise per-client (x, y) shards from partition index lists."""
    return [(x[idx], y[idx]) for idx in partitions]


def train_test_split(x: np.ndarray, y: np.ndarray, test_fraction: float = 0.1,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    perm = rng.permutation(n)
    cut = int(n * (1.0 - test_fraction))
    tr, te = perm[:cut], perm[cut:]
    return (x[tr], y[tr]), (x[te], y[te])


def lm_batches(tokens: np.ndarray, batch_size: int, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token-prediction batches: inputs = toks[:-1], labels = toks[1:]."""
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        perm = rng.permutation(n)
        for s in range(0, (n // batch_size) * batch_size, batch_size):
            idx = perm[s:s + batch_size]
            seq = tokens[idx]
            yield {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
