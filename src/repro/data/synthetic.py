"""Synthetic datasets (offline container: CIFAR-10/FEMNIST cannot be
downloaded — see DESIGN.md §8).

* ``synthetic_image_classification`` — class-conditional Gaussian images with
  learnable structure (each class has a distinct low-rank template), so a
  small CNN/MLP genuinely improves with training, non-trivially.
* ``synthetic_lm_tokens`` — Zipf-distributed token streams with a Markov
  bigram skeleton for the LM smoke tests / examples.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def synthetic_image_classification(
        num_examples: int, image_shape: Tuple[int, int, int] = (32, 32, 3),
        num_classes: int = 10, noise: float = 0.35, rank: int = 6,
        seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Class-templated images: x = template[y] + noise, unit-normalised."""
    rng = np.random.default_rng(seed)
    h, w, c = image_shape
    d = h * w * c
    u = rng.normal(0, 1, (num_classes, rank, d)).astype(np.float32)
    coeff = rng.normal(0, 1, (num_classes, rank)).astype(np.float32)
    templates = np.einsum("kr,krd->kd", coeff, u) / np.sqrt(rank)
    templates /= np.linalg.norm(templates, axis=1, keepdims=True)
    y = rng.integers(0, num_classes, num_examples).astype(np.int32)
    x = templates[y] + noise * rng.normal(0, 1, (num_examples, d)).astype(
        np.float32)
    return x.reshape((num_examples, h, w, c)).astype(np.float32), y


def synthetic_lm_tokens(num_sequences: int, seq_len: int, vocab_size: int,
                        seed: int = 0) -> np.ndarray:
    """Zipf unigram mixture with a deterministic bigram successor skeleton."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
    successor = rng.permutation(vocab_size)
    toks = np.empty((num_sequences, seq_len), np.int32)
    toks[:, 0] = rng.choice(vocab_size, num_sequences, p=unigram)
    for t in range(1, seq_len):
        use_bigram = rng.random(num_sequences) < 0.5
        draw = rng.choice(vocab_size, num_sequences, p=unigram)
        toks[:, t] = np.where(use_bigram, successor[toks[:, t - 1]], draw)
    return toks
