"""Non-IID data partitioning for FL (paper Sec. VII-A).

* ``dirichlet_partition`` — CIFAR-10 style: split indices across N devices by
  a Dirichlet(concentration) draw per class (Hsu et al. [40]); the paper uses
  concentration 0.5 over 120 devices.
* ``writer_partition``    — FEMNIST style: each device is a "writer" with its
  own label-usage profile and >= min_samples examples.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_devices: int,
                        concentration: float = 0.5, seed: int = 0,
                        min_per_device: int = 8) -> List[np.ndarray]:
    """Return per-device index arrays with Dirichlet label skew."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    for _ in range(256):
        buckets: List[List[int]] = [[] for _ in range(num_devices)]
        for c in classes:
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            probs = rng.dirichlet(np.full(num_devices, concentration))
            cuts = (np.cumsum(probs) * len(idx)).astype(int)[:-1]
            for dev, part in enumerate(np.split(idx, cuts)):
                buckets[dev].extend(part.tolist())
        sizes = np.asarray([len(b) for b in buckets])
        if sizes.min() >= min_per_device:
            break
    out = []
    for b in buckets:
        arr = np.asarray(b, np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


def writer_partition(labels: np.ndarray, num_devices: int,
                     samples_per_writer: Tuple[int, int] = (50, 400),
                     label_profile_size: int = 12, seed: int = 0
                     ) -> List[np.ndarray]:
    """FEMNIST-like: each device draws from its own small label subset.

    Mirrors the LEAF preprocessing the paper uses: writers with < 50 samples
    are filtered out (we draw sizes >= 50 directly) and each writer's data is
    concentrated on a personal subset of classes (handwriting style proxy).
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    by_class = {c: np.flatnonzero(labels == c) for c in classes}
    out = []
    for _ in range(num_devices):
        profile = rng.choice(classes, size=min(label_profile_size,
                                               len(classes)), replace=False)
        size = int(rng.integers(samples_per_writer[0],
                                samples_per_writer[1] + 1))
        weights = rng.dirichlet(np.full(len(profile), 0.8))
        counts = rng.multinomial(size, weights)
        idx: List[int] = []
        for c, k in zip(profile, counts):
            pool = by_class[c]
            take = rng.choice(pool, size=min(k, len(pool)), replace=False)
            idx.extend(take.tolist())
        arr = np.asarray(idx, np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


def partition_stats(parts: Sequence[np.ndarray], labels: np.ndarray) -> dict:
    """Summary statistics used by tests and benchmark logs."""
    sizes = np.asarray([len(p) for p in parts])
    classes = np.unique(labels)
    label_dists = np.stack([
        np.bincount(labels[p], minlength=classes.max() + 1) / max(len(p), 1)
        for p in parts])
    global_dist = np.bincount(labels, minlength=classes.max() + 1) / len(labels)
    tv = 0.5 * np.abs(label_dists - global_dist[None, :]).sum(axis=1)
    return dict(sizes=sizes, mean_tv_distance=float(tv.mean()),
                max_tv_distance=float(tv.max()))
