"""repro.data — non-IID partitioning + synthetic datasets + pipelines."""

from repro.data.partition import (dirichlet_partition, writer_partition,
                                  partition_stats)
from repro.data.synthetic import (synthetic_image_classification,
                                  synthetic_lm_tokens)
from repro.data.pipeline import (batch_iterator, bucket_examples,
                                 bucket_num_batches, make_client_datasets,
                                 pad_client_data, stack_client_arrays,
                                 train_test_split, lm_batches)
