"""repro.data — non-IID partitioning + synthetic datasets + pipelines."""

from repro.data.partition import (dirichlet_partition, writer_partition,
                                  partition_stats)
from repro.data.synthetic import (synthetic_image_classification,
                                  synthetic_lm_tokens)
from repro.data.pipeline import (batch_iterator, make_client_datasets,
                                 train_test_split, lm_batches)
