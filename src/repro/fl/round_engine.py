"""Fused device-resident FL round engine over a ClientBank.

PR 1 collapsed a round to one jitted computation but still stacked the K
sampled clients' data on the host every round and re-uploaded it — the
dominant non-compute cost on GPU/TPU once the round itself is fused.  The
engine now consumes a :class:`repro.fl.client_bank.ClientBank`: all N
clients' bucketed data lives on device as ``[N, B, ...]`` stacks, and a
round is

    gather K selected rows inside the jit  (jnp.take over the bank)
      -> vmapped E-epoch local SGD           (client.batched_local_sgd)
      -> fused eq.-(4) aggregation           (server.aggregate_fused;
                                              Pallas fl_aggregate on TPU)
    with the params buffer donated off-CPU — ZERO per-round host->device
    transfers of client data.

One gather core (:meth:`_gathered_round`) serves every path, so the
single-round and multi-round data planes can no longer diverge:

* :meth:`round_step` — one fused round from the bank + a ``selected``
  index vector; the trainer's hot path (controller decisions + sampling
  stay on the host so stateful controllers and callbacks keep working).
* :meth:`run_scan` — benchmark/sweep fast path: an entire multi-round
  Algorithm-1 rollout (decide -> sample -> train -> aggregate -> queue
  update) inside a single ``lax.scan`` over the same bank.  The scan
  body (:meth:`_build_scan`, shared with the ScenarioArena) treats the
  sampling count K as TRACED data over a static slot count ``K_max``:
  per-slot draws are prefix-stable (``fold_in(round_key, slot)``) and
  slots beyond the traced ``k_act`` are inert (row-0 gather, zeroed
  eq.-(4) coefficients and metric contributions), which is what lets a
  mixed-K arena grid fuse into one padded-K executable whose lanes stay
  bitwise-equal to the per-K programs.  It can also evaluate a test set
  on device every ``eval_every`` rounds (``eval_fn`` — see
  ``repro.sim.eval.EvalBank``).
* :meth:`round_step_stacked` — the PR-1 host-stacked round, retained for
  bank-vs-host equivalence tests and transfer-cost benchmarking.

Tier ladder (:class:`repro.fl.client_bank.TieredClientBank`): a skewed
bank holds one ``[N_t, B_t, ...]`` stack per power-of-two size tier, and
a round runs ONE fused gathered round per non-empty tier of the selected
set — each tier ``jnp.take``s its slots (non-members clamped to row 0 and
masked out by zeroed coefficients) through the same :meth:`_gathered_round`
core, and the per-tier eq.-(4) contributions are summed into the params
(:meth:`_tier_loop_round`).  A selection that lands entirely in one tier
(including every round of a one-tier ladder) short-circuits to the
single-bucket executable, bit-identical to :class:`ClientBank` rounds.
``run_scan`` rides the same tier loop with each tier's training behind a
selection-conditioned ``lax.cond`` (the sampled selection is traced, so
the skip is a runtime branch — a round that hits one tier pays one tier),
and the mesh-sharded path rides it too — each tier's round shard_maps its
K-client axis exactly like the single-bucket path.  Executable count
stays one compiled data shape per tier: per-tier single-bucket steps,
plus one tiered executable per distinct hit-tier subset (bounded by the
ladder's ``max_tiers``).

Mesh sharding: pass ``mesh`` (e.g. ``launch.mesh.make_fl_mesh()`` or the
``data`` axis of ``launch.mesh.make_production_mesh()``) and the K-client
axis of every round is ``shard_map``ped over ``mesh_axis``: each shard
trains K/shards clients and reduces its partial eq.-(4) term, the partials
``psum`` across shards (``server.aggregate_fused_psum``), and params stay
replicated.  The bank itself shards its N axis over the same mesh when
divisible.

Bucketing contract: see ``repro.fl.client`` / ``repro.data.pipeline`` —
each bank stack tiles its clients to one power-of-two bucket (the global
bucket for :class:`ClientBank`, one per rung for the tier ladder), so
each task compiles exactly one data shape per tier, and ``num_steps``/
``num_examples`` masks preserve true per-client step counts and sampling
statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import policy as pol
from repro.core import queues as vq
from repro.obs import trace as obs_trace
from repro.core import system_model as sm
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.fl.client_bank import ClientBank, TieredClientBank

PyTree = Any
AnyBank = Any   # ClientBank | TieredClientBank


def _tier_parts(parts_key: tuple, buffers: tuple) -> list:
    """Zip the static per-tier key ``(tid, steps, masked[, quant])`` with
    the matching device buffers ``(xs, ys, ns, ne[, sc, zp])`` into the
    ``(tid, xs, ys, ns, ne, sc, zp, steps)`` entries ``_tier_loop_round``
    consumes — the ONE place the parts layout is defined, shared by the
    tiered step and the tiered scan.  Pre-quantization callers (3-tuple
    keys, 4-tuple buffers) get ``sc = zp = None`` — the fp32 trace."""
    out = []
    for key, buf in zip(parts_key, buffers):
        tid, steps = key[0], key[1]
        xs, ys, ns, ne = buf[:4]
        sc, zp = (buf[4], buf[5]) if len(buf) >= 6 else (None, None)
        out.append((tid, xs, ys, ns, ne, sc, zp, steps))
    return out


def _bank_quant_args(bank) -> tuple:
    """``bank.quant_args()`` with a fp32 default for banks predating the
    quantized storage mode (duck-typed callers, test doubles)."""
    fn = getattr(bank, "quant_args", None)
    return fn() if fn is not None else (None, None)


def bank_layout_key(bank: AnyBank, tier_subset=None) -> tuple:
    """The ``bank_key`` that :meth:`RoundEngine._scan_plan` would return
    for ``bank`` (optionally restricted to a static ``tier_subset``),
    computed WITHOUT touching device buffers.  The arena's dispatch
    planner uses this to ask "is this bucket's executable already
    compiled?" against the arena cache before paying for a plan — so the
    two layouts must stay in lockstep: ``masked`` here is
    ``not tier.uniform``, exactly when ``device_args`` returns non-None
    step masks, and ``quant`` is the int8-storage flag, exactly when
    ``quant_args`` returns non-None codes (the dequantizing gather is a
    different trace)."""
    if isinstance(bank, TieredClientBank) and bank.num_tiers == 1:
        bank = bank.tiers[0]
    if isinstance(bank, TieredClientBank):
        tiers = (tuple(range(bank.num_tiers)) if tier_subset is None
                 else tuple(tier_subset))
        return tuple((t, bank.tiers[t].steps_per_epoch,
                      not bank.tiers[t].uniform,
                      bank.tiers[t].storage == "int8") for t in tiers)
    return (bank.steps_per_epoch, not bank.uniform,
            getattr(bank, "storage", "fp32") == "int8")


def _default_donate() -> bool:
    # Buffer donation is a no-op (warning) on CPU; enable it only where the
    # runtime honours it.
    return jax.default_backend() != "cpu"


def _default_select(sp, t, h, queues, q, key, slots, kvec, cid):
    """The historical slot fill — the paper's i.i.d. draw; ``cid`` is
    ignored (selection mode baked into the executable)."""
    return pol.sampled_selection(sp, t, h, queues, q, key, slots, kvec)


class RoundEngine:
    """Executes FL rounds as fused, device-resident computations.

    Jitted executables are cached per (steps_per_epoch, masked, quant,
    clusters) for single rounds and (bank layout, K, policy, dropout) for
    scans — with a single-bucket bank that is one step executable per
    trainer; a tier ladder adds one step executable per tier plus one
    tier-loop executable per distinct hit-tier subset (keyed by the
    static (tier, steps, masked, quant) tuple).  Bank buffers are never
    donated; only params (and the scan's queues) are.
    """

    def __init__(self, task: fl_client.Task, client_cfg: fl_client.ClientConfig,
                 impl: str = "auto", donate: Optional[bool] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "data"):
        self.task = task
        self.cfg = client_cfg
        self.impl = impl
        self.donate = _default_donate() if donate is None else donate
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._step_fns: Dict[tuple, Any] = {}
        self._stacked_fns: Dict[tuple, Any] = {}
        self._scan_fns: Dict[tuple, Any] = {}
        self._tiered_fns: Dict[tuple, Any] = {}

    def make_bank(self, client_data, tiered: str = "auto",
                  max_tiers: int = 4, storage: str = "fp32",
                  clusters: Optional[int] = None) -> AnyBank:
        """Build the device-resident bank this engine's rounds gather from
        (client axis co-sharded with the engine's mesh).

        ``tiered``: 'auto' builds the bucket-ladder
        :class:`TieredClientBank` only when the partition actually spans
        more than one size tier (a uniform ladder IS the single-bucket
        bank); 'single' forces the one-global-bucket :class:`ClientBank`;
        'tiered' forces the ladder even when it has one rung.

        ``storage``: 'fp32' (default, the historical bitwise path) or
        'int8' per-client-quantized rows dequantized inside the fused
        gather.  ``clusters``: fit k-means cluster routing for
        ``round_step(..., hierarchical=True)`` — single-bucket banks
        only (the tier loop already reduces per tier).
        """
        if tiered not in ("auto", "single", "tiered"):
            raise ValueError(f"unknown bank mode {tiered!r}")
        from repro.data.pipeline import validate_client_data
        validate_client_data(client_data)
        assignment = None
        if tiered == "auto":
            from repro.data.pipeline import assign_tiers
            sizes = [int(np.asarray(x).shape[0]) for x, _ in client_data]
            assignment = assign_tiers(sizes, self.cfg.batch_size, max_tiers)
            # the bank reuses this exact assignment, so the auto decision
            # and the constructed ladder cannot diverge
            tiered = "single" if len(assignment[1]) == 1 else "tiered"
        if tiered == "single":
            return ClientBank(client_data, self.cfg, mesh=self.mesh,
                              mesh_axis=self.mesh_axis, storage=storage,
                              clusters=clusters)
        if clusters is not None:
            raise ValueError("clusters= needs a single-bucket bank "
                             "(tiered='single'), got a tier ladder")
        return TieredClientBank(client_data, self.cfg, mesh=self.mesh,
                                mesh_axis=self.mesh_axis,
                                max_tiers=max_tiers, assignment=assignment,
                                storage=storage)

    # -- shared round core -------------------------------------------------

    def _shards(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.mesh_axis])

    def _round_core(self, params, xs, ys, coeffs, lr, rngs, num_steps,
                    num_examples, steps: int, cluster_sel=None,
                    num_clusters: int = 0):
        """Train the stacked clients + aggregate — optionally shard_mapped
        over the client axis.  Pure trace shared by every entry point.

        ``cluster_sel`` (``[K]`` traced cluster ids, optional) switches
        the eq.-(4) reduce to the hierarchical cluster-then-global form
        (``server.aggregate_hierarchical``; its psum twin under a mesh).
        ``None`` keeps the flat reduce — the historical trace, untouched.
        """
        loss_fn, cfg, impl = self.task.loss_fn, self.cfg, self.impl
        shards = self._shards()
        if shards <= 1:
            deltas, losses = fl_client.batched_local_sgd(
                loss_fn, params, xs, ys, lr, rngs, cfg, steps,
                num_steps=num_steps, num_examples=num_examples)
            if cluster_sel is not None:
                return fl_server.aggregate_hierarchical(
                    params, deltas, coeffs, cluster_sel,
                    num_clusters), losses
            return fl_server.aggregate_fused(params, deltas, coeffs,
                                             impl=impl), losses
        k = xs.shape[0]
        if k % shards:
            raise ValueError(
                f"sample_count {k} not divisible by mesh axis "
                f"{self.mesh_axis!r} size {shards}")
        axis = self.mesh_axis

        if cluster_sel is not None:
            def body_h(params, lr, xs, ys, coeffs, rngs, ns, ne, csel):
                deltas, losses = fl_client.batched_local_sgd(
                    loss_fn, params, xs, ys, lr, rngs, cfg, steps,
                    num_steps=ns, num_examples=ne)
                new_params = fl_server.aggregate_hierarchical_psum(
                    params, deltas, coeffs, csel, num_clusters, axis)
                return new_params, losses

            sharded = shard_map(
                body_h, mesh=self.mesh,
                in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis),
                          P(axis), P(axis), P(axis)),
                out_specs=(P(), P(axis)), check_rep=False)
            return sharded(params, lr, xs, ys, coeffs, rngs, num_steps,
                           num_examples, cluster_sel)

        def body(params, lr, xs, ys, coeffs, rngs, ns, ne):
            deltas, losses = fl_client.batched_local_sgd(
                loss_fn, params, xs, ys, lr, rngs, cfg, steps,
                num_steps=ns, num_examples=ne)
            new_params = fl_server.aggregate_fused_psum(
                params, deltas, coeffs, axis, impl=impl)
            return new_params, losses

        # P(axis) specs on the None masks apply to zero leaves — one spec
        # tuple covers both the masked and unmasked traces.
        sharded = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis),
                      P(axis), P(axis)),
            out_specs=(P(), P(axis)), check_rep=False)
        return sharded(params, lr, xs, ys, coeffs, rngs, num_steps,
                       num_examples)

    def _gathered_round(self, params, all_x, all_y, all_steps, all_sizes,
                        all_scale, all_zero, selected, coeffs, lr, rngs,
                        steps: int, cluster_of=None, num_clusters: int = 0):
        """THE gather core: select K clients from ``[N, ...]`` bank stacks
        inside the trace (``jnp.take``) and run the round on them.  Both
        ``round_step`` and the scan body go through here, so the two data
        planes share one implementation.

        ``all_scale`` / ``all_zero`` (``[N]`` f32, or None) are an int8
        bank's per-client affine codes: the K selected rows are
        dequantized RIGHT HERE, after the take — fp32 rows only ever
        materialize at ``[K, B, ...]``, never at bank scale.  With None
        codes the trace below is character-for-character the historical
        fp32 gather (the bitwise non-regression contract).  ``cluster_of``
        (``[N]`` int32, optional) routes the round's aggregation through
        the hierarchical reduce (see :meth:`_round_core`).
        """
        xs = jnp.take(all_x, selected, axis=0)
        if all_scale is not None:
            shape = selected.shape + (1,) * (xs.ndim - 1)
            xs = (xs.astype(jnp.float32)
                  * jnp.take(all_scale, selected).reshape(shape)
                  + jnp.take(all_zero, selected).reshape(shape))
        ys = jnp.take(all_y, selected, axis=0)
        ns = None if all_steps is None else jnp.take(all_steps, selected)
        ne = None if all_sizes is None else jnp.take(all_sizes, selected)
        csel = (None if cluster_of is None
                else jnp.take(cluster_of, selected))
        return self._round_core(params, xs, ys, coeffs, lr, rngs, ns, ne,
                                steps, cluster_sel=csel,
                                num_clusters=num_clusters)

    def _tier_loop_round(self, params, parts, tier_sel, pos_sel, coeffs,
                         lr, rngs, cond_skip: bool = False):
        """THE tier loop: one fused gathered round per tier, contributions
        summed across tiers.

        ``parts``: static sequence of ``(tid, xs, ys, ns, ne, steps)`` —
        one entry per tier to run; ``tier_sel`` / ``pos_sel``: ``[K]``
        per-slot tier id and tier-local row.  Each tier runs ALL K slots
        through :meth:`_gathered_round` on its own stack (one compiled
        data shape per tier): non-member slots gather row 0 and carry a
        zeroed coefficient, so they contribute exactly nothing to that
        tier's eq.-(4) term and their loss is masked out.  The per-tier
        aggregated params are turned back into update terms and summed —
        mathematically eq. (4) over the full selection; the f32 summation
        order differs from a flat single-bucket aggregation (tiers are
        reduced innermost-first), which only matters at the ulp level.
        Shared by the tiered ``round_step`` and the tiered scan body, so
        the two tiered data planes cannot diverge; with a mesh each
        tier's round shard_maps its K axis via :meth:`_round_core`
        exactly like the single-bucket path.

        ``cond_skip``: wrap each tier's training in a selection-
        conditioned ``lax.cond`` so a tier the (traced) selection misses
        costs a predicate instead of a full ``K * B_t`` vmapped SGD — the
        scan body's path, where tier emptiness cannot be routed on the
        host.  A hit tier runs the identical trace as the unconditional
        loop, and a missed tier's contribution was exactly zero anyway
        (zeroed coefficients), so the two modes agree.  Off by default:
        ``round_step`` routes hit tiers on the host, every part it
        passes is non-empty, and a cond would only add overhead (under
        ``vmap`` — the ScenarioArena — the cond degenerates to running
        both branches and selecting, which is still correct).
        """
        upd, losses = None, jnp.zeros(pos_sel.shape, jnp.float32)
        for tid, xs, ys, ns, ne, sc, zp, steps in parts:
            mask = tier_sel == tid
            pos = jnp.where(mask, pos_sel, 0)
            cf = coeffs * mask.astype(coeffs.dtype)

            def run_tier(pos, cf, xs=xs, ys=ys, ns=ns, ne=ne, sc=sc,
                         zp=zp, steps=steps, mask=mask):
                p_t, l_t = self._gathered_round(params, xs, ys, ns, ne,
                                                sc, zp, pos, cf, lr,
                                                rngs, steps)
                u_t = jax.tree_util.tree_map(lambda a, b: a - b, p_t,
                                             params)
                return u_t, l_t.astype(jnp.float32) * mask

            if cond_skip:
                def skip_tier(pos, cf):
                    return (jax.tree_util.tree_map(jnp.zeros_like, params),
                            jnp.zeros(pos_sel.shape, jnp.float32))

                u_t, l_t = jax.lax.cond(jnp.any(mask), run_tier, skip_tier,
                                        pos, cf)
            else:
                u_t, l_t = run_tier(pos, cf)
            upd = (u_t if upd is None else
                   jax.tree_util.tree_map(jnp.add, upd, u_t))
            losses = losses + l_t
        new_params = jax.tree_util.tree_map(jnp.add, params, upd)
        return new_params, losses

    # -- single fused round ------------------------------------------------

    def _build_step(self, steps: int, num_clusters: int = 0):
        def step(params, all_x, all_y, all_steps, all_sizes, all_scale,
                 all_zero, all_clusters, selected, coeffs, lr, rngs):
            return self._gathered_round(params, all_x, all_y, all_steps,
                                        all_sizes, all_scale, all_zero,
                                        selected, coeffs, lr, rngs,
                                        steps, cluster_of=all_clusters,
                                        num_clusters=num_clusters)

        donate = (0,) if self.donate else ()
        return jax.jit(step, donate_argnums=donate)

    def round_step(self, global_params: PyTree, bank: AnyBank,
                   selected: np.ndarray, coeffs: np.ndarray, lr: float,
                   rngs: jax.Array, hierarchical: bool = False
                   ) -> Tuple[PyTree, jax.Array]:
        """One fused round gathered from the device-resident bank.

        ``selected``: [K] client indices (any integer array — the gather
        runs inside the jit, so no client data crosses the host boundary);
        ``coeffs``: [K] per-draw aggregation weights; ``rngs``: [K, 2]
        per-client PRNG keys.  Returns (new global params, per-client
        losses [K]).  The params argument is donated off-CPU — callers
        must use the returned pytree.  Bank buffers are never donated.

        An int8-storage bank rides the same call: its per-client affine
        codes flow through ``quant_args()`` and the gather dequantizes
        the K selected rows in-trace (a distinct cached executable — the
        fp32 trace is untouched).  ``hierarchical=True`` runs eq. (4) as
        the cluster-then-global reduce over the bank's k-means routing
        (requires a bank built with ``clusters=``; single-bucket banks
        and pools only).

        A :class:`TieredClientBank` routes through the tier loop: one
        fused gathered round per tier the selection actually hits, with a
        single-tier selection short-circuiting to the single-bucket
        executable (bit-identical to a :class:`ClientBank` round).
        """
        selected = np.asarray(selected)
        if selected.size and not (0 <= int(selected.min()) and
                                  int(selected.max()) < bank.num_clients):
            # jnp.take clips out-of-range indices inside the jit, which
            # would silently train the wrong client — keep the host
            # path's IndexError semantics.
            raise IndexError(
                f"selected indices {selected} out of range for bank of "
                f"{bank.num_clients} clients")
        if isinstance(bank, TieredClientBank):
            if hierarchical:
                raise ValueError(
                    "hierarchical aggregation is single-bucket only — "
                    "the tier loop already reduces per tier")
            return self._round_step_tiered(global_params, bank, selected,
                                           coeffs, lr, rngs)
        steps = bank.steps_per_epoch
        all_x, all_y, all_steps, all_sizes = bank.device_args()
        all_scale, all_zero = _bank_quant_args(bank)
        if hierarchical:
            all_clusters = getattr(bank, "cluster_of_device", None)
            if all_clusters is None:
                raise ValueError(
                    "hierarchical=True needs a bank built with "
                    "clusters=... (no cluster routing on this bank)")
            num_clusters = int(bank.num_clusters)
        else:
            all_clusters, num_clusters = None, 0
        key = (steps, all_steps is not None, all_scale is not None,
               num_clusters)
        fn = self._step_fns.get(key)
        cold = fn is None
        if cold:
            fn = self._step_fns[key] = self._build_step(steps,
                                                        num_clusters)
        with obs_trace.span("engine.round", k=int(selected.size),
                            cold=cold):
            return fn(global_params, all_x, all_y, all_steps, all_sizes,
                      all_scale, all_zero, all_clusters,
                      jnp.asarray(selected, jnp.int32),
                      jnp.asarray(coeffs, jnp.float32),
                      jnp.asarray(lr, jnp.float32), rngs)

    # -- tiered rounds -----------------------------------------------------

    def _build_tiered_step(self, parts_key: tuple):
        """One jit per distinct hit-tier subset: the whole tier loop
        (every hit tier's gathered round + the cross-tier sum) fuses into
        a single dispatch.  ``parts_key``: static ``(tid, steps, masked,
        quant)`` per hit tier — buffer pytrees arrive as a matching
        tuple."""
        def step(params, buffers, tier_sel, pos_sel, coeffs, rngs, lr):
            return self._tier_loop_round(params,
                                         _tier_parts(parts_key, buffers),
                                         tier_sel, pos_sel, coeffs, lr,
                                         rngs)

        donate = (0,) if self.donate else ()
        return jax.jit(step, donate_argnums=donate)

    def _round_step_tiered(self, global_params: PyTree,
                           bank: TieredClientBank, selected: np.ndarray,
                           coeffs: np.ndarray, lr: float, rngs: jax.Array
                           ) -> Tuple[PyTree, jax.Array]:
        """Tier-aware round: host-side routing (selection indices are host
        data anyway), device-side training.  Only the tiers the selection
        hits run — an empty tier costs nothing."""
        tier_sel = bank.tier_of[selected]
        pos_sel = bank.pos_in_tier[selected]
        hit = np.unique(tier_sel)
        if hit.size <= 1:
            # whole selection in one tier (or empty, matching the
            # ClientBank no-op semantics): the tier IS a single-bucket
            # bank — reuse the classic executable, bit-identical to a
            # ClientBank round (and to the pre-ladder engine).
            tier = bank.tiers[int(hit[0]) if hit.size else 0]
            return self.round_step(global_params, tier, pos_sel, coeffs,
                                   lr, rngs)
        parts_key, buffers = [], []
        for t in hit:
            tier = bank.tiers[int(t)]
            xs, ys, ns, ne = tier.device_args()
            sc, zp = tier.quant_args()
            parts_key.append((int(t), tier.steps_per_epoch,
                              ns is not None, sc is not None))
            buffers.append((xs, ys, ns, ne, sc, zp))
        parts_key = tuple(parts_key)
        fn = self._tiered_fns.get(parts_key)
        if fn is None:
            fn = self._tiered_fns[parts_key] = \
                self._build_tiered_step(parts_key)
        return fn(global_params, tuple(buffers),
                  jnp.asarray(tier_sel, jnp.int32),
                  jnp.asarray(pos_sel, jnp.int32),
                  jnp.asarray(coeffs, jnp.float32), rngs,
                  jnp.asarray(lr, jnp.float32))

    # -- PR-1 host-stacked round (equivalence / transfer benchmarking) -----

    def _build_stacked(self, steps: int):
        def step(params, xs, ys, coeffs, lr, rngs, num_steps, num_examples):
            return self._round_core(params, xs, ys, coeffs, lr, rngs,
                                    num_steps, num_examples, steps)

        donate = (0,) if self.donate else ()
        return jax.jit(step, donate_argnums=donate)

    def round_step_stacked(self, global_params: PyTree, xs: np.ndarray,
                           ys: np.ndarray, coeffs: np.ndarray, lr: float,
                           rngs: jax.Array,
                           num_steps: Optional[np.ndarray] = None,
                           num_examples: Optional[np.ndarray] = None
                           ) -> Tuple[PyTree, jax.Array]:
        """The PR-1 data plane: host-stacked ``[K, B, ...]`` batches
        uploaded per round (``bank.gather_host`` produces them).  Same
        round core as :meth:`round_step` — kept so equivalence tests and
        benchmarks can pin the bank path against it, byte for byte.
        """
        steps = xs.shape[1] // self.cfg.batch_size
        key = (steps, num_steps is not None)
        fn = self._stacked_fns.get(key)
        if fn is None:
            fn = self._stacked_fns[key] = self._build_stacked(steps)
        if num_steps is not None:
            num_steps = jnp.asarray(num_steps, jnp.int32)
        if num_examples is not None:
            num_examples = jnp.asarray(num_examples, jnp.int32)
        return fn(global_params, jnp.asarray(xs), jnp.asarray(ys),
                  jnp.asarray(coeffs, jnp.float32),
                  jnp.asarray(lr, jnp.float32), rngs, num_steps,
                  num_examples)

    # -- multi-round scan fast path ----------------------------------------

    def _scan_plan(self, bank: AnyBank, tier_subset=None):
        """(round_fn, data, bank_key) — the data-plane half of a rollout
        over ``bank``: ``round_fn(params, data, selected, coeffs, lr,
        rngs)`` is the single-bucket gathered round or the tier loop, and
        ``data`` the opaque device-buffer pytree it consumes.  Shared by
        :meth:`run_scan` and the ScenarioArena (``repro.sim``), so the
        host-looped and scenario-batched rollouts ride ONE data plane.
        A one-tier ladder collapses to its single bucket here (bitwise
        the :class:`ClientBank` plan); a multi-tier ladder's round runs
        every tier under a selection-conditioned ``lax.cond``
        (``cond_skip`` — rounds whose draw lands in few tiers stop
        paying ``K * sum_t B_t`` work).

        ``tier_subset`` (sorted tier-id tuple, tiered banks only) builds
        the round against a STATIC subset of the ladder: tiers outside
        the subset simply do not exist in the trace.  This is the arena
        dispatch planner's scan-skip lever — a bucket of lanes that can
        never draw tier ``t`` compiles a body without it, recovering the
        skewed-ladder win that ``cond_skip`` loses under ``vmap`` (cond
        lowers to select there, so every tier body executes).  Callers
        OWN the safety argument: selections that land outside the subset
        would gather garbage positions; the planner only emits subsets
        covering each lane's replayed footprint.  The returned
        ``bank_key`` keeps the per-tier layout triples, so distinct
        subsets cache distinct executables."""
        if isinstance(bank, TieredClientBank) and bank.num_tiers == 1:
            bank = bank.tiers[0]            # the ladder IS one bucket
        if not isinstance(bank, TieredClientBank):
            if tier_subset is not None and tuple(tier_subset) != (0,):
                raise ValueError(
                    f"tier_subset={tier_subset!r} on a single-bucket "
                    f"bank — only None or (0,) make sense there")
            tier_subset = None
        if isinstance(bank, TieredClientBank):
            if tier_subset is None:
                tier_subset = tuple(range(bank.num_tiers))
            else:
                tier_subset = tuple(tier_subset)
                if tier_subset != tuple(sorted(set(tier_subset))):
                    raise ValueError(f"tier_subset must be sorted and "
                                     f"unique, got {tier_subset!r}")
                if not tier_subset or not set(tier_subset) <= set(
                        range(bank.num_tiers)):
                    raise ValueError(
                        f"tier_subset {tier_subset!r} outside the "
                        f"ladder's {bank.num_tiers} tiers")
            parts_key, buffers = [], []
            for t in tier_subset:
                tier = bank.tiers[t]
                xs, ys, ns, ne = tier.device_args()
                sc, zp = tier.quant_args()
                parts_key.append((t, tier.steps_per_epoch, ns is not None,
                                  sc is not None))
                buffers.append((xs, ys, ns, ne, sc, zp))
            parts_key = tuple(parts_key)

            def round_fn(params, data, selected, coeffs, lr, rngs):
                bufs, tier_of, pos = data
                return self._tier_loop_round(
                    params, _tier_parts(parts_key, bufs),
                    jnp.take(tier_of, selected),
                    jnp.take(pos, selected), coeffs, lr, rngs,
                    cond_skip=True)

            data = (tuple(buffers), bank.tier_of_device, bank.pos_device)
            return round_fn, data, parts_key
        all_x, all_y, all_steps, all_sizes = bank.device_args()
        all_scale, all_zero = _bank_quant_args(bank)
        steps, masked = bank.steps_per_epoch, all_steps is not None

        def round_fn(params, data, selected, coeffs, lr, rngs):
            return self._gathered_round(params, *data, selected, coeffs,
                                        lr, rngs, steps)

        return (round_fn,
                (all_x, all_y, all_steps, all_sizes, all_scale, all_zero),
                (steps, masked, all_scale is not None))

    def _build_scan(self, k: int, decide_fn, round_fn, select_fn=None,
                    eval_fn=None, eval_every: int = 0,
                    use_dropout: bool = False):
        """Full-rollout scan body; UN-jitted (``run_scan`` jits it, the
        ScenarioArena vmaps it over a scenario axis first).

        ``decide_fn(sp, h, queues, V, lam, cid, kvec) -> ControlDecision``
        supplies the control plane — a fixed ``repro.core.policy`` rule
        (``cid`` ignored) or the traced ``lax.switch`` dispatch
        (controller-as-data); ``round_fn`` the data plane from
        :meth:`_scan_plan`.  ``eb`` is the rollout's energy budget
        ``[N]`` as a traced input (the scenario axis sweeps it), applied
        over ``sp`` before anything reads it.

        ``select_fn(sp, t, h, queues, q, key, slots, kvec, cid) ->
        drawn [K_max] int32`` fills the client slots from the decision —
        ``None`` uses the paper's i.i.d. draw
        (``policy.sampled_selection``, byte-identical to the historical
        inline code), a fixed rule comes from
        :meth:`_fixed_policy_select`, and the arena passes the traced
        ``policy.select_by_id`` dispatch so deterministic controllers
        (round-robin's cyclic schedule, DivFL's facility-location greedy)
        ride the same scan.  Every mode must be prefix-stable in the slot
        index (the padded-K invariant below).

        ``use_dropout`` (STATIC) threads a per-round alive mask
        ``drop_seq`` ([T, N] float, 1.0 = alive) through the scan:
        dropped clients reuse the inert-slot masking — their eq.-(4)
        coefficient, loss contribution, and wall-time/energy terms are
        zeroed, exactly like padded slots — but they stay in the
        ``selected`` output (the dispatch footprint is selection-, not
        survival-, dependent) and the expected-energy queue drift is
        untouched (the controller plans on expectations; realized
        dropouts are a data-plane event).  ``False`` builds the exact
        historical trace — the dropout axis cannot perturb existing
        rollouts (``drop_seq`` is passed as ``None`` and never read).

        Padded-K contract: ``k`` is the STATIC slot count ``K_max`` and
        the traced ``k_act`` (scalar int) / ``kvec`` (``[N]`` float, the
        same K broadcast — see the materialization note below) carry the
        rollout's TRUE sampling count.  Every per-slot quantity is
        prefix-stable in the slot index — slot ``i`` draws its selection
        and its client PRNG key from ``fold_in(key, i)``, independent of
        ``K_max`` — and slots ``i >= k_act`` are inert: their draw clamps
        to row 0, their eq.-(4) coefficient, loss contribution, and
        wall-time/energy terms are zeroed (the exact non-member masking
        ``_tier_loop_round`` uses), and their ``selected`` output is -1.
        A padded rollout (``k_act < K_max``) is therefore bit-identical
        on the model trajectory to the same rollout built with
        ``K_max == k_act`` — zero coefficients contribute exactly 0.0 to
        the vmap-stable eq.-(4) sum, and masked additions of 0.0 are
        exact — which is what lets a mixed-K ScenarioArena grid run as
        ONE executable (see ``repro.sim.arena``).

        Bitwise contract with the ScenarioArena: ``V``, ``lam`` — and the
        per-rollout K, ``kvec`` — must arrive MATERIALIZED as ``[N]``
        vector arguments, not rank-0 scalars.  A scalar lets XLA's
        algebraic simplifier reassociate scalar-multiply chains inside
        the solver in the unbatched trace but not in a vmapped one (it is
        a per-lane vector there), drifting arena lanes from this scan at
        the last ulp; an array argument's producer is opaque to XLA, so
        both traces compute the identical elementwise graph.

        ``eval_fn(params, eval_data) -> {name: scalar}`` (optional) adds
        an on-device test-set evaluation every ``eval_every`` rounds: the
        scan carry holds the last evaluation (the "stacked carry" — under
        the arena's vmap it is the whole ``[S, ...]`` lane stack), the
        round index drives an UNBATCHED ``lax.cond`` (the predicate
        depends only on the shared round counter, so vmap keeps it a real
        branch — off-rounds pay a predicate, not an evaluation), and each
        round emits ``test_<name>`` columns holding the most recent
        evaluation.  Round 0's carry is an evaluation of the initial
        params.  Evaluation only reads ``params``; the model trajectory
        is unchanged.

        Chunked-rollout contract (the arena's streaming path): ``t0`` is
        the TRACED global index of this segment's first round — the scan
        runs rounds ``t0 .. t0 + len(h_seq)`` of the logical rollout, so
        the ``eval_every`` predicate keeps firing on global round
        boundaries across segments — and ``last_ev`` optionally seeds the
        eval carry from a previous segment (``None`` evaluates the
        incoming params, the monolithic behaviour).  The returned
        ``extras`` tuple is the remaining scan carry — ``(rng,)`` or
        ``(rng, last_ev)`` — exactly what the next segment must receive
        for the chunked trajectory to be bitwise-identical to the
        one-shot scan: the per-round ``jax.random.split`` chain continues
        from the carried key, and every other carry leaf is threaded
        unchanged.  Because ``t0`` is traced, equal-length segments share
        one executable.
        """
        if select_fn is None:
            select_fn = _default_select

        def scan_fn(params, queues, sp, eb, data, h_seq, drop_seq, lr_seq,
                    rng, V, lam, cid, kvec, k_act, eval_data, t0, last_ev):
            sp_run = dataclasses.replace(sp, energy_budget=eb)
            n = sp_run.num_devices
            w = sp_run.data_weights
            slots = jnp.arange(k)
            active = slots < k_act
            af = active.astype(jnp.float32)
            k_f = k_act.astype(jnp.float32)

            def body(carry, inp):
                if eval_fn is not None:
                    params, queues, rng, last_ev = carry
                else:
                    params, queues, rng = carry
                if use_dropout:
                    t_idx, h, alive, lr = inp
                else:
                    t_idx, h, lr = inp
                dec = decide_fn(sp_run, h, queues, V, lam, cid, kvec)
                rng, k_sel, k_cli = jax.random.split(rng, 3)
                # slot fill from the decision — every mode's slot i
                # depends only on (round inputs, i), never on K_max:
                # the padded-K invariant above
                drawn = select_fn(sp_run, t_idx, h, queues, dec.q, k_sel,
                                  slots, kvec, cid)
                selected = jnp.where(active, drawn, 0)
                rngs = jax.vmap(
                    lambda i: jax.random.fold_in(k_cli, i))(slots)
                if use_dropout:
                    # realized dropouts zero the slot exactly like a
                    # padded slot; `act` replaces `af` everywhere a
                    # surviving upload is what counts
                    act = af * jnp.take(alive, selected)
                else:
                    act = af
                coeffs = (jnp.take(w, selected) /
                          (jnp.take(kvec, selected) *
                           jnp.take(dec.q, selected)) * act)
                params, losses = round_fn(params, data, selected, coeffs,
                                          lr, rngs)
                queues = vq.update_queues(
                    queues,
                    vq.energy_increment(sp_run, h, dec.p, dec.f, dec.q,
                                        k=kvec))
                t = sm.round_time(sp_run, h, dec.p, dec.f, k=kvec)
                e = sm.round_energy(sp_run, h, dec.p, dec.f, k=kvec)
                if use_dropout:
                    loss = (jnp.sum(losses * act) /
                            jnp.maximum(jnp.sum(act), 1.0))
                    live = active & (act > 0.0)
                    # all slots dropped: no upload finished this round
                    wall = jnp.maximum(jnp.max(jnp.where(
                        live, jnp.take(t, selected), -jnp.inf)), 0.0)
                else:
                    loss = jnp.sum(losses * af) / k_f
                    live = active
                    wall = jnp.max(jnp.where(
                        live, jnp.take(t, selected), -jnp.inf))
                # inactive slots scatter to the dropped out-of-range row n
                mask = jnp.zeros((n,), jnp.float32).at[
                    jnp.where(live, selected, n)].set(1.0, mode="drop")
                out = dict(
                    loss=loss,
                    wall_time=wall,
                    energy_mean=(jnp.sum(e * mask) /
                                 jnp.maximum(jnp.sum(mask), 1.0)),
                    queue_mean=jnp.mean(queues),
                    queue_norm=jnp.linalg.norm(queues),
                    q_min=jnp.min(dec.q), q_max=jnp.max(dec.q),
                    selected=jnp.where(active, selected, -1),
                )
                if eval_fn is not None:
                    last_ev = jax.lax.cond(
                        (t_idx + 1) % eval_every == 0,
                        lambda op: eval_fn(op[0], eval_data),
                        lambda op: op[1],
                        (params, last_ev))
                    out.update({"test_" + name: v
                                for name, v in last_ev.items()})
                    return (params, queues, rng, last_ev), out
                return (params, queues, rng), out

            num_rounds = h_seq.shape[0]
            if use_dropout:
                xs = (t0 + jnp.arange(num_rounds), h_seq, drop_seq,
                      lr_seq)
            else:
                xs = (t0 + jnp.arange(num_rounds), h_seq, lr_seq)
            if eval_fn is not None:
                last_ev0 = (eval_fn(params, eval_data) if last_ev is None
                            else last_ev)
                carry0 = (params, queues, rng, last_ev0)
            else:
                carry0 = (params, queues, rng)
            carry, outs = jax.lax.scan(body, carry0, xs)
            return carry[0], carry[1], tuple(carry[2:]), outs

        return scan_fn

    @staticmethod
    def _fixed_policy_decide(policy: str):
        """A ``decide_fn`` for :meth:`_build_scan` that always runs one
        named ``repro.core.policy`` rule (the traced ``cid`` is ignored —
        the policy is baked into the executable, no switch overhead)."""
        fn = pol.DECIDE_FNS[pol.POLICY_IDS[policy]]

        def decide(sp, h, queues, V, lam, cid, kvec):
            return fn(sp, h, queues, V, lam, k=kvec)

        return decide

    @staticmethod
    def _fixed_policy_select(policy: str):
        """A ``select_fn`` for :meth:`_build_scan` that always runs one
        named policy's selection mode (no switch — and for sampled-mode
        policies the trace is byte-identical to the historical inline
        draw, the bitwise anchor the arena lanes replay against)."""
        fn = pol.SELECT_FNS[pol.SELECTION_MODES[policy]]

        def select(sp, t, h, queues, q, key, slots, kvec, cid):
            return fn(sp, t, h, queues, q, key, slots, kvec)

        return select

    def run_scan(self, global_params: PyTree, sp: sm.SystemParams,
                 bank: AnyBank, h_seq: np.ndarray, lr_seq: np.ndarray,
                 rng: jax.Array, *, queues: Optional[jax.Array] = None,
                 policy: str = "lroa", V: float = 0.0, lam: float = 0.0,
                 drop_seq: Optional[np.ndarray] = None
                 ) -> Tuple[PyTree, jax.Array, Dict[str, np.ndarray]]:
        """Run ``h_seq.shape[0]`` full Algorithm-1 rounds in one jitted scan.

        ``bank``: the device-resident all-client bank (its ``num_steps`` /
        ``num_examples`` masks keep padded clients from over-training or
        over-sampling their duplicated rows relative to Algorithm 1); a
        :class:`TieredClientBank` runs the tier loop inside the scan body
        — each tier's training sits behind a selection-conditioned
        ``lax.cond`` (the selection is traced, so the skip is a runtime
        branch, not host routing), with non-member slots of a hit tier
        masked out by zeroed coefficients; a one-tier ladder delegates to
        the single-bucket scan unchanged.  ``h_seq``: [T, N] channel gains
        (``ChannelProcess.sample_sequence`` or ``sample_jax`` precompute
        them without host loops); ``lr_seq``: [T] learning rates.
        ``policy`` is any rule in the ``repro.core.policy.POLICIES``
        controller zoo — every registered controller (including DivFL's
        in-trace facility-location greedy and the deterministic
        round-robin schedule) runs fused; the policy's decide rule AND
        its selection mode are both baked into the executable.
        ``drop_seq`` ([T, N] float, 1.0 = alive, optional) threads a
        per-round realized-dropout mask through the scan; ``None`` (the
        default) builds the exact historical no-dropout trace.  Returns
        (final params, final queues, per-round metric arrays).  Both the
        params pytree and the ``queues`` array are donated off-CPU —
        callers must use the returned values, not the arguments.  Bank
        buffers are never donated.
        """
        if policy not in pol.POLICY_IDS:
            raise ValueError(f"unknown policy {policy!r} (scan-traceable: "
                             f"{pol.POLICIES})")
        use_dropout = drop_seq is not None
        round_fn, data, bank_key = self._scan_plan(bank)
        key = (bank_key, sp.sample_count, policy, use_dropout)
        fn = self._scan_fns.get(key)
        if fn is None:
            with obs_trace.span("arena.compile", stage="build",
                                layer="engine", policy=policy,
                                k=int(sp.sample_count)):
                scan_fn = self._build_scan(
                    sp.sample_count,
                    self._fixed_policy_decide(policy),
                    round_fn,
                    self._fixed_policy_select(policy),
                    use_dropout=use_dropout)
                donate = (0, 1) if self.donate else ()
                fn = self._scan_fns[key] = jax.jit(scan_fn,
                                                   donate_argnums=donate)
        if queues is None:
            queues = vq.init_queues(sp.num_devices)
        n = sp.num_devices
        # K is passed as DATA even though it is static here — both as the
        # materialized [N] vector the decide rules consume (kvec) and the
        # scalar active-slot count (k_act) — so this trace is the exact
        # graph a padded-K arena lane computes (bitwise contract).
        with obs_trace.span("engine.round", what="run_scan",
                            policy=policy, rounds=int(h_seq.shape[0]),
                            k=int(sp.sample_count)):
            params, queues, _, outs = fn(
                global_params, queues, sp,
                jnp.asarray(sp.energy_budget, jnp.float32), data,
                jnp.asarray(h_seq, jnp.float32),
                (jnp.asarray(drop_seq, jnp.float32) if use_dropout
                 else None),
                jnp.asarray(lr_seq, jnp.float32), rng,
                jnp.full((n,), V, jnp.float32), jnp.full((n,), lam,
                                                         jnp.float32),
                jnp.int32(pol.POLICY_IDS[policy]),
                jnp.full((n,), sp.sample_count, jnp.float32),
                jnp.int32(sp.sample_count), None, jnp.int32(0), None)
            metrics = {name: np.asarray(v) for name, v in outs.items()}
        return params, queues, metrics
