"""Fused device-resident FL round engine.

The seed trainer dispatched one jitted ``local_update`` per sampled client
(K jit entries + K host<->device syncs per round) and aggregated with a
Python loop over coefficients.  The engine collapses a round to
(approximately) ONE jitted computation:

    stack K clients' bucketed data [K, B, ...]      (host, cached per bucket)
      -> vmapped E-epoch local SGD                  (client.batched_local_sgd)
      -> fused eq.-(4) aggregation over the ravelled
         model vector                               (server.aggregate_fused,
                                                     Pallas fl_aggregate on TPU)
    all inside one jit with the params buffer donated off-CPU, so the
    global model is updated in place instead of copied every round.

Two entry points:

* :meth:`RoundEngine.round_step` — one fused round given pre-stacked client
  data; the trainer's hot path (controller decisions + sampling stay on the
  host so stateful controllers and per-round callbacks keep working).
* :meth:`RoundEngine.run_scan` — benchmark/sweep fast path: an entire
  multi-round Algorithm-1 rollout (decide -> sample -> train -> aggregate ->
  queue update) inside a single ``lax.scan``, with channel gains and the lr
  schedule precomputed as ``[T, ...]`` arrays.  Zero host round-trips
  between rounds; params and queues are donated through the scan.

Bucketing contract: see ``repro.fl.client`` — client datasets are cyclically
tiled to a power-of-two number of mini-batches, sized from ``ceil(n / bs)``
so the bucket always holds at least ``n`` rows (every example appears in the
tiled stream) while compiled shapes stay O(log(max_n / batch_size)) per task.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queues as vq
from repro.core import solver as slv
from repro.core import system_model as sm
from repro.fl import client as fl_client
from repro.fl import server as fl_server

PyTree = Any


def _default_donate() -> bool:
    # Buffer donation is a no-op (warning) on CPU; enable it only where the
    # runtime honours it.
    return jax.default_backend() != "cpu"


class RoundEngine:
    """Executes FL rounds as fused, device-resident computations.

    Jitted executables are cached per (steps_per_epoch, K, policy) — the
    bucketing contract keeps that cache small.  The host-side pad cache
    assumes ``client_data`` is stable across calls (true for the trainer)
    and is bounded at one tiled copy per client (the largest bucket seen;
    smaller buckets are prefix slices of it).
    """

    def __init__(self, task: fl_client.Task, client_cfg: fl_client.ClientConfig,
                 impl: str = "auto", donate: Optional[bool] = None):
        self.task = task
        self.cfg = client_cfg
        self.impl = impl
        self.donate = _default_donate() if donate is None else donate
        self._step_fns: Dict[int, Any] = {}
        self._scan_fns: Dict[tuple, Any] = {}
        self._pad_cache: Dict[int, tuple] = {}

    # -- host-side data prep ---------------------------------------------

    def bucket_examples(self, sizes: Sequence[int]) -> int:
        """Bucketed example count B for a set of client dataset sizes.

        Sized from ``ceil(n_i / bs)`` so ``B >= max_i n_i`` — the cyclic
        tiling then contains every client's every example.  The *applied*
        per-epoch step count stays the floor-based ``max(n_i // bs, 1)``
        (see :meth:`stack_clients`), so step semantics are unchanged.
        """
        bs = self.cfg.batch_size
        steps = max(max(-(-int(s) // bs), 1) for s in sizes)
        return fl_client.bucket_num_batches(steps) * bs

    def stack_clients(self, client_data: Sequence[tuple],
                      selected: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 Optional[np.ndarray], Optional[np.ndarray]]:
        """Gather + tile the selected clients' data to [K, B, ...].

        Returns (xs, ys, num_steps, num_examples).  ``num_steps`` and
        ``num_examples`` are both None when every selected client exactly
        fills the bucket (selects the cheaper unmasked SGD trace — no
        per-step ``where`` over the pytree); otherwise [K] true per-epoch
        step counts and true dataset sizes (the latter keeps epoch
        sampling off the padded duplicate rows).  Both traces live under
        the same per-bucket jit executable;
        :meth:`FederatedTrainer.warmup` pre-compiles the reachable ones.
        """
        bs = self.cfg.batch_size
        idxs = [int(i) for i in np.asarray(selected)]
        sizes = [client_data[i][0].shape[0] for i in idxs]
        b = self.bucket_examples(sizes)
        xs, ys = [], []
        for i in idxs:
            # Bounded cache: one entry per client, holding the largest
            # bucket seen.  Cyclic tiling to a smaller bucket is a prefix
            # of tiling to a larger one (row j is example j mod n), so
            # smaller buckets are served by slicing.
            cached = self._pad_cache.get(i)
            if cached is None or cached[0].shape[0] < b:
                x, y = client_data[i]
                cached = fl_client.pad_client_data(np.asarray(x),
                                                   np.asarray(y), b)
                self._pad_cache[i] = cached
            px, py = cached
            xs.append(px[:b])
            ys.append(py[:b])
        steps = np.asarray([max(s // bs, 1) for s in sizes], np.int32)
        if np.all(steps == b // bs):
            return np.stack(xs), np.stack(ys), None, None
        return (np.stack(xs), np.stack(ys), steps,
                np.asarray(sizes, np.int32))

    def stack_all_clients(self, client_data: Sequence[tuple]
                          ) -> Tuple[np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]:
        """Tile every client to one common bucket -> [N, B, ...] (scan path).

        Always returns concrete ``num_steps`` / ``num_examples`` [N]
        arrays (the scan body gathers per-selection values from them)."""
        n = len(client_data)
        xs, ys, num_steps, num_examples = self.stack_clients(
            client_data, np.arange(n))
        if num_steps is None:
            num_steps = np.full(n, xs.shape[1] // self.cfg.batch_size,
                                np.int32)
            num_examples = np.full(n, xs.shape[1], np.int32)
        return xs, ys, num_steps, num_examples

    # -- single fused round ----------------------------------------------

    def _build_step(self, steps: int):
        loss_fn, cfg, impl = self.task.loss_fn, self.cfg, self.impl

        def step(params, xs, ys, coeffs, lr, rngs, num_steps, num_examples):
            deltas, losses = fl_client.batched_local_sgd(
                loss_fn, params, xs, ys, lr, rngs, cfg, steps,
                num_steps=num_steps, num_examples=num_examples)
            new_params = fl_server.aggregate_fused(params, deltas, coeffs,
                                                   impl=impl)
            return new_params, losses

        donate = (0,) if self.donate else ()
        return jax.jit(step, donate_argnums=donate)

    def round_step(self, global_params: PyTree, xs: np.ndarray,
                   ys: np.ndarray, coeffs: np.ndarray, lr: float,
                   rngs: jax.Array, num_steps: Optional[np.ndarray] = None,
                   num_examples: Optional[np.ndarray] = None
                   ) -> Tuple[PyTree, jax.Array]:
        """One fused round: K local trainings + eq.-(4) aggregation, one jit.

        ``xs``/``ys``: bucketed [K, B, ...] stacks; ``coeffs``: [K] per-draw
        aggregation weights; ``rngs``: [K, 2] per-client PRNG keys;
        ``num_steps``: [K] true per-epoch step counts and ``num_examples``:
        [K] true dataset sizes (both None => every client fills the
        bucket).  Returns (new global params, per-client losses [K]).  The
        params argument is donated off-CPU — callers must use the returned
        pytree.
        """
        steps = xs.shape[1] // self.cfg.batch_size
        fn = self._step_fns.get(steps)
        if fn is None:
            fn = self._step_fns[steps] = self._build_step(steps)
        if num_steps is not None:
            num_steps = jnp.asarray(num_steps, jnp.int32)
        if num_examples is not None:
            num_examples = jnp.asarray(num_examples, jnp.int32)
        return fn(global_params, jnp.asarray(xs), jnp.asarray(ys),
                  jnp.asarray(coeffs, jnp.float32),
                  jnp.asarray(lr, jnp.float32), rngs, num_steps,
                  num_examples)

    # -- multi-round scan fast path --------------------------------------

    def _build_scan(self, steps: int, k: int, policy: str):
        loss_fn, cfg, impl = self.task.loss_fn, self.cfg, self.impl

        def scan_fn(params, queues, sp, all_x, all_y, all_steps, all_sizes,
                    h_seq, lr_seq, rng, V, lam):
            n = sp.num_devices
            w = sp.data_weights

            def body(carry, inp):
                params, queues, rng = carry
                h, lr = inp
                if policy == "lroa":
                    dec = slv.solve_p2(sp, h, queues, V, lam)
                elif policy == "uni_d":
                    q = jnp.full((n,), 1.0 / n, jnp.float32)
                    f = slv.solve_f(sp, q, queues, V)
                    p = slv.solve_p(sp, q, queues, h, V)
                    dec = slv.ControlDecision(f=f, p=p, q=q)
                else:
                    raise ValueError(f"unknown policy {policy!r}")
                rng, k_sel, k_cli = jax.random.split(rng, 3)
                selected = jax.random.choice(k_sel, n, (k,), replace=True,
                                             p=dec.q)
                xs = jnp.take(all_x, selected, axis=0)
                ys = jnp.take(all_y, selected, axis=0)
                rngs = jax.random.split(k_cli, k)
                deltas, losses = fl_client.batched_local_sgd(
                    loss_fn, params, xs, ys, lr, rngs, cfg, steps,
                    num_steps=jnp.take(all_steps, selected),
                    num_examples=jnp.take(all_sizes, selected))
                coeffs = w[selected] / (float(k) * dec.q[selected])
                params = fl_server.aggregate_fused(params, deltas, coeffs,
                                                   impl=impl)
                queues = vq.update_queues(
                    queues, vq.energy_increment(sp, h, dec.p, dec.f, dec.q))
                t = sm.round_time(sp, h, dec.p, dec.f)
                e = sm.round_energy(sp, h, dec.p, dec.f)
                mask = jnp.zeros((n,), jnp.float32).at[selected].set(1.0)
                out = dict(
                    loss=jnp.mean(losses),
                    wall_time=jnp.max(jnp.take(t, selected)),
                    energy_mean=(jnp.sum(e * mask) /
                                 jnp.maximum(jnp.sum(mask), 1.0)),
                    queue_mean=jnp.mean(queues),
                    q_min=jnp.min(dec.q), q_max=jnp.max(dec.q),
                    selected=selected,
                )
                return (params, queues, rng), out

            (params, queues, _), outs = jax.lax.scan(
                body, (params, queues, rng), (h_seq, lr_seq))
            return params, queues, outs

        donate = (0, 1) if self.donate else ()
        return jax.jit(scan_fn, donate_argnums=donate)

    def run_scan(self, global_params: PyTree, sp: sm.SystemParams,
                 all_x: np.ndarray, all_y: np.ndarray, h_seq: np.ndarray,
                 lr_seq: np.ndarray, rng: jax.Array, *,
                 num_steps: np.ndarray, num_examples: np.ndarray,
                 queues: Optional[jax.Array] = None, policy: str = "lroa",
                 V: float = 0.0, lam: float = 0.0
                 ) -> Tuple[PyTree, jax.Array, Dict[str, np.ndarray]]:
        """Run ``h_seq.shape[0]`` full Algorithm-1 rounds in one jitted scan.

        ``all_x``/``all_y``: [N, B, ...] bucketed data for every client,
        ``num_steps``: [N] true per-epoch step counts, ``num_examples``:
        [N] true dataset sizes — pass all four exactly as
        :meth:`stack_all_clients` returned them (required so padded
        clients can't silently over-train or over-sample their duplicated
        rows relative to Algorithm 1); ``h_seq``: [T, N] channel gains;
        ``lr_seq``: [T] learning rates.  ``policy`` is 'lroa' (Algorithm 2
        decisions from V/lam) or 'uni_d' (uniform q, dynamic f/p).
        Returns (final params, final queues, per-round metric arrays).
        Both the params pytree and the ``queues`` array are donated
        off-CPU — callers must use the returned values, not the arguments.
        """
        if policy not in ("lroa", "uni_d"):
            raise ValueError(f"unknown policy {policy!r}")
        steps = all_x.shape[1] // self.cfg.batch_size
        key = (steps, sp.sample_count, policy)
        fn = self._scan_fns.get(key)
        if fn is None:
            fn = self._scan_fns[key] = self._build_scan(*key)
        if queues is None:
            queues = vq.init_queues(sp.num_devices)
        params, queues, outs = fn(
            global_params, queues, sp, jnp.asarray(all_x),
            jnp.asarray(all_y), jnp.asarray(num_steps, jnp.int32),
            jnp.asarray(num_examples, jnp.int32),
            jnp.asarray(h_seq, jnp.float32),
            jnp.asarray(lr_seq, jnp.float32), rng,
            jnp.asarray(V, jnp.float32), jnp.asarray(lam, jnp.float32))
        metrics = {name: np.asarray(v) for name, v in outs.items()}
        return params, queues, metrics
