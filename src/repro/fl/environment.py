"""FL edge environment: stochastic channel process + device heterogeneity.

The paper (Sec. VII-A) draws channel gains i.i.d. from an exponential
distribution with mean 0.1, clipped to [0.01, 0.5], with a fixed seed across
runs. Device heterogeneity (CPU speed, data sizes, budgets) is configured
here so every experiment is reproducible from a seed.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core import system_model as sm


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    mean_gain: float = 0.1
    min_gain: float = 0.01
    max_gain: float = 0.5
    seed: int = 0


class ChannelProcess:
    """IID exponential channel gains, clipped to a reasonable range.

    The paper filters outliers outside [0.01, 0.5]; we redraw instead of
    clipping so the stationary distribution is a *truncated* exponential
    (clipping would put atoms at the boundaries and bias the mean).
    """

    def __init__(self, num_devices: int, cfg: ChannelConfig = ChannelConfig()):
        self.num_devices = num_devices
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)

    def sample(self) -> np.ndarray:
        cfg = self.cfg
        h = self._rng.exponential(cfg.mean_gain, self.num_devices)
        bad = (h < cfg.min_gain) | (h > cfg.max_gain)
        for _ in range(64):
            if not bad.any():
                break
            h[bad] = self._rng.exponential(cfg.mean_gain, int(bad.sum()))
            bad = (h < cfg.min_gain) | (h > cfg.max_gain)
        return np.clip(h, cfg.min_gain, cfg.max_gain).astype(np.float32)

    def stream(self) -> Iterator[np.ndarray]:
        while True:
            yield self.sample()


@dataclasses.dataclass(frozen=True)
class HeterogeneityConfig:
    """System heterogeneity: per-device multipliers, log-uniform spread."""
    cpu_speed_spread: float = 1.0    # f_max multiplier range [1/s, s]
    cycles_spread: float = 1.0       # c_n multiplier range
    budget_spread: float = 1.0       # Ebar multiplier range
    seed: int = 0


def heterogeneous_params(base: sm.SystemParams,
                         het: HeterogeneityConfig) -> sm.SystemParams:
    """Apply log-uniform heterogeneity multipliers to a parameter set."""
    rng = np.random.default_rng(het.seed)
    n = base.num_devices

    def mult(spread: float) -> np.ndarray:
        if spread <= 1.0:
            return np.ones((n,), np.float32)
        lo, hi = -np.log(spread), np.log(spread)
        return np.exp(rng.uniform(lo, hi, n)).astype(np.float32)

    f_mult = mult(het.cpu_speed_spread)
    return dataclasses.replace(
        base,
        f_max=np.asarray(base.f_max * f_mult, np.float32),
        f_min=np.asarray(np.minimum(base.f_min * f_mult, base.f_max * f_mult),
                         np.float32),
        cycles_per_sample=np.asarray(
            base.cycles_per_sample * mult(het.cycles_spread), np.float32),
        energy_budget=np.asarray(
            base.energy_budget * mult(het.budget_spread), np.float32),
    )
