"""FL edge environment: stochastic channel processes + device heterogeneity.

The paper (Sec. VII-A) draws channel gains i.i.d. from an exponential
distribution with mean 0.1, clipped to [0.01, 0.5], with a fixed seed across
runs.  On top of that stationary mode this module adds the non-stationary
environments the "no knowledge of future dynamics" claim is stressed
against:

* ``mode='markov'`` — a per-client two-state Gilbert-Elliott chain
  (good/bad) with transition probabilities ``p_gb`` (good->bad) and
  ``p_bg`` (bad->good); each round's gain is a truncated exponential whose
  mean is the current state's (``mean_gain`` good, ``bad_gain`` bad).  The
  chain starts from its stationary distribution, so every round's marginal
  occupancy is the stationary ``pi``.
* per-client dropout/straggler tails — a Bernoulli ``[T, N]`` alive mask
  (:func:`sample_dropout_mask`); dropped clients reuse the inert-slot
  masking in ``_build_scan``.

Stream separation contract: the STATIONARY gains consume the raw rollout
key exactly as before the non-stationary modes existed; the Markov chain
draws from ``fold_in(key, 1)`` and the dropout mask from
``fold_in(key, 2)``.  Adding either axis therefore leaves existing
stationary-lane trajectories bitwise unchanged (regression-tested in
``tests/test_environment_stats.py``).

Device heterogeneity (CPU speed, data sizes, budgets) is configured here so
every experiment is reproducible from a seed.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import system_model as sm

# Redraw budget for the truncated exponential: ~10% of raw draws fall
# outside [0.01, 0.5] at the paper's defaults, so P(no valid draw in 64)
# is negligible (~1e-64); the final clip only ever touches that case.
_REDRAWS = 64

#: Channel-mode names in id order — the ScenarioGrid's ``chan_mode``
#: column stores the index.
CHANNEL_MODES = ("iid", "markov")
CHANNEL_MODE_IDS = {name: i for i, name in enumerate(CHANNEL_MODES)}

# Distinct fold_in streams per random axis.  Stationary gains use the
# RAW key (the pre-existing contract — never renumber); everything added
# later folds a fresh constant so new axes cannot perturb old streams.
_MARKOV_FOLD = 1
_DROPOUT_FOLD = 2


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    mean_gain: float = 0.1
    min_gain: float = 0.01
    max_gain: float = 0.5
    seed: int = 0
    #: 'iid' (the paper's stationary draw) or 'markov' (Gilbert-Elliott).
    mode: str = "iid"
    #: Bad-state mean gain (markov mode only).
    bad_gain: float = 0.02
    #: P(good -> bad) per round.
    p_gb: float = 0.0
    #: P(bad -> good) per round.
    p_bg: float = 0.0
    #: Per-client per-round dropout probability.
    dropout: float = 0.0

    def __post_init__(self):
        if self.mode not in CHANNEL_MODE_IDS:
            raise ValueError(f"unknown channel mode {self.mode!r} "
                             f"(known: {CHANNEL_MODES})")
        if not (0.0 <= self.p_gb <= 1.0 and 0.0 <= self.p_bg <= 1.0):
            raise ValueError("transition probabilities must lie in [0, 1]")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError("dropout rate must lie in [0, 1)")


def sample_gains(key: jax.Array, num_rounds: int, num_devices: int,
                 mean_gain, min_gain, max_gain) -> jax.Array:
    """Pure device-side truncated-exponential gains, ``[T, N]`` float32.

    The functional core of :meth:`ChannelProcess.sample_jax` with the
    distribution parameters as (possibly traced) arguments, so the
    ScenarioArena can ``vmap`` it over a per-scenario (key, mean, clip)
    axis and pregenerate every scenario's channel sequence in one jit.
    Same redraw scheme as the numpy path: a ``[_REDRAWS, T, N]`` candidate
    block, each slot takes its first in-range draw, and only the
    measure-~exp(-64) no-valid-draw case is clipped to the boundary.
    """
    draws = (jax.random.exponential(
        key, (_REDRAWS, num_rounds, num_devices)) *
        jnp.asarray(mean_gain, jnp.float32))
    ok = (draws >= min_gain) & (draws <= max_gain)
    first = jnp.argmax(ok, axis=0)
    h = jnp.take_along_axis(draws, first[None], axis=0)[0]
    return jnp.clip(h, min_gain, max_gain).astype(jnp.float32)


def markov_stationary(p_gb, p_bg):
    """Stationary bad-state probability of the two-state chain.

    ``pi_bad = p_gb / (p_gb + p_bg)``; a degenerate chain (both
    probabilities zero) never leaves its initial state, and we define its
    stationary distribution as all-good.
    """
    denom = jnp.asarray(p_gb, jnp.float32) + jnp.asarray(p_bg, jnp.float32)
    return jnp.where(denom > 0.0,
                     p_gb / jnp.maximum(denom, 1e-12), 0.0)


def sample_markov_states(key: jax.Array, num_rounds: int, num_devices: int,
                         p_gb, p_bg) -> jax.Array:
    """Per-client Gilbert-Elliott state sequence ``[T, N]`` int32 (0 good,
    1 bad), initial state drawn from the stationary distribution."""
    k_init, k_steps = jax.random.split(key)
    pi_bad = markov_stationary(p_gb, p_bg)
    s0 = (jax.random.uniform(k_init, (num_devices,)) < pi_bad
          ).astype(jnp.int32)
    u = jax.random.uniform(k_steps, (num_rounds, num_devices))

    def step(s, u_t):
        nxt = jnp.where(s == 0, (u_t < p_gb).astype(jnp.int32),
                        1 - (u_t < p_bg).astype(jnp.int32))
        return nxt, s

    _, states = jax.lax.scan(step, s0, u)
    return states


def sample_gains_markov(key: jax.Array, num_rounds: int, num_devices: int,
                        mean_gain, bad_gain, min_gain, max_gain,
                        p_gb, p_bg) -> jax.Array:
    """Gilbert-Elliott gains ``[T, N]``: a state chain modulates the mean
    of the same truncated-exponential redraw scheme as the i.i.d. mode.

    Consumes ``fold_in(key, 1)`` (see the module docstring's stream
    separation contract), so it shares a rollout key with
    :func:`sample_gains` without perturbing the stationary stream.
    """
    k_states, k_draws = jax.random.split(
        jax.random.fold_in(key, _MARKOV_FOLD))
    states = sample_markov_states(k_states, num_rounds, num_devices,
                                  p_gb, p_bg)
    mean = jnp.where(states == 1, jnp.asarray(bad_gain, jnp.float32),
                     jnp.asarray(mean_gain, jnp.float32))
    draws = jax.random.exponential(
        k_draws, (_REDRAWS, num_rounds, num_devices)) * mean
    ok = (draws >= min_gain) & (draws <= max_gain)
    first = jnp.argmax(ok, axis=0)
    h = jnp.take_along_axis(draws, first[None], axis=0)[0]
    return jnp.clip(h, min_gain, max_gain).astype(jnp.float32)


def sample_channel_sequence(key: jax.Array, num_rounds: int,
                            num_devices: int, mode, mean_gain, bad_gain,
                            min_gain, max_gain, p_gb, p_bg) -> jax.Array:
    """Mode-dispatched gains ``[T, N]`` with a TRACED mode id.

    Both modes are computed and a ``where`` selects — pregeneration-time
    cost only, and the select is exact, so an ``'iid'`` lane's output is
    bitwise the plain :func:`sample_gains` stream (the stationary
    regression contract) while the arena vmaps ONE function over
    mixed-mode scenario columns.
    """
    stat = sample_gains(key, num_rounds, num_devices, mean_gain,
                        min_gain, max_gain)
    mark = sample_gains_markov(key, num_rounds, num_devices, mean_gain,
                               bad_gain, min_gain, max_gain, p_gb, p_bg)
    mode_i = jnp.asarray(mode, jnp.int32)
    return jnp.where(mode_i == CHANNEL_MODE_IDS["markov"], mark, stat)


def sample_dropout_mask(key: jax.Array, num_rounds: int, num_devices: int,
                        rate) -> jax.Array:
    """Per-client alive mask ``[T, N]`` float32 (1.0 = alive).

    Bernoulli(1 - rate) per (round, client), drawn from the dedicated
    ``fold_in(key, 2)`` stream so a zero-rate lane still consumes NO
    randomness shared with the gains (adding the axis cannot move any
    existing trajectory).
    """
    u = jax.random.uniform(jax.random.fold_in(key, _DROPOUT_FOLD),
                           (num_rounds, num_devices))
    return (u >= jnp.asarray(rate, jnp.float32)).astype(jnp.float32)


class ChannelProcess:
    """Channel gains from a seeded host process (numpy) or device draws.

    ``mode='iid'``: exponential gains clipped to a reasonable range.  The
    paper filters outliers outside [0.01, 0.5]; we redraw instead of
    clipping so the stationary distribution is a *truncated* exponential
    (clipping would put atoms at the boundaries and bias the mean).

    ``mode='markov'``: the Gilbert-Elliott chain of
    :func:`sample_gains_markov` — per-client good/bad states modulate the
    truncated-exponential mean; the host mirror keeps a persistent state
    vector across :meth:`sample` calls.

    Redraws are vectorised: a ``[64, ...]`` block of candidates is drawn
    at once and each device takes its first in-range value — no
    data-dependent host loop, so whole ``[T, N]`` channel sequences
    (:meth:`sample_sequence`, or :meth:`sample_jax` for device arrays)
    are one vectorised draw (markov mode loops over rounds for the chain,
    but stays vectorised over devices and candidates).
    """

    def __init__(self, num_devices: int, cfg: ChannelConfig = ChannelConfig()):
        self.num_devices = num_devices
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self._state: Optional[np.ndarray] = None  # markov host state [N]

    def _first_in_range(self, draws, xp=np):
        """[R, ...] candidate block -> first in-range value along axis 0."""
        cfg = self.cfg
        ok = (draws >= cfg.min_gain) & (draws <= cfg.max_gain)
        first = xp.argmax(ok, axis=0)
        h = xp.take_along_axis(draws, first[None], axis=0)[0]
        # argmax == 0 with ok[0] False means no draw landed in range:
        # the clip puts only those (measure ~exp(-64)) on the boundary
        return xp.clip(h, cfg.min_gain, cfg.max_gain).astype(xp.float32)

    # -- markov host mirror ------------------------------------------------

    def _init_state(self) -> np.ndarray:
        pi_bad = float(markov_stationary(self.cfg.p_gb, self.cfg.p_bg))
        return (self._rng.uniform(size=self.num_devices) < pi_bad
                ).astype(np.int32)

    def _advance_state(self, s: np.ndarray) -> np.ndarray:
        u = self._rng.uniform(size=self.num_devices)
        return np.where(s == 0, (u < self.cfg.p_gb).astype(np.int32),
                        1 - (u < self.cfg.p_bg).astype(np.int32))

    def markov_state_sequence(self, num_rounds: int) -> np.ndarray:
        """[T, N] int32 host state sequence, advancing the persistent
        chain (statistical mirror of :func:`sample_markov_states`; the
        numpy and jax streams are independent)."""
        if self._state is None:
            self._state = self._init_state()
        states = np.empty((num_rounds, self.num_devices), np.int32)
        for t in range(num_rounds):
            states[t] = self._state
            self._state = self._advance_state(self._state)
        return states

    # -- sampling ----------------------------------------------------------

    def sample(self) -> np.ndarray:
        if self.cfg.mode == "markov":
            return self.sample_sequence(1)[0]
        return self._first_in_range(self._rng.exponential(
            self.cfg.mean_gain, (_REDRAWS, self.num_devices)))

    def sample_sequence(self, num_rounds: int, max_block: int = 256
                        ) -> np.ndarray:
        """[T, N] gains for a whole rollout — vectorised, no host loop
        over rounds (chunked at ``max_block`` rounds to bound the [64, T,
        N] candidate block's memory).  Markov mode draws the state chain
        first, then one mean-modulated candidate block per chunk."""
        out = []
        for t0 in range(0, num_rounds, max_block):
            t = min(max_block, num_rounds - t0)
            if self.cfg.mode == "markov":
                states = self.markov_state_sequence(t)
                mean = np.where(states == 1, self.cfg.bad_gain,
                                self.cfg.mean_gain).astype(np.float32)
                draws = self._rng.exponential(
                    1.0, (_REDRAWS, t, self.num_devices)) * mean
            else:
                draws = self._rng.exponential(
                    self.cfg.mean_gain, (_REDRAWS, t, self.num_devices))
            out.append(self._first_in_range(draws))
        return np.concatenate(out) if out else np.zeros(
            (0, self.num_devices), np.float32)

    def sample_jax(self, key: jax.Array, num_rounds: Optional[int] = None
                   ) -> jax.Array:
        """Device-array gains — [T, N] (or [N] when ``num_rounds`` is
        None) drawn entirely on device, so ``run_scan``'s precomputed
        channel sequences never touch the host.  Keyed by ``key``, not
        the process seed (jax and numpy streams are independent).
        Delegates to the pure samplers (the forms the ScenarioArena vmaps
        over per-scenario channel statistics) — stationary mode consumes
        the raw key, markov mode the ``fold_in(key, 1)`` stream, exactly
        as the arena's pregenerated-gains path does."""
        t = 1 if num_rounds is None else num_rounds
        cfg = self.cfg
        if cfg.mode == "markov":
            h = sample_gains_markov(key, t, self.num_devices,
                                    cfg.mean_gain, cfg.bad_gain,
                                    cfg.min_gain, cfg.max_gain,
                                    cfg.p_gb, cfg.p_bg)
        else:
            h = sample_gains(key, t, self.num_devices, cfg.mean_gain,
                             cfg.min_gain, cfg.max_gain)
        return h[0] if num_rounds is None else h

    def dropout_jax(self, key: jax.Array, num_rounds: int) -> jax.Array:
        """[T, N] alive mask from the dedicated dropout stream of the
        SAME rollout key the gains consume (see module docstring)."""
        return sample_dropout_mask(key, num_rounds, self.num_devices,
                                   self.cfg.dropout)

    def dropout_sequence(self, num_rounds: int) -> np.ndarray:
        """[T, N] host alive mask (numpy stream; statistical mirror)."""
        u = self._rng.uniform(size=(num_rounds, self.num_devices))
        return (u >= self.cfg.dropout).astype(np.float32)

    def stream(self) -> Iterator[np.ndarray]:
        while True:
            yield self.sample()


@dataclasses.dataclass(frozen=True)
class HeterogeneityConfig:
    """System heterogeneity: per-device multipliers, log-uniform spread."""
    cpu_speed_spread: float = 1.0    # f_max multiplier range [1/s, s]
    cycles_spread: float = 1.0       # c_n multiplier range
    budget_spread: float = 1.0       # Ebar multiplier range
    seed: int = 0


def heterogeneous_params(base: sm.SystemParams,
                         het: HeterogeneityConfig) -> sm.SystemParams:
    """Apply log-uniform heterogeneity multipliers to a parameter set."""
    rng = np.random.default_rng(het.seed)
    n = base.num_devices

    def mult(spread: float) -> np.ndarray:
        if spread <= 1.0:
            return np.ones((n,), np.float32)
        lo, hi = -np.log(spread), np.log(spread)
        return np.exp(rng.uniform(lo, hi, n)).astype(np.float32)

    f_mult = mult(het.cpu_speed_spread)
    return dataclasses.replace(
        base,
        f_max=np.asarray(base.f_max * f_mult, np.float32),
        f_min=np.asarray(np.minimum(base.f_min * f_mult, base.f_max * f_mult),
                         np.float32),
        cycles_per_sample=np.asarray(
            base.cycles_per_sample * mult(het.cycles_spread), np.float32),
        energy_budget=np.asarray(
            base.energy_budget * mult(het.budget_spread), np.float32),
    )
