"""FL edge environment: stochastic channel process + device heterogeneity.

The paper (Sec. VII-A) draws channel gains i.i.d. from an exponential
distribution with mean 0.1, clipped to [0.01, 0.5], with a fixed seed across
runs. Device heterogeneity (CPU speed, data sizes, budgets) is configured
here so every experiment is reproducible from a seed.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import system_model as sm

# Redraw budget for the truncated exponential: ~10% of raw draws fall
# outside [0.01, 0.5] at the paper's defaults, so P(no valid draw in 64)
# is negligible (~1e-64); the final clip only ever touches that case.
_REDRAWS = 64


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    mean_gain: float = 0.1
    min_gain: float = 0.01
    max_gain: float = 0.5
    seed: int = 0


def sample_gains(key: jax.Array, num_rounds: int, num_devices: int,
                 mean_gain, min_gain, max_gain) -> jax.Array:
    """Pure device-side truncated-exponential gains, ``[T, N]`` float32.

    The functional core of :meth:`ChannelProcess.sample_jax` with the
    distribution parameters as (possibly traced) arguments, so the
    ScenarioArena can ``vmap`` it over a per-scenario (key, mean, clip)
    axis and pregenerate every scenario's channel sequence in one jit.
    Same redraw scheme as the numpy path: a ``[_REDRAWS, T, N]`` candidate
    block, each slot takes its first in-range draw, and only the
    measure-~exp(-64) no-valid-draw case is clipped to the boundary.
    """
    draws = (jax.random.exponential(
        key, (_REDRAWS, num_rounds, num_devices)) *
        jnp.asarray(mean_gain, jnp.float32))
    ok = (draws >= min_gain) & (draws <= max_gain)
    first = jnp.argmax(ok, axis=0)
    h = jnp.take_along_axis(draws, first[None], axis=0)[0]
    return jnp.clip(h, min_gain, max_gain).astype(jnp.float32)


class ChannelProcess:
    """IID exponential channel gains, clipped to a reasonable range.

    The paper filters outliers outside [0.01, 0.5]; we redraw instead of
    clipping so the stationary distribution is a *truncated* exponential
    (clipping would put atoms at the boundaries and bias the mean).

    Redraws are vectorised: a ``[64, ...]`` block of candidates is drawn
    at once and each device takes its first in-range value — no
    data-dependent host loop, so whole ``[T, N]`` channel sequences
    (:meth:`sample_sequence`, or :meth:`sample_jax` for device arrays)
    are one vectorised draw.
    """

    def __init__(self, num_devices: int, cfg: ChannelConfig = ChannelConfig()):
        self.num_devices = num_devices
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)

    def _first_in_range(self, draws, xp=np):
        """[R, ...] candidate block -> first in-range value along axis 0."""
        cfg = self.cfg
        ok = (draws >= cfg.min_gain) & (draws <= cfg.max_gain)
        first = xp.argmax(ok, axis=0)
        h = xp.take_along_axis(draws, first[None], axis=0)[0]
        # argmax == 0 with ok[0] False means no draw landed in range:
        # the clip puts only those (measure ~exp(-64)) on the boundary
        return xp.clip(h, cfg.min_gain, cfg.max_gain).astype(xp.float32)

    def sample(self) -> np.ndarray:
        return self._first_in_range(self._rng.exponential(
            self.cfg.mean_gain, (_REDRAWS, self.num_devices)))

    def sample_sequence(self, num_rounds: int, max_block: int = 256
                        ) -> np.ndarray:
        """[T, N] gains for a whole rollout — vectorised, no host loop
        over rounds (chunked at ``max_block`` rounds to bound the [64, T,
        N] candidate block's memory)."""
        out = []
        for t0 in range(0, num_rounds, max_block):
            t = min(max_block, num_rounds - t0)
            out.append(self._first_in_range(self._rng.exponential(
                self.cfg.mean_gain, (_REDRAWS, t, self.num_devices))))
        return np.concatenate(out) if out else np.zeros(
            (0, self.num_devices), np.float32)

    def sample_jax(self, key: jax.Array, num_rounds: Optional[int] = None
                   ) -> jax.Array:
        """Device-array gains — [T, N] (or [N] when ``num_rounds`` is
        None) drawn entirely on device, so ``run_scan``'s precomputed
        channel sequences never touch the host.  Keyed by ``key``, not
        the process seed (jax and numpy streams are independent).
        Delegates to the pure :func:`sample_gains` (the form the
        ScenarioArena vmaps over per-scenario channel statistics)."""
        t = 1 if num_rounds is None else num_rounds
        h = sample_gains(key, t, self.num_devices, self.cfg.mean_gain,
                         self.cfg.min_gain, self.cfg.max_gain)
        return h[0] if num_rounds is None else h

    def stream(self) -> Iterator[np.ndarray]:
        while True:
            yield self.sample()


@dataclasses.dataclass(frozen=True)
class HeterogeneityConfig:
    """System heterogeneity: per-device multipliers, log-uniform spread."""
    cpu_speed_spread: float = 1.0    # f_max multiplier range [1/s, s]
    cycles_spread: float = 1.0       # c_n multiplier range
    budget_spread: float = 1.0       # Ebar multiplier range
    seed: int = 0


def heterogeneous_params(base: sm.SystemParams,
                         het: HeterogeneityConfig) -> sm.SystemParams:
    """Apply log-uniform heterogeneity multipliers to a parameter set."""
    rng = np.random.default_rng(het.seed)
    n = base.num_devices

    def mult(spread: float) -> np.ndarray:
        if spread <= 1.0:
            return np.ones((n,), np.float32)
        lo, hi = -np.log(spread), np.log(spread)
        return np.exp(rng.uniform(lo, hi, n)).astype(np.float32)

    f_mult = mult(het.cpu_speed_spread)
    return dataclasses.replace(
        base,
        f_max=np.asarray(base.f_max * f_mult, np.float32),
        f_min=np.asarray(np.minimum(base.f_min * f_mult, base.f_max * f_mult),
                         np.float32),
        cycles_per_sample=np.asarray(
            base.cycles_per_sample * mult(het.cycles_spread), np.float32),
        energy_budget=np.asarray(
            base.energy_budget * mult(het.budget_spread), np.float32),
    )
