"""The full FL loop (Algorithm 1) with LROA (or baseline) control, wall-clock
latency and energy accounting, and periodic evaluation.

How a round executes (dataflow)
-------------------------------
All N clients' bucketed data is stacked into a device-resident bank ONCE
at trainer construction — a single-bucket
:class:`~repro.fl.client_bank.ClientBank` for (near-)uniform partitions,
or the bucket-ladder :class:`~repro.fl.client_bank.TieredClientBank` when
the partition spans multiple size tiers (``bank_mode='auto'``; skewed
non-iid splits would otherwise inflate the single global bucket to
``O(N * max_i n_i)`` device rows).  Per round t:
  1. observe channel gains h^t (ChannelProcess)                      [host]
  2. controller decides (f^t, p^t, q^t) — Algorithm 2 for LROA       [jit]
  3. sample K^t draws with replacement by q^t (DivFL selects
     deterministically via facility-location greedy on the shared
     channel-feature similarity)                                     [host]
  4. + 5. the fused fast path (``RoundEngine.round_step``): the K
     selected clients are gathered from the bank *inside* a SINGLE
     jitted computation (``jnp.take`` over the ``[N, B, ...]`` stacks)
     that runs all K local trainings (vmapped E-epoch SGD) and the
     unbiased aggregation (4) (Pallas ``fl_aggregate`` on TPU) — zero
     per-round host->device transfers of client data, one dispatch +
     one loss sync per round.  A tiered bank runs one such fused round
     per tier the selection hits (single-tier selections short-circuit
     to the single-bucket executable).  With a mesh, the client axis is
     shard_mapped over the ``data`` axis (per-shard training + partial
     reduce, cross-shard psum).
  6. queues update; latency += max_{n in K^t} T_n^t (eq. 10), energy
     accrues                                                         [host]

Every controller — DivFL included — rides the fused fast path: DivFL's
selection is a pure function of the round's channel gains (the same
facility-location greedy the arena traces), so no per-client host
round-trip is needed.  ``use_engine=False`` forces the sequential slow
path (one ``local_update`` per client, reading each client's true
examples as a bank slice via ``ClientBank.client_view``) — there DivFL
additionally observes each update vector between trainings, which
enriches its similarity metric from round 1 on (the reference
semantics).  The equivalence tests pin the two paths against each other.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import system_model as sm
from repro.core.baselines import DivFLController
from repro.core.controller import realized_round_time
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.fl.client_bank import ClientBank, TieredClientBank
from repro.fl.environment import ChannelProcess
from repro.fl.round_engine import RoundEngine
from repro.obs import trace as obs_trace

PyTree = Any


@dataclasses.dataclass
class RoundRecord:
    round: int
    wall_time: float          # realised latency of this round (eq. 10)
    cum_time: float
    mean_loss: float
    selected: List[int]
    q_min: float
    q_max: float
    queue_mean: float
    energy_mean: float        # realised mean energy this round
    test_accuracy: Optional[float] = None


@dataclasses.dataclass
class FLRunResult:
    records: List[RoundRecord]
    params: PyTree
    controller_name: str

    @property
    def total_time(self) -> float:
        return self.records[-1].cum_time if self.records else 0.0

    def accuracy_curve(self) -> List[tuple]:
        return [(r.round, r.cum_time, r.test_accuracy)
                for r in self.records if r.test_accuracy is not None]


class FederatedTrainer:
    """Controller-agnostic synchronous FL driver."""

    def __init__(self, task: fl_client.Task, params: sm.SystemParams,
                 controller, channel: ChannelProcess,
                 client_data: Sequence[tuple],
                 client_cfg: fl_client.ClientConfig,
                 lr_schedule: Callable[[jnp.ndarray], jnp.ndarray],
                 test_data: Optional[tuple] = None,
                 eval_every: int = 10, seed: int = 0,
                 use_engine: bool = True,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 bank_mode: str = "auto", bank_storage: str = "fp32"):
        assert len(client_data) == params.num_devices
        self.task = task
        self.params = params
        self.controller = controller
        self.channel = channel
        self.client_cfg = client_cfg
        self.lr_schedule = lr_schedule
        # Pre-convert the test set to device arrays once — evaluate() used to
        # re-upload the full test set on every call.
        self.test_data = (None if test_data is None else
                          (jnp.asarray(test_data[0]),
                           jnp.asarray(test_data[1])))
        self.eval_every = eval_every
        self.use_engine = use_engine
        self.engine = RoundEngine(task, client_cfg, mesh=mesh)
        # The ONE device upload of client data: every round (fused or
        # sequential) reads the bank from here on.  bank_mode 'auto'
        # builds the bucket-ladder TieredClientBank only when the
        # partition spans multiple size tiers; bank_storage 'int8' keeps
        # the rows quantized on device (dequantized inside the fused
        # gather — ~4x clients-per-byte; 'fp32' is the bitwise default).
        self.bank = self.engine.make_bank(client_data, tiered=bank_mode,
                                          storage=bank_storage)
        self._np_rng = np.random.default_rng(seed)
        self._jax_rng = jax.random.PRNGKey(seed)
        self.global_params = task.init(jax.random.PRNGKey(seed + 1))
        self.w = np.asarray(params.data_weights)
        # run_round must work standalone (not only via run()).
        self._records: List[RoundRecord] = []

    @property
    def _fused(self) -> bool:
        """True when rounds run on the fused engine fast path (the single
        eligibility rule shared by run_round, warmup, and run).  Every
        controller is eligible — DivFL's selection is a pure function of
        the round's channel gains, so nothing needs the per-client
        host loop any more."""
        return self.use_engine

    # -- warmup -----------------------------------------------------------

    def warmup(self) -> None:
        """Compile every local-training executable a full run can hit,
        without mutating any trainer state — benchmarks call this so
        steady-state timings exclude jit compilation.

        Fused path, single-bucket bank: ONE executable covers every
        selection (`round_step`'s trace depends only on the bank-wide
        masked/unmasked mode), so one call on a *copy* of the params
        compiles it (donation never touches the live model).  Tiered
        bank: one call per tier compiles each tier's single-bucket
        executable, plus one mixed selection cycling through the tiers
        compiles the tier-loop executable for that hit set; other
        hit-tier subsets (rounds hitting a strict subset of >= 2 tiers)
        still jit on first occurrence — the per-round compile universe is
        bounded by the ladder's rung count, not by the selection.
        Sequential path: one ``local_update`` per distinct post-padding
        data shape (``local_update``'s jit specializes on the array
        shape, not just the step count).  All outputs are discarded.
        Warmup *executes* real calls rather than AOT ``lower().compile()``
        because the AOT path does not populate the jit call cache — a
        subsequent real call would trace and compile again.
        """
        rng = jax.random.PRNGKey(0)
        sizes = [int(s) for s in self.bank.sizes]
        bs = self.client_cfg.batch_size
        if self._fused:
            k = self.params.sample_count
            p = jax.tree_util.tree_map(jnp.copy, self.global_params)
            if (isinstance(self.bank, TieredClientBank)
                    and self.bank.num_tiers > 1):
                reps = [int(m[0]) for m in self.bank.tier_members]
                sels = [np.full(k, r, np.int64) for r in reps]
                sels.append(np.asarray([reps[i % len(reps)]
                                        for i in range(k)], np.int64))
            else:
                sels = [np.zeros(k, np.int64)]
            for sel in sels:
                # zero lr/coeffs keep the chained params numerically
                # inert; chaining respects donation off-CPU
                p, _ = self.engine.round_step(
                    p, self.bank, sel, np.zeros(k, np.float32), 0.0,
                    jax.random.split(rng, k))
            jax.block_until_ready(jax.tree_util.tree_leaves(p))
        else:
            seen = set()
            for i, n in enumerate(sizes):
                eff = max(n, bs)   # local_update tiles n < bs up to bs
                if eff in seen:
                    continue
                seen.add(eff)
                x, y = self.bank.client_view(i)
                delta, _ = fl_client.local_update(
                    self.task, self.global_params, x, y, 0.0, rng,
                    self.client_cfg)
                jax.block_until_ready(jax.tree_util.tree_leaves(delta))
        # decide() is pure for every controller and evaluate() only reads
        # state, so warming their executables mutates nothing either
        self.controller.decide(jnp.ones((self.params.num_devices,),
                                        jnp.float32))
        if self.test_data is not None:
            self.evaluate()

    # -- evaluation -------------------------------------------------------

    def evaluate(self) -> float:
        if self.test_data is None:
            return float("nan")
        x, y = self.test_data
        m = self.task.metrics(self.global_params, {"x": x, "y": y})
        return float(m["accuracy"])

    # -- one round --------------------------------------------------------

    def _client_rngs(self, count: int) -> jax.Array:
        """Split the trainer key ``count`` times (same draws as the
        sequential per-client loop, so both paths see identical client
        randomness)."""
        subs = []
        for _ in range(count):
            self._jax_rng, sub = jax.random.split(self._jax_rng)
            subs.append(sub)
        return jnp.stack(subs)

    def _train_fused(self, selected: np.ndarray, coeffs: np.ndarray,
                     lr: float) -> List[float]:
        """Fast path: one fused jit gathers the selected clients from the
        device-resident bank, trains all K, and applies eq. (4)."""
        rngs = self._client_rngs(len(selected))
        self.global_params, losses = self.engine.round_step(
            self.global_params, self.bank, selected, coeffs, lr, rngs)
        return [float(l) for l in np.asarray(losses)]

    def _train_sequential(self, selected: np.ndarray, coeffs: np.ndarray,
                          lr: float) -> List[float]:
        """Slow path: per-client dispatch (DivFL / reference semantics),
        reading each client's true examples as a bank slice."""
        deltas, losses = [], []
        for idx in selected:
            x, y = self.bank.client_view(int(idx))
            self._jax_rng, sub = jax.random.split(self._jax_rng)
            delta, loss = fl_client.local_update(
                self.task, self.global_params, x, y, lr, sub, self.client_cfg)
            deltas.append(delta)
            losses.append(loss)
            if isinstance(self.controller, DivFLController):
                self.controller.observe_updates(
                    np.asarray([idx]),
                    fl_client.flatten_update(delta)[None, :])
        if isinstance(self.controller, DivFLController):
            # DivFL approximates the full update from the diverse subset:
            # plain data-weighted averaging over the chosen clients.
            self.global_params = fl_server.fedavg_reference(
                self.global_params, deltas, self.w[np.asarray(selected)])
        else:
            self.global_params = fl_server.aggregate(
                self.global_params, deltas, coeffs)
        return losses

    def run_round(self, t: int) -> RoundRecord:
        with obs_trace.span("trainer.round", t=int(t)):
            return self._run_round_impl(t)

    def _run_round_impl(self, t: int) -> RoundRecord:
        h = jnp.asarray(self.channel.sample())
        decision = self.controller.decide(h)
        q = np.asarray(decision.q)

        if isinstance(self.controller, DivFLController):
            selected = self.controller.select(h)
        else:
            selected = fl_server.sample_clients(self._np_rng, q,
                                                self.params.sample_count)

        lr = float(self.lr_schedule(jnp.asarray(t)))
        coeffs = fl_server.aggregation_weights(
            selected, q, self.w, self.params.sample_count)
        if self._fused:
            losses = self._train_fused(selected, coeffs, lr)
        else:
            losses = self._train_sequential(selected, coeffs, lr)

        wall = realized_round_time(self.params, h, decision,
                                   np.asarray(selected))
        e_round = np.asarray(sm.round_energy(self.params, h, decision.p,
                                             decision.f))
        self.controller.step_queues(h, decision)

        cum = (self._records[-1].cum_time if self._records else 0.0) + wall
        rec = RoundRecord(
            round=t, wall_time=wall, cum_time=cum,
            mean_loss=float(np.mean(losses)) if losses else float("nan"),
            selected=[int(i) for i in selected],
            q_min=float(q.min()), q_max=float(q.max()),
            queue_mean=float(np.asarray(self.controller.queues).mean()),
            energy_mean=float(e_round[np.unique(selected)].mean()),
        )
        if self.test_data is not None and (t % self.eval_every == 0):
            rec.test_accuracy = self.evaluate()
        self._records.append(rec)
        return rec

    # -- full run ---------------------------------------------------------

    def run(self, num_rounds: int, verbose: bool = False) -> FLRunResult:
        self._records = []
        for t in range(num_rounds):
            rec = self.run_round(t)
            if verbose and (t % max(num_rounds // 10, 1) == 0):
                print(f"[{getattr(self.controller, 'name', '?')}] round {t} "
                      f"loss {rec.mean_loss:.4f} wall {rec.wall_time:.1f}s "
                      f"cum {rec.cum_time:.0f}s acc {rec.test_accuracy}")
        if self.test_data is not None and self._records:
            self._records[-1].test_accuracy = self.evaluate()
        # With buffer donation on (GPU/TPU), any later fused round donates
        # the live global_params buffers, which would invalidate a
        # previously returned result's params — snapshot them so results
        # stay readable.  The sequential path never donates, so it skips
        # the copy.
        params = (jax.tree_util.tree_map(jnp.copy, self.global_params)
                  if self.engine.donate and self._fused
                  else self.global_params)
        return FLRunResult(records=self._records, params=params,
                           controller_name=getattr(self.controller, "name",
                                                   "unknown"))
