"""ClientBank — the device-resident FL data plane.

The trainer used to gather each round's K sampled clients on the host
(``[K, B, ...]`` numpy stacks) and re-upload them to the device — the
dominant non-compute cost once the round itself is one fused jit.  The
bank inverts that: ALL N clients' bucketed data is tiled and stacked to
``[N, B, ...]`` ONCE at construction, uploaded once, and every round
gathers its K selected rows *inside* the jit with ``jnp.take`` — zero
per-round host->device transfers of client data, and N (not K) becomes
the unit the system scales over.

Ownership / memory contract
---------------------------
* The bank owns the only device copy of the ``[N, B, ...]`` stacks plus
  the ``[N]`` ``num_steps`` / ``num_examples`` masks.  They are
  **never donated**: the round engine donates only the params (and scan
  queue) buffers, so one bank serves every round, every policy, and any
  number of concurrent rollouts.
* Host retention is bounded by the TRUE data volume ``sum_i n_i`` (a
  private copy per client, decoupled from caller mutation), never the
  tiled ``O(N * max_i n_i)`` form: :meth:`client_view` (the sequential /
  DivFL path) reads those copies directly, and :meth:`gather_host` (the
  PR-1 host-stacked round, retained for equivalence tests and
  transfer-cost benchmarking) lazily rebuilds — then caches — the tiled
  stacks only if it is actually used.
* With a mesh, the client axis is placed with
  ``NamedSharding(P(mesh_axis))`` when ``N`` divides the axis size —
  each shard holds ``N / axis_size`` clients' buckets and the round
  engine's ``shard_map`` trains/reduces per shard (cross-shard ``psum``
  in the aggregation).  Otherwise the bank is replicated.

Bucketing contract (see ``repro.data.pipeline`` / ``repro.fl.client``):
one GLOBAL bucket ``B = bucket_num_batches(max_i ceil(n_i / bs)) * bs``
covers every client, so the whole system compiles exactly one data shape
per task.  Clients are cyclically tiled to ``B`` rows; ``num_steps``
keeps each client at its true ``max(n_i // bs, 1)`` applied optimizer
steps and ``num_examples`` keeps epoch sampling off the padded duplicate
rows, so padding changes neither training distributions nor step counts.

The evaluation half of the data plane follows the same contract:
``repro.sim.eval.EvalBank`` holds the TEST set device-resident (uploaded
once at construction, blocking, never-aliasing, never-donated) and
evaluates stacked ``[S, ...]`` params in one vmapped ``task.metrics``
pass — the ScenarioArena's on-device replacement for host-side per-lane
evaluation loops.

Tier ladder (:class:`TieredClientBank`)
---------------------------------------
The single global bucket makes DEVICE memory ``O(N * max_i n_i)`` — a
heavily skewed partition (one giant client) taxes every row with the
skew.  :class:`TieredClientBank` removes that waste: clients are grouped
by their own power-of-two bucket into a small ladder of size tiers
(``data.pipeline.assign_tiers``), each tier is its own
:class:`ClientBank` holding a ``[N_t, B_t, ...]`` stack, and global
``tier_of`` / ``pos_in_tier`` index maps translate trainer-level client
ids to (tier, row).  Device memory is bounded by
``sum_t N_t * B_t ~ sum_i n_i`` instead of ``N * max_i n_i``, and the
system compiles one data shape PER TIER instead of one global shape.
All per-tier invariants (cyclic tiling, masks, masked/unmasked trace
equivalence, never-donated buffers, mesh N-axis sharding when divisible)
are inherited unchanged from the per-tier :class:`ClientBank`, and a
one-tier ladder is literally a single :class:`ClientBank` — the round
engine's tiered path is bit-identical to the single-bucket path there.

Scale plane (PR 10)
-------------------
Three N-axis multipliers live behind the same bank interface (see
docs/architecture.md "Scale plane"): opt-in ``storage='int8'`` keeps the
xs stacks int8 with per-client affine codes dequantized inside the fused
gather (~4x clients-per-byte; fp32 path bitwise-untouched);
:class:`BankPool` recycles slots of a fixed ``[N_cap, B, ...]`` shape so
population churn costs one row upload and zero retraces; and per-client
k-means cluster routing (``clusters=``) feeds
``server.aggregate_hierarchical``'s cluster-then-global eq.-(4) reduce.
``nbytes`` / ``bytes_per_client`` make the footprint a tracked number on
every bank.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import (assign_clusters, assign_tiers,
                                 client_bucket_examples,
                                 client_cluster_features, dequantize_stack,
                                 kmeans_clusters, pad_client_data,
                                 quantize_stack, stack_client_arrays,
                                 validate_client_data)
from repro.fl import client as fl_client

_STORAGES = ("fp32", "int8")


def _check_storage(storage: str) -> str:
    if storage not in _STORAGES:
        raise ValueError(f"storage must be one of {_STORAGES}, "
                         f"got {storage!r}")
    return storage


def estimate_bank_nbytes(sizes: Sequence[int], batch_size: int,
                         feature_shape: Tuple[int, ...],
                         label_shape: Tuple[int, ...] = (),
                         feature_dtype=np.float32,
                         label_dtype=np.int32,
                         storage: str = "fp32") -> int:
    """Device bytes a single-bucket :class:`ClientBank` WOULD hold.

    Pure accounting over the bucketing contract — no allocation — so the
    scale bench can record the fp32 one-shot footprint at an N where
    actually constructing it is exactly the infeasibility being claimed.
    Mirrors :attr:`ClientBank.nbytes`: the ``[N, B, ...]`` xs/ys stacks,
    the two ``[N]`` int32 masks, and (int8 mode) the ``[N]`` f32
    scale/zero codes.
    """
    _check_storage(storage)
    n = len(sizes)
    b = max(client_bucket_examples(int(s), batch_size) for s in sizes)
    feat = int(np.prod(feature_shape, dtype=np.int64)) if feature_shape else 1
    lab = int(np.prod(label_shape, dtype=np.int64)) if label_shape else 1
    x_item = 1 if storage == "int8" else np.dtype(feature_dtype).itemsize
    total = n * b * feat * x_item
    total += n * b * lab * np.dtype(label_dtype).itemsize
    total += 2 * n * 4                       # num_steps / num_examples
    if storage == "int8":
        total += 2 * n * 4                   # x_scale / x_zero
    return int(total)


class ClientBank:
    """Device-resident ``[N, B, ...]`` stacks of every client's data."""

    def __init__(self, client_data: Sequence[tuple],
                 client_cfg: fl_client.ClientConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "data", storage: str = "fp32",
                 clusters: Optional[int] = None):
        self.batch_size = client_cfg.batch_size
        self.storage = _check_storage(storage)
        validate_client_data(client_data)
        # Host retention is the TRUE data (sum_i n_i rows, private copies
        # decoupled from caller mutation), not the tiled [N, B, ...]
        # mirror: with skewed sizes the global bucket makes the tiled form
        # O(N * max_i n_i), which would defeat scaling over N.  The tiled
        # stacks exist transiently for the upload (and lazily again only
        # if the test/bench-only gather_host is used).
        self._clients = [(np.array(x), np.array(y)) for x, y in client_data]
        host_x, host_y, num_steps, num_examples = stack_client_arrays(
            self._clients, self.batch_size)
        self._num_steps, self._num_examples = num_steps, num_examples
        self._tiled: Optional[tuple] = None
        self.num_clients = host_x.shape[0]
        self.bucket_examples = host_x.shape[1]
        self.steps_per_epoch = self.bucket_examples // self.batch_size
        # Every client exactly fills the bucket => the masks are inert and
        # the engine may use the cheaper unmasked SGD trace.
        self.uniform = bool(np.all(num_examples == self.bucket_examples))
        self.mesh, self.mesh_axis = mesh, mesh_axis
        if self.storage == "int8":
            # Per-client affine codes; the fused gather dequantizes the K
            # selected rows right after jnp.take, so the full stack lives
            # int8 on device and fp32 rows never materialize at [N, ...].
            q, scale, zero = quantize_stack(host_x)
            self.xs = self._to_device(q)
            self.x_scale = self._to_device(scale)
            self.x_zero = self._to_device(zero)
        else:
            self.xs = self._to_device(host_x)
            self.x_scale = self.x_zero = None
        self.ys = self._to_device(host_y)
        # the masks are also retained host-side (gather_host/sizes): upload
        # private copies so a zero-copy device_put can't alias them
        self.num_steps = self._to_device(num_steps.copy())
        self.num_examples = self._to_device(num_examples.copy())
        # Cluster routing for hierarchical eq.-(4) aggregation: host-side
        # k-means over per-client summary features, mirrored to device for
        # the in-jit segment reduce.  Control-plane data, like tiers.
        if clusters is not None:
            feats = client_cluster_features(self._clients)
            self.cluster_of, self.cluster_centroids = kmeans_clusters(
                feats, clusters)
            self.num_clusters = int(self.cluster_centroids.shape[0])
            self.cluster_of_device = jnp.asarray(self.cluster_of, jnp.int32)
        else:
            self.cluster_of = self.cluster_centroids = None
            self.num_clusters = 0
            self.cluster_of_device = None
        # The ONE host->device upload happens here, not lazily: block so
        # the device copy can't race callers mutating their arrays after
        # construction (transfers are async).
        jax.block_until_ready((self.xs, self.ys, self.num_steps,
                               self.num_examples))

    def _to_device(self, arr: np.ndarray) -> jax.Array:
        # ``arr`` is always a freshly built stack (never caller-owned), so
        # uploads may read it in place.  With a mesh placement, device_put
        # straight from host so each device receives only its shard — a
        # jnp.array staging hop would commit the full unsharded stack to
        # one device first, the exact OOM the sharded bank avoids.
        placement = self._placement()
        if placement is None:
            # jnp.array (copy semantics) so the device buffer can't alias
            # host memory the constructor is about to drop.
            return jnp.array(arr)
        return jax.device_put(arr, placement)

    def _placement(self):
        """NamedSharding over the client axis when the mesh divides N."""
        if self.mesh is None:
            return None
        shards = self.mesh.shape[self.mesh_axis]
        spec = (jax.sharding.PartitionSpec(self.mesh_axis)
                if shards > 1 and self.num_clients % shards == 0
                else jax.sharding.PartitionSpec())
        return jax.sharding.NamedSharding(self.mesh, spec)

    @property
    def sizes(self) -> np.ndarray:
        """True per-client dataset sizes ``n_i`` (host, [N])."""
        return self._num_examples

    @property
    def true_examples(self) -> int:
        """``sum_i n_i`` — the irreducible example count."""
        return int(self._num_examples.sum())

    @property
    def padded_examples(self) -> int:
        """``N * B`` — device rows actually held (incl. tiling padding)."""
        return self.num_clients * self.bucket_examples

    @property
    def nbytes(self) -> int:
        """Device bytes held: xs/ys stacks, masks, and (int8) the
        scale/zero codes — the tracked number behind the memory claim."""
        arrs = [self.xs, self.ys, self.num_steps, self.num_examples]
        if self.x_scale is not None:
            arrs += [self.x_scale, self.x_zero]
        return int(sum(a.size * a.dtype.itemsize for a in arrs))

    @property
    def bytes_per_client(self) -> float:
        """:attr:`nbytes` amortized over N — the clients-per-byte axis the
        int8 mode multiplies ~4x."""
        return self.nbytes / self.num_clients

    def quant_args(self) -> Tuple[Optional[jax.Array], Optional[jax.Array]]:
        """Per-client affine codes ``(x_scale, x_zero)`` for the in-gather
        dequantization, or ``(None, None)`` in fp32 mode (the engine keys
        its executables on that, so the fp32 trace is literally the old
        one)."""
        return self.x_scale, self.x_zero

    def device_args(self) -> Tuple[jax.Array, jax.Array,
                                   Optional[jax.Array],
                                   Optional[jax.Array]]:
        """(xs, ys, num_steps, num_examples) for in-jit gathering.

        The masks come back None for a uniform bank (every client fills
        the bucket) — selecting the cheaper unmasked SGD trace; thanks to
        the shared epoch-permutation keys the two traces are
        bit-identical there anyway.
        """
        if self.uniform:
            return self.xs, self.ys, None, None
        return self.xs, self.ys, self.num_steps, self.num_examples

    # -- host-side views ---------------------------------------------------

    def gather_host(self, selected: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray,
                               Optional[np.ndarray], Optional[np.ndarray]]:
        """PR-1-style host gather of the selected rows -> ``[K, B, ...]``.

        Same bucket, same tiled rows as the device path — kept for the
        bank-vs-host equivalence tests and the host-restacked benchmark
        baseline (which is why the tiled stacks are cached after the
        first call, matching PR 1's pad cache; production rounds never
        call this, so the cache stays unbuilt there).  ``num_steps`` /
        ``num_examples`` are None when every selected client exactly
        fills the bucket (the PR-1 unmasked trace), else the selected
        ``[K]`` mask rows.

        Always the UNQUANTIZED fp32 rows, even for an int8 bank — this is
        the reference the quantization tolerance contract is stated
        against (``|dequant(q) - x| <= 0.5 * scale_i``).
        """
        if self._tiled is None:
            self._tiled = stack_client_arrays(self._clients,
                                              self.batch_size)[:2]
        host_x, host_y = self._tiled
        idx = np.asarray(selected, np.int64)
        xs, ys = host_x[idx], host_y[idx]
        if np.all(self._num_examples[idx] == self.bucket_examples):
            return xs, ys, None, None
        return xs, ys, self._num_steps[idx], self._num_examples[idx]

    def client_view(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client ``i``'s true (x, y) — the bank's private host copy (the
        first ``n_i`` rows of its device slice hold the same values, by
        the cyclic-tiling contract).  The sequential / DivFL path reads
        these instead of retained caller datasets."""
        return self._clients[i]


class TieredClientBank:
    """Bucket-ladder bank: one :class:`ClientBank` per power-of-two size
    tier, plus global-index maps.

    Clients are grouped by ``data.pipeline.assign_tiers`` (per-client
    power-of-two buckets, greedily merged down to ``max_tiers`` rungs).
    Tier ``t`` holds its members' data as an ordinary per-tier
    :class:`ClientBank` — a ``[N_t, B_t, ...]`` device stack with that
    tier's masks, inheriting every single-bucket invariant — so device
    memory is ``sum_t N_t * B_t`` (~``sum_i n_i``) instead of the global
    bucket's ``N * max_i n_i``.

    The maps are the tiered contract: ``tier_of[i]`` names client i's
    tier and ``pos_in_tier[i]`` its row in that tier's stack (members
    keep ascending global order within a tier, so a one-tier ladder has
    ``pos_in_tier == arange(N)`` and the single tier IS the single-bucket
    bank).  ``tier_of_device`` / ``pos_device`` are the same maps as
    device arrays for the round engine's in-jit tier loop (run_scan).
    """

    def __init__(self, client_data: Sequence[tuple],
                 client_cfg: fl_client.ClientConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "data", max_tiers: int = 4,
                 assignment: Optional[tuple] = None,
                 storage: str = "fp32"):
        self.batch_size = client_cfg.batch_size
        self.storage = _check_storage(storage)
        validate_client_data(client_data)
        sizes = [int(np.asarray(x).shape[0]) for x, _ in client_data]
        self.num_clients = len(sizes)
        # ``assignment``: a precomputed ``assign_tiers`` result, so a
        # caller that already ran the ladder decision (RoundEngine's
        # 'auto' mode) can hand it over instead of recomputing it
        if assignment is None:
            assignment = assign_tiers(sizes, self.batch_size, max_tiers)
        tier_of, buckets = assignment
        self.tier_of = tier_of
        self.tier_buckets = buckets
        self.num_tiers = len(buckets)
        self.tier_members = [np.flatnonzero(tier_of == t)
                             for t in range(self.num_tiers)]
        pos = np.zeros(self.num_clients, np.int32)
        for members in self.tier_members:
            pos[members] = np.arange(members.size, dtype=np.int32)
        self.pos_in_tier = pos
        self.tiers = [ClientBank([client_data[i] for i in members],
                                 client_cfg, mesh=mesh, mesh_axis=mesh_axis,
                                 storage=storage)
                      for members in self.tier_members]
        # device copies for the in-jit tier loop (scan samples clients on
        # device, so the tier routing must be traceable)
        self.tier_of_device = jnp.asarray(tier_of, jnp.int32)
        self.pos_device = jnp.asarray(pos, jnp.int32)
        self.mesh, self.mesh_axis = mesh, mesh_axis

    @property
    def sizes(self) -> np.ndarray:
        """True per-client dataset sizes ``n_i`` in GLOBAL order ([N])."""
        out = np.zeros(self.num_clients, np.int32)
        for members, bank in zip(self.tier_members, self.tiers):
            out[members] = bank.sizes
        return out

    @property
    def true_examples(self) -> int:
        """``sum_i n_i`` — the irreducible example count."""
        return sum(bank.true_examples for bank in self.tiers)

    @property
    def padded_examples(self) -> int:
        """``sum_t N_t * B_t`` — device rows held across the ladder."""
        return sum(bank.padded_examples for bank in self.tiers)

    @property
    def nbytes(self) -> int:
        """Device bytes held across the ladder (sum of per-tier banks)."""
        return sum(bank.nbytes for bank in self.tiers)

    @property
    def bytes_per_client(self) -> float:
        """:attr:`nbytes` amortized over the GLOBAL client count."""
        return self.nbytes / self.num_clients

    def client_view(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client ``i``'s true (x, y) via its tier's private host copy
        (the sequential / DivFL path, same contract as
        :meth:`ClientBank.client_view`)."""
        return self.tiers[self.tier_of[i]].client_view(
            int(self.pos_in_tier[i]))


class BankPool:
    """Slot-recycled streaming pool: a fixed-capacity device-resident
    ``[N_cap, B, ...]`` bank whose population churns WITHOUT retracing.

    The one-shot banks above freeze their population at construction —
    admitting a new client means a new bank, a new layout key, and a new
    executable.  The pool instead allocates the stacks ONCE at a static
    ``(capacity, B)`` shape and turns client turnover into data motion
    over that shape: admitting a client tiles its rows to ``B``,
    optionally quantizes them, and writes them into a free slot with one
    donating in-place ``.at[slot].set`` scatter (ONE row upload, slot id
    read as data); evicting only returns the slot to the free list (zero
    device work — the stale rows are unreachable behind the slot table).
    Every executable the engine compiled against the pool keeps firing
    across unlimited churn: the strict watchdog contract is ZERO retraces
    after :meth:`warmup`.

    Implements the bank interface (``device_args`` / ``quant_args`` /
    sizes / accounting), so ``RoundEngine.round_step`` / ``run_scan`` and
    the arena ride it unchanged.  Differences from :class:`ClientBank`:

    * ``uniform`` is always False — the masked trace stays valid for any
      resident mix, so churn can never flip the executable choice.
    * Buffers ARE donated (to the pool's own scatter): callers must
      re-read :meth:`device_args` after an admit rather than hold stale
      references.
    * Selection is over SLOTS: decide rules draw from
      :meth:`sample_slots` / :meth:`slots_for`; empty slots hold inert
      rows (``num_steps=1`` over zeros) but are the caller's job to avoid.

    Tallies (admits/evicts/uploads/traces, quantization error) are views
    over the shared obs :class:`~repro.obs.metrics.MetricsRegistry` under
    the ``pool.*`` namespace (PR-9 contract).
    """

    def __init__(self, client_cfg: fl_client.ClientConfig, capacity: int,
                 max_examples: Optional[int] = None,
                 feature_shape: Optional[Tuple[int, ...]] = None,
                 label_shape: Tuple[int, ...] = (),
                 feature_dtype=np.float32, label_dtype=np.int32,
                 storage: str = "fp32", clusters: Optional[int] = None,
                 initial_clients: Optional[Dict[int, tuple]] = None,
                 registry=None):
        from repro.obs.metrics import MetricsRegistry
        self.batch_size = client_cfg.batch_size
        self.storage = _check_storage(storage)
        self.registry = registry if registry is not None else MetricsRegistry()
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        init_items = list(initial_clients.items()) if initial_clients else []
        if init_items:
            validate_client_data([pair for _, pair in init_items])
            if len(init_items) > self.capacity:
                raise ValueError(f"{len(init_items)} initial clients exceed "
                                 f"pool capacity {self.capacity}")
            x0, y0 = init_items[0][1]
            x0, y0 = np.asarray(x0), np.asarray(y0)
            feature_shape = tuple(x0.shape[1:])
            label_shape = tuple(y0.shape[1:])
            feature_dtype, label_dtype = x0.dtype, y0.dtype
            sizes = [np.asarray(x).shape[0] for _, (x, _) in init_items]
            max_examples = max(int(max_examples or 0), max(sizes))
        elif feature_shape is None or max_examples is None:
            raise ValueError("an empty pool needs feature_shape and "
                             "max_examples to fix its static [N_cap, B, "
                             "...] shape up front")
        self.feature_shape = tuple(feature_shape)
        self.label_shape = tuple(label_shape)
        self.feature_dtype = np.dtype(feature_dtype)
        self.label_dtype = np.dtype(label_dtype)
        if not np.issubdtype(self.feature_dtype, np.floating):
            raise ValueError(f"feature_dtype {self.feature_dtype} is not a "
                             f"float dtype")
        self.bucket_examples = client_bucket_examples(int(max_examples),
                                                      self.batch_size)
        self.steps_per_epoch = self.bucket_examples // self.batch_size
        self.num_clients = self.capacity          # bank-interface N
        # Churn must never flip the executable: always take the masked
        # trace, even if the residents happen to be uniform right now.
        self.uniform = False
        self.mesh, self.mesh_axis = None, "data"
        b = self.bucket_examples
        # Empty slots hold inert rows: one step over zeros, full-bucket
        # num_examples, identity dequant codes.  Defined (non-NaN)
        # behavior even if a decide rule mistakenly selects one.
        self.xs = jnp.zeros((self.capacity, b) + self.feature_shape,
                            jnp.int8 if self.storage == "int8"
                            else self.feature_dtype)
        self.ys = jnp.zeros((self.capacity, b) + self.label_shape,
                            self.label_dtype)
        self.num_steps = jnp.ones((self.capacity,), jnp.int32)
        self.num_examples = jnp.full((self.capacity,), b, jnp.int32)
        if self.storage == "int8":
            self.x_scale = jnp.ones((self.capacity,), jnp.float32)
            self.x_zero = jnp.zeros((self.capacity,), jnp.float32)
        else:
            self.x_scale = self.x_zero = None
        # Cluster routing: centroids are fitted ONCE on the initial
        # population and stay fixed, so an admitted client's cluster id
        # never depends on admission order.
        if clusters is not None:
            if not init_items:
                raise ValueError("clusters needs initial_clients to fit "
                                 "centroids on")
            feats = client_cluster_features([p for _, p in init_items])
            _, self.cluster_centroids = kmeans_clusters(feats, clusters)
            self.num_clusters = int(self.cluster_centroids.shape[0])
            self.cluster_of = np.zeros(self.capacity, np.int32)
            self.cluster_of_device = jnp.zeros((self.capacity,), jnp.int32)
        else:
            self.cluster_centroids = self.cluster_of = None
            self.num_clusters = 0
            self.cluster_of_device = None
        self._buffer_names = ["xs", "ys", "num_steps", "num_examples"]
        if self.storage == "int8":
            self._buffer_names += ["x_scale", "x_zero"]
        if self.cluster_of_device is not None:
            self._buffer_names += ["cluster_of_device"]
        # ONE donating scatter executable for the pool's lifetime: built
        # here, traced on the first admit (or warmup), and counted so the
        # zero-retrace contract is a tracked number, not a hope.
        def _scatter(buffers, slot, rows):
            self.registry.counter("pool.traces").inc()
            return tuple(buf.at[slot].set(row)
                         for buf, row in zip(buffers, rows))
        # donation makes the scatter a true in-place row write; on CPU it
        # is a no-op (warning), so gate it like the engine does
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._scatter = jax.jit(_scatter, donate_argnums=donate)
        # Host-side slot table + bounded true-data retention (private
        # copies of RESIDENT clients only, dropped on evict).
        self.slot_of: Dict[object, int] = {}
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        self._host: Dict[object, tuple] = {}
        self._sizes = np.zeros(self.capacity, np.int32)
        jax.block_until_ready(tuple(getattr(self, n)
                                    for n in self._buffer_names))
        for cid, (x, y) in init_items:
            self.admit(cid, x, y)

    # -- churn --------------------------------------------------------------

    def admit(self, client_id, x: np.ndarray, y: np.ndarray) -> int:
        """Bring a client resident: tile → (quantize) → one in-place row
        scatter into a free slot.  Returns the slot id."""
        if client_id in self.slot_of:
            raise ValueError(f"client {client_id!r} is already resident "
                             f"(slot {self.slot_of[client_id]})")
        if not self._free:
            raise ValueError(f"pool is full ({self.capacity} slots) — "
                             f"evict before admitting")
        x, y = np.asarray(x), np.asarray(y)
        validate_client_data([(x, y)])
        if (x.dtype, x.shape[1:]) != (self.feature_dtype,
                                      self.feature_shape) or \
           (y.dtype, y.shape[1:]) != (self.label_dtype, self.label_shape):
            raise ValueError(
                f"client {client_id!r}: (x {x.dtype} {x.shape[1:]}, "
                f"y {y.dtype} {y.shape[1:]}) does not match the pool's "
                f"static spec (x {self.feature_dtype} {self.feature_shape},"
                f" y {self.label_dtype} {self.label_shape})")
        n = int(x.shape[0])
        if n > self.bucket_examples:
            raise ValueError(
                f"client {client_id!r}: {n} examples exceed the pool "
                f"bucket B={self.bucket_examples} — size the pool's "
                f"max_examples for the largest admissible client")
        px, py = pad_client_data(x, y, self.bucket_examples)
        ns = np.int32(max(n // self.batch_size, 1))
        ne = np.int32(n)
        rows = {"ys": jnp.asarray(py), "num_steps": jnp.asarray(ns),
                "num_examples": jnp.asarray(ne)}
        if self.storage == "int8":
            q, scale, zero = quantize_stack(px[None])
            err = float(np.abs(dequantize_stack(q, scale, zero)
                               - px[None].astype(np.float32)).max())
            self.registry.histogram("pool.quant.abs_err").observe(err)
            rows["xs"] = jnp.asarray(q[0])
            rows["x_scale"] = jnp.asarray(scale[0])
            rows["x_zero"] = jnp.asarray(zero[0])
        else:
            rows["xs"] = jnp.asarray(px)
        slot = self._free.pop()
        if self.cluster_of_device is not None:
            feats = client_cluster_features([(x, y)])
            cid = assign_clusters(feats, self.cluster_centroids)[0]
            self.cluster_of[slot] = cid
            rows["cluster_of_device"] = jnp.asarray(np.int32(cid))
        buffers = tuple(getattr(self, name) for name in self._buffer_names)
        row_vals = tuple(rows[name] for name in self._buffer_names)
        new_buffers = self._scatter(buffers, jnp.int32(slot), row_vals)
        for name, buf in zip(self._buffer_names, new_buffers):
            setattr(self, name, buf)
        self.slot_of[client_id] = slot
        self._host[client_id] = (x.copy(), y.copy())
        self._sizes[slot] = n
        self.registry.counter("pool.admits").inc()
        self.registry.counter("pool.uploads").inc()
        self.registry.gauge("pool.resident").set(len(self.slot_of))
        return slot

    def evict(self, client_id) -> int:
        """Return a client's slot to the free list.  Zero device work —
        the rows stay in place but become unreachable behind the slot
        table; a later admit overwrites them.  Returns the freed slot."""
        if client_id not in self.slot_of:
            raise ValueError(f"client {client_id!r} is not resident")
        slot = self.slot_of.pop(client_id)
        self._free.append(slot)
        self._host.pop(client_id, None)
        self._sizes[slot] = 0
        self.registry.counter("pool.evicts").inc()
        self.registry.gauge("pool.resident").set(len(self.slot_of))
        return slot

    def warmup(self) -> None:
        """Trace the scatter once (admit+evict a throwaway client) so the
        strict watchdog can arm over a pool whose churn path is already
        compiled — every later admit is a cache hit.  A no-op when any
        admit already ran (the executable exists; a full pool needs no
        sentinel and has no slot for one)."""
        if self.uploads:
            jax.block_until_ready(self.xs)
            return
        sentinel = object()
        x = np.zeros((1,) + self.feature_shape, self.feature_dtype)
        y = np.zeros((1,) + self.label_shape, self.label_dtype)
        self.admit(sentinel, x, y)
        self.evict(sentinel)
        jax.block_until_ready(self.xs)

    # -- slot views ---------------------------------------------------------

    def slots_for(self, client_ids: Sequence) -> np.ndarray:
        """Resident clients' slots, in the given order ([K] int32)."""
        return np.asarray([self.slot_of[c] for c in client_ids], np.int32)

    def sample_slots(self, rng: np.random.Generator, k: int) -> np.ndarray:
        """Draw ``k`` distinct OCCUPIED slots — the decide-rule feed over
        a churning population (empty slots never selected)."""
        occupied = np.asarray(sorted(self.slot_of.values()), np.int32)
        if k > occupied.size:
            raise ValueError(f"asked for {k} slots but only "
                             f"{occupied.size} are occupied")
        return np.asarray(rng.choice(occupied, size=k, replace=False),
                          np.int32)

    def client_view(self, client_id) -> Tuple[np.ndarray, np.ndarray]:
        """A resident client's true (x, y) — the pool's private host
        copy (dropped at evict; same contract as the banks')."""
        return self._host[client_id]

    # -- bank interface -----------------------------------------------------

    def device_args(self) -> Tuple[jax.Array, jax.Array,
                                   Optional[jax.Array],
                                   Optional[jax.Array]]:
        """(xs, ys, num_steps, num_examples) over the CURRENT buffers —
        re-read after every admit (the scatter donates and replaces
        them); masks are always present (see ``uniform``)."""
        return self.xs, self.ys, self.num_steps, self.num_examples

    def quant_args(self) -> Tuple[Optional[jax.Array], Optional[jax.Array]]:
        """Per-slot affine codes, or ``(None, None)`` in fp32 mode."""
        return self.x_scale, self.x_zero

    @property
    def sizes(self) -> np.ndarray:
        """Per-SLOT true sizes ``n_i`` (0 for empty slots; host, [N_cap])."""
        return self._sizes

    @property
    def true_examples(self) -> int:
        return int(self._sizes.sum())

    @property
    def padded_examples(self) -> int:
        return self.capacity * self.bucket_examples

    @property
    def nbytes(self) -> int:
        """Device bytes held — FIXED at construction (the whole point:
        churn moves rows, never memory)."""
        arrs = [getattr(self, name) for name in self._buffer_names]
        return int(sum(a.size * a.dtype.itemsize for a in arrs))

    @property
    def bytes_per_client(self) -> float:
        """:attr:`nbytes` amortized over CAPACITY (the slots exist
        whether or not they are occupied)."""
        return self.nbytes / self.capacity

    @property
    def num_resident(self) -> int:
        return len(self.slot_of)

    # -- registry views (PR-9 contract) -------------------------------------

    @property
    def admits(self) -> int:
        return int(self.registry.get("pool.admits"))

    @property
    def evicts(self) -> int:
        return int(self.registry.get("pool.evicts"))

    @property
    def uploads(self) -> int:
        return int(self.registry.get("pool.uploads"))

    @property
    def traces(self) -> int:
        """Scatter (re)traces — stays at 1 after :meth:`warmup` for the
        pool's whole life (the zero-retrace churn contract)."""
        return int(self.registry.get("pool.traces"))
