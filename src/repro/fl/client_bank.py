"""ClientBank — the device-resident FL data plane.

The trainer used to gather each round's K sampled clients on the host
(``[K, B, ...]`` numpy stacks) and re-upload them to the device — the
dominant non-compute cost once the round itself is one fused jit.  The
bank inverts that: ALL N clients' bucketed data is tiled and stacked to
``[N, B, ...]`` ONCE at construction, uploaded once, and every round
gathers its K selected rows *inside* the jit with ``jnp.take`` — zero
per-round host->device transfers of client data, and N (not K) becomes
the unit the system scales over.

Ownership / memory contract
---------------------------
* The bank owns the only device copy of the ``[N, B, ...]`` stacks plus
  the ``[N]`` ``num_steps`` / ``num_examples`` masks.  They are
  **never donated**: the round engine donates only the params (and scan
  queue) buffers, so one bank serves every round, every policy, and any
  number of concurrent rollouts.
* Host retention is bounded by the TRUE data volume ``sum_i n_i`` (a
  private copy per client, decoupled from caller mutation), never the
  tiled ``O(N * max_i n_i)`` form: :meth:`client_view` (the sequential /
  DivFL path) reads those copies directly, and :meth:`gather_host` (the
  PR-1 host-stacked round, retained for equivalence tests and
  transfer-cost benchmarking) lazily rebuilds — then caches — the tiled
  stacks only if it is actually used.
* With a mesh, the client axis is placed with
  ``NamedSharding(P(mesh_axis))`` when ``N`` divides the axis size —
  each shard holds ``N / axis_size`` clients' buckets and the round
  engine's ``shard_map`` trains/reduces per shard (cross-shard ``psum``
  in the aggregation).  Otherwise the bank is replicated.

Bucketing contract (see ``repro.data.pipeline`` / ``repro.fl.client``):
one GLOBAL bucket ``B = bucket_num_batches(max_i ceil(n_i / bs)) * bs``
covers every client, so the whole system compiles exactly one data shape
per task.  Clients are cyclically tiled to ``B`` rows; ``num_steps``
keeps each client at its true ``max(n_i // bs, 1)`` applied optimizer
steps and ``num_examples`` keeps epoch sampling off the padded duplicate
rows, so padding changes neither training distributions nor step counts.

The evaluation half of the data plane follows the same contract:
``repro.sim.eval.EvalBank`` holds the TEST set device-resident (uploaded
once at construction, blocking, never-aliasing, never-donated) and
evaluates stacked ``[S, ...]`` params in one vmapped ``task.metrics``
pass — the ScenarioArena's on-device replacement for host-side per-lane
evaluation loops.

Tier ladder (:class:`TieredClientBank`)
---------------------------------------
The single global bucket makes DEVICE memory ``O(N * max_i n_i)`` — a
heavily skewed partition (one giant client) taxes every row with the
skew.  :class:`TieredClientBank` removes that waste: clients are grouped
by their own power-of-two bucket into a small ladder of size tiers
(``data.pipeline.assign_tiers``), each tier is its own
:class:`ClientBank` holding a ``[N_t, B_t, ...]`` stack, and global
``tier_of`` / ``pos_in_tier`` index maps translate trainer-level client
ids to (tier, row).  Device memory is bounded by
``sum_t N_t * B_t ~ sum_i n_i`` instead of ``N * max_i n_i``, and the
system compiles one data shape PER TIER instead of one global shape.
All per-tier invariants (cyclic tiling, masks, masked/unmasked trace
equivalence, never-donated buffers, mesh N-axis sharding when divisible)
are inherited unchanged from the per-tier :class:`ClientBank`, and a
one-tier ladder is literally a single :class:`ClientBank` — the round
engine's tiered path is bit-identical to the single-bucket path there.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import assign_tiers, stack_client_arrays
from repro.fl import client as fl_client


class ClientBank:
    """Device-resident ``[N, B, ...]`` stacks of every client's data."""

    def __init__(self, client_data: Sequence[tuple],
                 client_cfg: fl_client.ClientConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "data"):
        self.batch_size = client_cfg.batch_size
        # Host retention is the TRUE data (sum_i n_i rows, private copies
        # decoupled from caller mutation), not the tiled [N, B, ...]
        # mirror: with skewed sizes the global bucket makes the tiled form
        # O(N * max_i n_i), which would defeat scaling over N.  The tiled
        # stacks exist transiently for the upload (and lazily again only
        # if the test/bench-only gather_host is used).
        self._clients = [(np.array(x), np.array(y)) for x, y in client_data]
        host_x, host_y, num_steps, num_examples = stack_client_arrays(
            self._clients, self.batch_size)
        self._num_steps, self._num_examples = num_steps, num_examples
        self._tiled: Optional[tuple] = None
        self.num_clients = host_x.shape[0]
        self.bucket_examples = host_x.shape[1]
        self.steps_per_epoch = self.bucket_examples // self.batch_size
        # Every client exactly fills the bucket => the masks are inert and
        # the engine may use the cheaper unmasked SGD trace.
        self.uniform = bool(np.all(num_examples == self.bucket_examples))
        self.mesh, self.mesh_axis = mesh, mesh_axis
        self.xs = self._to_device(host_x)
        self.ys = self._to_device(host_y)
        # the masks are also retained host-side (gather_host/sizes): upload
        # private copies so a zero-copy device_put can't alias them
        self.num_steps = self._to_device(num_steps.copy())
        self.num_examples = self._to_device(num_examples.copy())
        # The ONE host->device upload happens here, not lazily: block so
        # the device copy can't race callers mutating their arrays after
        # construction (transfers are async).
        jax.block_until_ready((self.xs, self.ys, self.num_steps,
                               self.num_examples))

    def _to_device(self, arr: np.ndarray) -> jax.Array:
        # ``arr`` is always a freshly built stack (never caller-owned), so
        # uploads may read it in place.  With a mesh placement, device_put
        # straight from host so each device receives only its shard — a
        # jnp.array staging hop would commit the full unsharded stack to
        # one device first, the exact OOM the sharded bank avoids.
        placement = self._placement()
        if placement is None:
            # jnp.array (copy semantics) so the device buffer can't alias
            # host memory the constructor is about to drop.
            return jnp.array(arr)
        return jax.device_put(arr, placement)

    def _placement(self):
        """NamedSharding over the client axis when the mesh divides N."""
        if self.mesh is None:
            return None
        shards = self.mesh.shape[self.mesh_axis]
        spec = (jax.sharding.PartitionSpec(self.mesh_axis)
                if shards > 1 and self.num_clients % shards == 0
                else jax.sharding.PartitionSpec())
        return jax.sharding.NamedSharding(self.mesh, spec)

    @property
    def sizes(self) -> np.ndarray:
        """True per-client dataset sizes ``n_i`` (host, [N])."""
        return self._num_examples

    @property
    def true_examples(self) -> int:
        """``sum_i n_i`` — the irreducible example count."""
        return int(self._num_examples.sum())

    @property
    def padded_examples(self) -> int:
        """``N * B`` — device rows actually held (incl. tiling padding)."""
        return self.num_clients * self.bucket_examples

    def device_args(self) -> Tuple[jax.Array, jax.Array,
                                   Optional[jax.Array],
                                   Optional[jax.Array]]:
        """(xs, ys, num_steps, num_examples) for in-jit gathering.

        The masks come back None for a uniform bank (every client fills
        the bucket) — selecting the cheaper unmasked SGD trace; thanks to
        the shared epoch-permutation keys the two traces are
        bit-identical there anyway.
        """
        if self.uniform:
            return self.xs, self.ys, None, None
        return self.xs, self.ys, self.num_steps, self.num_examples

    # -- host-side views ---------------------------------------------------

    def gather_host(self, selected: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray,
                               Optional[np.ndarray], Optional[np.ndarray]]:
        """PR-1-style host gather of the selected rows -> ``[K, B, ...]``.

        Same bucket, same tiled rows as the device path — kept for the
        bank-vs-host equivalence tests and the host-restacked benchmark
        baseline (which is why the tiled stacks are cached after the
        first call, matching PR 1's pad cache; production rounds never
        call this, so the cache stays unbuilt there).  ``num_steps`` /
        ``num_examples`` are None when every selected client exactly
        fills the bucket (the PR-1 unmasked trace), else the selected
        ``[K]`` mask rows.
        """
        if self._tiled is None:
            self._tiled = stack_client_arrays(self._clients,
                                              self.batch_size)[:2]
        host_x, host_y = self._tiled
        idx = np.asarray(selected, np.int64)
        xs, ys = host_x[idx], host_y[idx]
        if np.all(self._num_examples[idx] == self.bucket_examples):
            return xs, ys, None, None
        return xs, ys, self._num_steps[idx], self._num_examples[idx]

    def client_view(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client ``i``'s true (x, y) — the bank's private host copy (the
        first ``n_i`` rows of its device slice hold the same values, by
        the cyclic-tiling contract).  The sequential / DivFL path reads
        these instead of retained caller datasets."""
        return self._clients[i]


class TieredClientBank:
    """Bucket-ladder bank: one :class:`ClientBank` per power-of-two size
    tier, plus global-index maps.

    Clients are grouped by ``data.pipeline.assign_tiers`` (per-client
    power-of-two buckets, greedily merged down to ``max_tiers`` rungs).
    Tier ``t`` holds its members' data as an ordinary per-tier
    :class:`ClientBank` — a ``[N_t, B_t, ...]`` device stack with that
    tier's masks, inheriting every single-bucket invariant — so device
    memory is ``sum_t N_t * B_t`` (~``sum_i n_i``) instead of the global
    bucket's ``N * max_i n_i``.

    The maps are the tiered contract: ``tier_of[i]`` names client i's
    tier and ``pos_in_tier[i]`` its row in that tier's stack (members
    keep ascending global order within a tier, so a one-tier ladder has
    ``pos_in_tier == arange(N)`` and the single tier IS the single-bucket
    bank).  ``tier_of_device`` / ``pos_device`` are the same maps as
    device arrays for the round engine's in-jit tier loop (run_scan).
    """

    def __init__(self, client_data: Sequence[tuple],
                 client_cfg: fl_client.ClientConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "data", max_tiers: int = 4,
                 assignment: Optional[tuple] = None):
        self.batch_size = client_cfg.batch_size
        sizes = [int(np.asarray(x).shape[0]) for x, _ in client_data]
        self.num_clients = len(sizes)
        # ``assignment``: a precomputed ``assign_tiers`` result, so a
        # caller that already ran the ladder decision (RoundEngine's
        # 'auto' mode) can hand it over instead of recomputing it
        if assignment is None:
            assignment = assign_tiers(sizes, self.batch_size, max_tiers)
        tier_of, buckets = assignment
        self.tier_of = tier_of
        self.tier_buckets = buckets
        self.num_tiers = len(buckets)
        self.tier_members = [np.flatnonzero(tier_of == t)
                             for t in range(self.num_tiers)]
        pos = np.zeros(self.num_clients, np.int32)
        for members in self.tier_members:
            pos[members] = np.arange(members.size, dtype=np.int32)
        self.pos_in_tier = pos
        self.tiers = [ClientBank([client_data[i] for i in members],
                                 client_cfg, mesh=mesh, mesh_axis=mesh_axis)
                      for members in self.tier_members]
        # device copies for the in-jit tier loop (scan samples clients on
        # device, so the tier routing must be traceable)
        self.tier_of_device = jnp.asarray(tier_of, jnp.int32)
        self.pos_device = jnp.asarray(pos, jnp.int32)
        self.mesh, self.mesh_axis = mesh, mesh_axis

    @property
    def sizes(self) -> np.ndarray:
        """True per-client dataset sizes ``n_i`` in GLOBAL order ([N])."""
        out = np.zeros(self.num_clients, np.int32)
        for members, bank in zip(self.tier_members, self.tiers):
            out[members] = bank.sizes
        return out

    @property
    def true_examples(self) -> int:
        """``sum_i n_i`` — the irreducible example count."""
        return sum(bank.true_examples for bank in self.tiers)

    @property
    def padded_examples(self) -> int:
        """``sum_t N_t * B_t`` — device rows held across the ladder."""
        return sum(bank.padded_examples for bank in self.tiers)

    def client_view(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client ``i``'s true (x, y) via its tier's private host copy
        (the sequential / DivFL path, same contract as
        :meth:`ClientBank.client_view`)."""
        return self.tiers[self.tier_of[i]].client_view(
            int(self.pos_in_tier[i]))
