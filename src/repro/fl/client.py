"""Client-side local training: E epochs of mini-batch SGD (Algorithm 1, l.9).

Model-agnostic: a ``Task`` supplies ``init``/``loss_fn``/``metrics`` over
pytree parameters; the client returns the *update* ``theta^{t,E} - theta^t``
(Algorithm 1, l.10) so the server can apply the unbiased aggregation (4).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import SGD, apply_updates

PyTree = Any


class Task(Protocol):
    """Minimal model interface the FL substrate trains against."""

    def init(self, rng: jax.Array) -> PyTree: ...

    def loss_fn(self, params: PyTree, batch: Dict[str, jax.Array]
                ) -> jax.Array: ...

    def metrics(self, params: PyTree, batch: Dict[str, jax.Array]
                ) -> Dict[str, jax.Array]: ...


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    local_epochs: int = 2
    batch_size: int = 32
    momentum: float = 0.9
    max_grad_norm: float = 0.0     # 0 => no clipping


def _num_batches(num_examples: int, batch_size: int) -> int:
    return max(num_examples // batch_size, 1)


@partial(jax.jit, static_argnames=("loss_fn", "cfg", "steps_per_epoch"))
def _local_sgd(loss_fn, params: PyTree, x: jax.Array, y: jax.Array,
               lr: jax.Array, rng: jax.Array, cfg: ClientConfig,
               steps_per_epoch: int) -> Tuple[PyTree, jax.Array]:
    """E epochs of shuffled mini-batch SGD, fully inside one jit."""
    opt = SGD(momentum=cfg.momentum)
    opt_state = opt.init(params)
    bs = cfg.batch_size
    n = x.shape[0]

    def epoch(carry, erng):
        params, opt_state = carry
        perm = jax.random.permutation(erng, n)
        xs = jnp.take(x, perm[:steps_per_epoch * bs], axis=0)
        ys = jnp.take(y, perm[:steps_per_epoch * bs], axis=0)
        xs = xs.reshape((steps_per_epoch, bs) + x.shape[1:])
        ys = ys.reshape((steps_per_epoch, bs) + y.shape[1:])

        def step(carry, batch):
            params, opt_state = carry
            bx, by = batch
            loss, grads = jax.value_and_grad(loss_fn)(
                params, {"x": bx, "y": by})
            if cfg.max_grad_norm > 0:
                from repro.optim import clip_by_global_norm
                grads = clip_by_global_norm(grads, cfg.max_grad_norm)
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return (apply_updates(params, updates), opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), (xs, ys))
        return (params, opt_state), jnp.mean(losses)

    rngs = jax.random.split(rng, cfg.local_epochs)
    (params, _), losses = jax.lax.scan(epoch, (params, opt_state), rngs)
    return params, jnp.mean(losses)


def local_update(task: Task, global_params: PyTree, data_x: np.ndarray,
                 data_y: np.ndarray, lr: float, rng: jax.Array,
                 cfg: ClientConfig) -> Tuple[PyTree, float]:
    """Run E local epochs; return (theta^{t,E} - theta^t, mean loss)."""
    steps = _num_batches(data_x.shape[0], cfg.batch_size)
    new_params, loss = _local_sgd(task.loss_fn, global_params,
                                  jnp.asarray(data_x), jnp.asarray(data_y),
                                  jnp.asarray(lr, jnp.float32), rng, cfg,
                                  steps)
    delta = jax.tree_util.tree_map(lambda a, b: a - b, new_params,
                                   global_params)
    return delta, float(loss)


def flatten_update(delta: PyTree, proj_dim: int = 256,
                   seed: int = 0) -> np.ndarray:
    """Random-project an update pytree to a small vector (DivFL similarity).

    Uses a count-sketch style signed bucket projection — O(d) time,
    deterministic in ``seed`` — so similarity costs O(N^2 proj_dim)
    instead of O(N^2 d).
    """
    leaves = [np.asarray(x, np.float32).ravel()
              for x in jax.tree_util.tree_leaves(delta)]
    flat = np.concatenate(leaves) if leaves else np.zeros((1,), np.float32)
    rng = np.random.default_rng(seed)
    buckets = rng.integers(0, proj_dim, flat.shape[0])
    signs = rng.choice(np.asarray([-1.0, 1.0], np.float32), flat.shape[0])
    out = np.zeros((proj_dim,), np.float32)
    np.add.at(out, buckets, flat * signs)
    return out
