"""Client-side local training: E epochs of mini-batch SGD (Algorithm 1, l.9).

Model-agnostic: a ``Task`` supplies ``init``/``loss_fn``/``metrics`` over
pytree parameters; the client returns the *update* ``theta^{t,E} - theta^t``
(Algorithm 1, l.10) so the server can apply the unbiased aggregation (4).

Two execution modes share the same SGD body (``_local_sgd_body``):

* ``local_update``       — one client per call (the legacy / DivFL path);
* ``batched_local_sgd``  — the round engine's hot path: all K sampled
  clients train in ONE computation via ``jax.vmap`` over a stacked
  ``[K, B, ...]`` client batch, returning stacked deltas ``[K, ...]`` and
  per-client losses ``[K]``; a pure trace the engine fuses into its own
  jit alongside aggregation and the queue update.

Padding / bucketing contract (ClientBank / round engine)
--------------------------------------------------------
``vmap`` requires every client in the batch to share a static data shape, so
the bank pads every client dataset in a stack to one common bucket of ``B``
examples — the GLOBAL bucket for a ``ClientBank`` (one compiled data shape
per task), or that tier's bucket for each rung of a ``TieredClientBank``
(one compiled data shape per tier; the contract below applies per stack
verbatim):

* ``B = bucket_num_batches(max_i ceil(n_i / batch_size)) * batch_size`` —
  the bucket is sized from the *ceil* step count rounded up to the next
  power of two, so ``B >= n_i`` always holds (the tiled stream contains
  every example);
* each client's data is padded by **cyclic tiling** (example ``j`` of the
  padded stream is example ``j mod n_i``), so every padded batch contains
  only real examples and gradients are never polluted by zero rows;
* each local epoch samples **without replacement from the client's true
  ``n_i`` examples only**: when ``num_examples`` is given, padded rows
  (``j >= n_i``) receive a sentinel sort key and land at the end of the
  epoch's ordering, and for ``n_i >= bs`` the applied step count satisfies
  ``steps_i * bs <= n_i`` so they are never consumed — every example has
  equal inclusion probability, exactly the sequential path's statistics
  (cyclic duplicates are never over-weighted).  When ``n_i < bs`` the one
  applied batch must hold ``bs`` rows, so it consumes padded rows
  ``n_i .. bs-1`` (in index order — the tiled duplicates of examples
  ``0 .. bs-n_i-1``): the *same* deterministic duplicate multiset the
  sequential path produces when :func:`local_update` tiles ``n_i`` up to
  one full batch, so the two paths still agree;
* every client only *applies* its own true ``steps_i = max(n_i // bs, 1)``
  optimizer steps per epoch: the scan still runs ``B // batch_size``
  iterations (static shape), and steps beyond ``steps_i`` are masked out of
  the params/momentum/loss (``num_steps`` argument).  Padding therefore
  changes neither which examples a client trains on nor how many SGD steps
  it takes.  When ``n_i == B`` (no padding; ``num_steps``/``num_examples``
  None) this is *exactly* the sequential semantics of :func:`local_update`;
* the epoch ordering is the argsort of iid uniform keys drawn identically
  by the masked and unmasked traces, so a mask covering the full bucket
  (``num_examples == B``, ``num_steps == B // bs``) reproduces the
  unmasked trace bit-for-bit — the bank's always-masked gather path stays
  bit-identical to an unmasked host-stacked round.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Bucketing / cyclic tiling are host-side data-plane ops: they live in the
# numpy-only data layer (shared with ClientBank construction) and are
# re-exported here because they are part of the client-side contract.
from repro.data.pipeline import bucket_num_batches, pad_client_data
from repro.optim import SGD, apply_updates

PyTree = Any


class Task(Protocol):
    """Minimal model interface the FL substrate trains against."""

    def init(self, rng: jax.Array) -> PyTree: ...

    def loss_fn(self, params: PyTree, batch: Dict[str, jax.Array]
                ) -> jax.Array: ...

    def metrics(self, params: PyTree, batch: Dict[str, jax.Array]
                ) -> Dict[str, jax.Array]: ...


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    local_epochs: int = 2
    batch_size: int = 32
    momentum: float = 0.9
    max_grad_norm: float = 0.0     # 0 => no clipping


def _num_batches(num_examples: int, batch_size: int) -> int:
    return max(num_examples // batch_size, 1)


def _local_sgd_body(loss_fn, params: PyTree, x: jax.Array, y: jax.Array,
                    lr: jax.Array, rng: jax.Array, cfg: ClientConfig,
                    steps_per_epoch: int,
                    num_steps: Optional[jax.Array] = None,
                    num_examples: Optional[jax.Array] = None
                    ) -> Tuple[PyTree, jax.Array]:
    """E epochs of shuffled mini-batch SGD; pure trace (vmap/jit composable).

    ``num_steps`` (traced scalar, defaults to all ``steps_per_epoch`` steps)
    masks out optimizer steps beyond a client's true per-epoch step count;
    ``num_examples`` (traced scalar) restricts each epoch's sampling to the
    first ``num_examples`` rows — the client's true dataset inside a padded
    bucket — so cyclic-tiling duplicates never skew inclusion probability.
    Both are the bucketing contract for batched execution over padded data.
    """
    opt = SGD(momentum=cfg.momentum)
    opt_state = opt.init(params)
    bs = cfg.batch_size
    n = x.shape[0]

    def epoch(carry, erng):
        params, opt_state = carry
        # Epoch order = argsort of iid uniform keys (a uniform random
        # permutation).  Masked and unmasked traces share the SAME key
        # draw, so a mask covering the full bucket reproduces the
        # unmasked ordering bit-for-bit — the ClientBank path (always
        # masked) stays bit-identical to an unmasked host-stacked round.
        scores = jax.random.uniform(erng, (n,))
        if num_examples is not None:
            # without-replacement sample of the true examples: padded rows
            # get a sentinel key and sort last (stable, so in index
            # order), out of reach of the num_steps applied batches when
            # num_examples >= bs; a tiny client (num_examples < bs) fills
            # its single batch with the first padded rows — the same
            # duplicate multiset the sequential tile-to-one-batch path
            # uses (see module docstring)
            scores = jnp.where(jnp.arange(n) < num_examples, scores, 2.0)
        perm = jnp.argsort(scores)
        xs = jnp.take(x, perm[:steps_per_epoch * bs], axis=0)
        ys = jnp.take(y, perm[:steps_per_epoch * bs], axis=0)
        xs = xs.reshape((steps_per_epoch, bs) + x.shape[1:])
        ys = ys.reshape((steps_per_epoch, bs) + y.shape[1:])

        def step(carry, batch):
            params, opt_state = carry
            si, bx, by = batch
            loss, grads = jax.value_and_grad(loss_fn)(
                params, {"x": bx, "y": by})
            if cfg.max_grad_norm > 0:
                from repro.optim import clip_by_global_norm
                grads = clip_by_global_norm(grads, cfg.max_grad_norm)
            updates, new_opt = opt.update(grads, opt_state, params, lr)
            new_params = apply_updates(params, updates)
            if num_steps is not None:
                keep = si < num_steps
                new_params = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(keep, a, b), new_params, params)
                new_opt = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(keep, a, b), new_opt, opt_state)
                loss = jnp.where(keep, loss, 0.0)
            return (new_params, new_opt), loss

        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state),
            (jnp.arange(steps_per_epoch), xs, ys))
        if num_steps is None:
            epoch_loss = jnp.mean(losses)
        else:
            epoch_loss = jnp.sum(losses) / num_steps.astype(jnp.float32)
        return (params, opt_state), epoch_loss

    rngs = jax.random.split(rng, cfg.local_epochs)
    (params, _), losses = jax.lax.scan(epoch, (params, opt_state), rngs)
    return params, jnp.mean(losses)


_local_sgd = partial(jax.jit, static_argnames=("loss_fn", "cfg",
                                               "steps_per_epoch"))(
    _local_sgd_body)


def local_update(task: Task, global_params: PyTree, data_x: np.ndarray,
                 data_y: np.ndarray, lr: float, rng: jax.Array,
                 cfg: ClientConfig) -> Tuple[PyTree, float]:
    """Run E local epochs; return (theta^{t,E} - theta^t, mean loss)."""
    steps = _num_batches(data_x.shape[0], cfg.batch_size)
    if data_x.shape[0] < steps * cfg.batch_size:
        # fewer examples than one batch: tile up to a single full batch
        data_x, data_y = pad_client_data(np.asarray(data_x),
                                         np.asarray(data_y),
                                         steps * cfg.batch_size)
    new_params, loss = _local_sgd(task.loss_fn, global_params,
                                  jnp.asarray(data_x), jnp.asarray(data_y),
                                  jnp.asarray(lr, jnp.float32), rng, cfg,
                                  steps)
    delta = jax.tree_util.tree_map(lambda a, b: a - b, new_params,
                                   global_params)
    return delta, float(loss)


def batched_local_sgd(loss_fn, params: PyTree, xs: jax.Array, ys: jax.Array,
                      lr: jax.Array, rngs: jax.Array, cfg: ClientConfig,
                      steps_per_epoch: int,
                      num_steps: Optional[jax.Array] = None,
                      num_examples: Optional[jax.Array] = None
                      ) -> Tuple[PyTree, jax.Array]:
    """vmap of the SGD body over a stacked ``[K, B, ...]`` client batch.

    ``num_steps`` (``[K]`` int array or None) carries each client's true
    per-epoch step count so padded clients don't over-train;
    ``num_examples`` (``[K]`` int array or None) their true dataset sizes
    so epoch sampling never draws a padded duplicate row (see module
    docstring).  Returns stacked deltas (leaves ``[K, ...]``) and
    per-client losses ``[K]``.  Pure trace: callers embed it in their own
    jit (the round engine fuses it with aggregation + queue update).
    """
    if num_steps is None:
        def one(x, y, r):
            return _local_sgd_body(loss_fn, params, x, y, lr, r, cfg,
                                   steps_per_epoch)
        new_params, losses = jax.vmap(one)(xs, ys, rngs)
    elif num_examples is None:
        def one(x, y, r, s):
            return _local_sgd_body(loss_fn, params, x, y, lr, r, cfg,
                                   steps_per_epoch, num_steps=s)
        new_params, losses = jax.vmap(one)(xs, ys, rngs, num_steps)
    else:
        def one(x, y, r, s, m):
            return _local_sgd_body(loss_fn, params, x, y, lr, r, cfg,
                                   steps_per_epoch, num_steps=s,
                                   num_examples=m)
        new_params, losses = jax.vmap(one)(xs, ys, rngs, num_steps,
                                           num_examples)
    deltas = jax.tree_util.tree_map(lambda a, p: a - p, new_params, params)
    return deltas, losses


def flatten_update(delta: PyTree, proj_dim: int = 256,
                   seed: int = 0) -> np.ndarray:
    """Random-project an update pytree to a small vector (DivFL similarity).

    Uses a count-sketch style signed bucket projection — O(d) time,
    deterministic in ``seed`` — so similarity costs O(N^2 proj_dim)
    instead of O(N^2 d).
    """
    leaves = [np.asarray(x, np.float32).ravel()
              for x in jax.tree_util.tree_leaves(delta)]
    flat = np.concatenate(leaves) if leaves else np.zeros((1,), np.float32)
    rng = np.random.default_rng(seed)
    buckets = rng.integers(0, proj_dim, flat.shape[0])
    signs = rng.choice(np.asarray([-1.0, 1.0], np.float32), flat.shape[0])
    out = np.zeros((proj_dim,), np.float32)
    np.add.at(out, buckets, flat * signs)
    return out
