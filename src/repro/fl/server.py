"""Server-side FL logic: sampling K-with-replacement and the unbiased
aggregation rule (paper eq. (4), unbiasedness proof in Appendix A).

    theta^{t+1} = theta^t + sum_{n in K^t} w_n / (K q_n^t) (theta_n^{t,E} - theta^t)

The aggregation is also exposed as a stacked-update form used by the
client-parallel `shard_map` path and by the Pallas `fl_aggregate` kernel.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def sample_clients(rng: np.random.Generator, q: np.ndarray,
                   sample_count: int) -> np.ndarray:
    """Draw K client indices with replacement according to q (Alg. 1, l.5)."""
    q = np.asarray(q, np.float64)
    q = q / q.sum()
    return rng.choice(q.shape[0], size=sample_count, replace=True, p=q)


def aggregation_weights(selected: np.ndarray, q: np.ndarray, w: np.ndarray,
                        sample_count: int) -> np.ndarray:
    """Per-draw coefficients w_n / (K q_n) for the selected multiset."""
    sel = np.asarray(selected)
    return (np.asarray(w)[sel] /
            (float(sample_count) * np.asarray(q)[sel])).astype(np.float32)


def aggregate(global_params: PyTree, deltas: Sequence[PyTree],
              coeffs: np.ndarray) -> PyTree:
    """theta + sum_i coeff_i * delta_i  — eq. (4)."""
    coeffs = jnp.asarray(coeffs, jnp.float32)

    def combine(p, *ds):
        acc = p.astype(jnp.float32)
        for c, d in zip(coeffs, ds):
            acc = acc + c * d.astype(jnp.float32)
        return acc.astype(p.dtype)

    return jax.tree_util.tree_map(combine, global_params, *deltas)


def aggregate_stacked(global_params: PyTree, stacked_deltas: PyTree,
                      coeffs: jax.Array) -> PyTree:
    """Same as :func:`aggregate` for deltas stacked on a leading K axis.

    This is the form the distributed runtime uses: ``stacked_deltas`` leaves
    have shape ``[K, ...]`` (client axis shardable over the mesh ``data``
    axis) and the weighted reduction lowers to a single reduce per leaf.
    """
    def combine(p, d):
        upd = jnp.tensordot(coeffs.astype(jnp.float32),
                            d.astype(jnp.float32), axes=1)
        return (p.astype(jnp.float32) + upd).astype(p.dtype)

    return jax.tree_util.tree_map(combine, global_params, stacked_deltas)


def fedavg_reference(global_params: PyTree, deltas: Sequence[PyTree],
                     w_sel: np.ndarray) -> PyTree:
    """Plain FedAvg (weights proportional to data sizes) for comparison."""
    coeffs = np.asarray(w_sel, np.float32)
    coeffs = coeffs / coeffs.sum()
    return aggregate(global_params, deltas, coeffs)
