"""Server-side FL logic: sampling K-with-replacement and the unbiased
aggregation rule (paper eq. (4), unbiasedness proof in Appendix A).

    theta^{t+1} = theta^t + sum_{n in K^t} w_n / (K q_n^t) (theta_n^{t,E} - theta^t)

``aggregate_stacked`` is the canonical form: deltas carry a leading client
axis ``[K, ...]`` and the weighted reduction lowers to one reduce per leaf.
The legacy list-of-pytrees :func:`aggregate` stacks and delegates to it.

``aggregate_fused`` is the round engine's device-resident path.  On TPU
(or forced ``impl='pallas'``) the whole parameter pytree is ravelled to one
flat ``[N]`` vector (``ParamRavel``), reduced by the Pallas ``fl_aggregate``
kernel, and unravelled back — one fused streaming pass over the model.
Off-TPU it dispatches leaf-chunked to ``aggregate_stacked`` (per-leaf
tensordot): same math, and XLA fuses it without the ravel/concat
round-trip.  ``aggregate_fused_psum`` is the mesh-sharded form — per-shard
partial reduce over the local slice of the client axis + cross-shard psum
(the round engine's ``shard_map`` body).
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def sample_clients(rng: np.random.Generator, q: np.ndarray,
                   sample_count: int) -> np.ndarray:
    """Draw K client indices with replacement according to q (Alg. 1, l.5)."""
    q = np.asarray(q, np.float64)
    q = q / q.sum()
    return rng.choice(q.shape[0], size=sample_count, replace=True, p=q)


def aggregation_weights(selected: np.ndarray, q: np.ndarray, w: np.ndarray,
                        sample_count: int) -> np.ndarray:
    """Per-draw coefficients w_n / (K q_n) for the selected multiset."""
    sel = np.asarray(selected)
    return (np.asarray(w)[sel] /
            (float(sample_count) * np.asarray(q)[sel])).astype(np.float32)


def stack_deltas(deltas: Sequence[PyTree]) -> PyTree:
    """List of K update pytrees -> one pytree with leading [K, ...] leaves."""
    return jax.tree_util.tree_map(lambda *ds: jnp.stack(ds), *deltas)


def aggregate(global_params: PyTree, deltas: Sequence[PyTree],
              coeffs: np.ndarray) -> PyTree:
    """theta + sum_i coeff_i * delta_i  — eq. (4), legacy list API.

    Stacks onto a client axis and shares :func:`aggregate_stacked`'s single
    reduce per leaf (the unrolled per-coefficient loop is gone).
    """
    return aggregate_stacked(global_params, stack_deltas(deltas),
                             jnp.asarray(coeffs, jnp.float32))


def aggregate_stacked(global_params: PyTree, stacked_deltas: PyTree,
                      coeffs: jax.Array) -> PyTree:
    """Canonical eq.-(4) reduction over deltas stacked on a leading K axis.

    ``stacked_deltas`` leaves have shape ``[K, ...]`` (client axis shardable
    over the mesh ``data`` axis); the weighted reduction lowers to a single
    reduce per leaf.  The reduce is written as broadcast-multiply + sum over
    the client axis rather than ``tensordot``: under ``jax.vmap`` (the
    ScenarioArena batches whole rollouts over a scenario axis) a tensordot
    becomes a batched matmul whose f32 reduction order differs from the
    unbatched lowering at the ulp level, while an explicit axis-0 sum keeps
    every lane bit-identical to the unbatched trace.
    """
    def combine(p, d):
        d = d.astype(jnp.float32)
        c = coeffs.astype(jnp.float32).reshape(
            d.shape[:1] + (1,) * (d.ndim - 1))
        return (p.astype(jnp.float32) + jnp.sum(c * d, axis=0)).astype(
            p.dtype)

    return jax.tree_util.tree_map(combine, global_params, stacked_deltas)


class ParamRavel:
    """Ravel/unravel adapter between a params pytree and one flat vector.

    Built once from a template pytree (shapes + dtypes + treedef); ``ravel``
    concatenates every leaf (cast to f32) into a single ``[N]`` vector so the
    fused aggregation kernel can stream the whole model in one pass, and
    ``unravel`` splits/reshapes/casts back.  All methods are pure jnp and
    trace under jit/vmap; ``ravel_stacked`` maps leaves ``[K, ...]`` to
    ``[K, N]``.
    """

    def __init__(self, template: PyTree):
        leaves, treedef = jax.tree_util.tree_flatten(template)
        self.treedef = treedef
        self.shapes = [tuple(l.shape) for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.sizes = [int(np.prod(s)) if s else 1 for s in self.shapes]
        self.offsets = np.cumsum([0] + self.sizes).tolist()
        self.total = self.offsets[-1]

    def ravel(self, tree: PyTree) -> jax.Array:
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves])

    def ravel_stacked(self, tree: PyTree) -> jax.Array:
        leaves = jax.tree_util.tree_leaves(tree)
        k = leaves[0].shape[0]
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(k, -1) for l in leaves], axis=1)

    def unravel(self, vec: jax.Array) -> PyTree:
        parts = [vec[self.offsets[i]:self.offsets[i + 1]]
                 .reshape(self.shapes[i]).astype(self.dtypes[i])
                 for i in range(len(self.shapes))]
        return jax.tree_util.tree_unflatten(self.treedef, parts)


def _use_ravelled_kernel(impl: str) -> bool:
    """Leaf-chunked dispatch policy: the ravel/concat round-trip only pays
    off when it feeds the streaming Pallas kernel (TPU, or forced
    interpret); off-TPU the per-leaf tensordot is the same math with zero
    reshape/concat traffic (see the ``kernels/fl_aggregate_pytree`` bench
    row).  Defers to the kernels' own dispatch predicate so the ravelled
    path and the kernel it feeds can never disagree."""
    from repro.kernels.ops import use_pallas_kernel  # late import: cycle
    return use_pallas_kernel(impl)


def aggregate_fused(global_params: PyTree, stacked_deltas: PyTree,
                    coeffs: jax.Array, impl: str = "auto",
                    adapter: ParamRavel | None = None) -> PyTree:
    """eq. (4) through the fused flat-vector kernel (Pallas on TPU).

    On the kernel path the model is ravelled to one ``[N]`` vector,
    reduced by ``fl_aggregate``, and unravelled; off-TPU (``impl='auto'``
    on CPU/GPU) dispatches leaf-chunked to :func:`aggregate_stacked` —
    identical math, one tensordot per leaf, no ravel/concat round-trip.
    Pure trace: embed in the caller's jit and donate the params buffer
    there to avoid a full-model copy.
    """
    from repro.kernels import fl_aggregate   # late import: avoid cycle

    if not _use_ravelled_kernel(impl):
        return aggregate_stacked(global_params, stacked_deltas,
                                 coeffs.astype(jnp.float32))
    if adapter is None:
        adapter = ParamRavel(global_params)
    theta = adapter.ravel(global_params)
    deltas = adapter.ravel_stacked(stacked_deltas)
    new_theta = fl_aggregate(theta, deltas, coeffs.astype(jnp.float32),
                             impl=impl)
    return adapter.unravel(new_theta)


def aggregate_fused_psum(global_params: PyTree, stacked_deltas: PyTree,
                         coeffs: jax.Array, axis_name: str,
                         impl: str = "auto",
                         adapter: ParamRavel | None = None) -> PyTree:
    """Mesh-sharded eq. (4): per-shard partial reduce + cross-shard psum.

    ``shard_map`` body form of :func:`aggregate_fused`: ``stacked_deltas``
    carries this shard's slice ``[K/shards, ...]`` of the client axis and
    ``coeffs`` the matching slice, so each shard runs one partial weighted
    reduce (Pallas ``fl_delta_reduce`` on TPU, tensordot elsewhere —
    leaf-chunked off-TPU like the unsharded path), the partials are
    ``psum``med over ``axis_name``, and theta is added once on the
    replicated result.
    """
    from repro.kernels import fl_delta_reduce   # late import: avoid cycle

    coeffs = coeffs.astype(jnp.float32)
    if not _use_ravelled_kernel(impl):
        def combine(p, d):
            d = d.astype(jnp.float32)
            c = coeffs.reshape(d.shape[:1] + (1,) * (d.ndim - 1))
            upd = jax.lax.psum(jnp.sum(c * d, axis=0), axis_name)
            return (p.astype(jnp.float32) + upd).astype(p.dtype)
        return jax.tree_util.tree_map(combine, global_params,
                                      stacked_deltas)
    if adapter is None:
        adapter = ParamRavel(global_params)
    upd = fl_delta_reduce(adapter.ravel_stacked(stacked_deltas), coeffs,
                          impl=impl)
    upd = jax.lax.psum(upd, axis_name)
    return adapter.unravel(adapter.ravel(global_params) + upd)


def aggregate_hierarchical(global_params: PyTree, stacked_deltas: PyTree,
                           coeffs: jax.Array, cluster_sel: jax.Array,
                           num_clusters: int) -> PyTree:
    """eq. (4) as cluster-partial reduce then global reduce.

    ``cluster_sel[k]`` names the cluster of the k-th SELECTED client (the
    bank's k-means routing gathered by the round's selection); each
    leaf's weighted deltas are first ``segment_sum``-reduced into
    ``[num_clusters, ...]`` cluster partials and the partials then summed
    once — the reduction tree the scale plane wants, where the global
    stage costs ``O(num_clusters)`` rows regardless of how many clients
    fan into each cluster.  Same math as :func:`aggregate_stacked` to f32
    RESOLUTION: the two stages reassociate the f32 sum, so equivalence is
    a tolerance contract (tests pin it), not bitwise.
    """
    coeffs = coeffs.astype(jnp.float32)
    sel = cluster_sel.astype(jnp.int32)

    def combine(p, d):
        d = d.astype(jnp.float32)
        c = coeffs.reshape(d.shape[:1] + (1,) * (d.ndim - 1))
        partials = jax.ops.segment_sum(c * d, sel,
                                       num_segments=num_clusters)
        return (p.astype(jnp.float32)
                + jnp.sum(partials, axis=0)).astype(p.dtype)

    return jax.tree_util.tree_map(combine, global_params, stacked_deltas)


def aggregate_hierarchical_psum(global_params: PyTree,
                                stacked_deltas: PyTree, coeffs: jax.Array,
                                cluster_sel: jax.Array, num_clusters: int,
                                axis_name: str) -> PyTree:
    """Mesh-sharded :func:`aggregate_hierarchical` (shard_map body form,
    the PR-2 psum machinery): each shard segment-reduces its slice of the
    client axis into ``[num_clusters, ...]`` partials, the partials are
    ``psum``med over ``axis_name`` (the cross-shard traffic is cluster
    rows, not client rows), and theta is added once on the replicated
    cluster sum."""
    coeffs = coeffs.astype(jnp.float32)
    sel = cluster_sel.astype(jnp.int32)

    def combine(p, d):
        d = d.astype(jnp.float32)
        c = coeffs.reshape(d.shape[:1] + (1,) * (d.ndim - 1))
        partials = jax.ops.segment_sum(c * d, sel,
                                       num_segments=num_clusters)
        partials = jax.lax.psum(partials, axis_name)
        return (p.astype(jnp.float32)
                + jnp.sum(partials, axis=0)).astype(p.dtype)

    return jax.tree_util.tree_map(combine, global_params, stacked_deltas)


def fedavg_reference(global_params: PyTree, deltas: Sequence[PyTree],
                     w_sel: np.ndarray) -> PyTree:
    """Plain FedAvg (weights proportional to data sizes) for comparison."""
    coeffs = np.asarray(w_sel, np.float32)
    coeffs = coeffs / coeffs.sum()
    return aggregate(global_params, deltas, coeffs)
