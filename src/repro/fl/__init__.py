"""repro.fl — federated learning substrate: Algorithm 1 loop, clients,
server aggregation (eq. 4), channel environment."""

from repro.fl.client import (Task, ClientConfig, local_update, flatten_update)
from repro.fl.server import (sample_clients, aggregation_weights, aggregate,
                             aggregate_stacked, fedavg_reference)
from repro.fl.environment import (ChannelConfig, ChannelProcess,
                                  HeterogeneityConfig, heterogeneous_params)
from repro.fl.trainer import FederatedTrainer, FLRunResult, RoundRecord
