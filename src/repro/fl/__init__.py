"""repro.fl — federated learning substrate: Algorithm 1 loop, clients,
server aggregation (eq. 4), channel environment, the device-resident
ClientBank data plane ([N, B, ...] stacks gathered inside the jit), and
the fused round engine (vmapped K-client training + stacked aggregation
in one jit, optionally shard_mapped over a mesh ``data`` axis)."""

from repro.fl.client import (Task, ClientConfig, local_update,
                             batched_local_sgd, bucket_num_batches,
                             pad_client_data, flatten_update)
from repro.fl.client_bank import (BankPool, ClientBank, TieredClientBank,
                                  estimate_bank_nbytes)
from repro.fl.server import (sample_clients, aggregation_weights, aggregate,
                             aggregate_stacked, aggregate_fused,
                             aggregate_fused_psum, aggregate_hierarchical,
                             aggregate_hierarchical_psum, stack_deltas,
                             ParamRavel, fedavg_reference)
from repro.fl.environment import (CHANNEL_MODES, ChannelConfig,
                                  ChannelProcess, HeterogeneityConfig,
                                  heterogeneous_params, markov_stationary,
                                  sample_channel_sequence,
                                  sample_dropout_mask, sample_gains,
                                  sample_gains_markov, sample_markov_states)
from repro.fl.round_engine import RoundEngine
from repro.fl.trainer import FederatedTrainer, FLRunResult, RoundRecord
