"""EvalBank — the device-resident evaluation data plane for the arena.

The accuracy half of the paper's Sec.-VII trade-off curves used to run
host-side: after an ``Arena.run`` the driver looped over the S lanes,
sliced each lane's params out of the stacked pytree (one device gather
per leaf per lane), and dispatched one ``task.metrics`` call per lane —
S tiny dispatch chains whose wall-clock dominates pilot-length sweeps.
The EvalBank inverts that exactly like the ClientBank inverted the
training data plane: the test set is uploaded ONCE at construction
(blocking, never-aliasing, never-donated copy), and evaluation is one
``jax.vmap``ped ``task.metrics`` pass over the whole ``[S, ...]`` params
stack — one dispatch for the entire grid.

Two consumers:

* :meth:`evaluate_stacked` — host-facing batched evaluation of a stacked
  params pytree (``Arena.run`` calls it on the final params, landing
  ``test_*`` columns in ``RolloutReport.final_metrics``).
* :meth:`eval_fn` + :meth:`device_args` — the in-scan plane: the arena
  threads ``device_args()`` into the rollout executable as traced inputs
  and the scan body calls ``eval_fn`` every ``eval_every`` rounds behind
  an unbatched ``lax.cond`` (see ``RoundEngine._build_scan``), emitting
  ``test_<metric>`` per-round columns.  Passing the buffers as arguments
  (not closures) keeps the test set out of the executable's constant
  pool and lets one compiled program serve any same-shape test set.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class EvalBank:
    """Device-resident test set + batched ``task.metrics`` evaluation."""

    def __init__(self, task, x, y):
        self.task = task
        # jnp.array copy semantics: the device buffers can never alias
        # caller numpy memory, mirroring the ClientBank upload contract
        self.x = jnp.array(np.asarray(x))
        self.y = jnp.array(np.asarray(y))
        # block so the upload can't race callers mutating their arrays
        jax.block_until_ready((self.x, self.y))
        self.num_examples = int(self.x.shape[0])
        #: the pure per-model evaluation trace, built by
        #: :meth:`make_eval_fn` — closes over the TASK only, never the
        #: bank, so embedding it in a long-lived cached executable (the
        #: arena) cannot pin the test-set buffers
        self.eval_fn = self.make_eval_fn(task)
        # one jitted executable per bank (jax caches on callable
        # identity, so this must be built once here, not per call)
        self._stacked = jax.jit(jax.vmap(self.eval_fn, in_axes=(0, None)))

    @staticmethod
    def make_eval_fn(task):
        """``eval_fn(params, data) -> {metric: scalar}`` over a traced
        ``(x, y)`` test set — THE evaluation trace, shared by the in-scan
        path (``RoundEngine._build_scan``) and :meth:`evaluate_stacked`
        so the ``test_*`` columns and ``final_metrics`` cannot diverge."""
        def eval_fn(params: PyTree, data) -> Dict[str, jax.Array]:
            x, y = data
            return task.metrics(params, {"x": x, "y": y})
        return eval_fn

    def device_args(self):
        """(x, y) device buffers for threading into a jitted rollout."""
        return (self.x, self.y)

    def evaluate_stacked(self, params: PyTree) -> Dict[str, np.ndarray]:
        """Evaluate a stacked ``[S, ...]`` params pytree in ONE vmapped
        dispatch; returns ``{metric: [S] numpy array}``."""
        out = self._stacked(params, (self.x, self.y))
        return {name: np.asarray(v) for name, v in out.items()}

    def evaluate_one(self, params: PyTree) -> Dict[str, float]:
        """Single-model evaluation (host convenience / reference)."""
        out = self.eval_fn(params, (self.x, self.y))
        return {name: float(v) for name, v in out.items()}

    def carry_struct(self, params_example: PyTree, s: int
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
        """Shape/dtype structs of the in-scan last-eval carry for an
        ``[s, ...]`` lane stack — ``{metric: ShapeDtypeStruct([s])}``,
        derived from the real evaluation trace via ``jax.eval_shape`` so
        it cannot drift from what ``_build_scan`` actually carries.  The
        streaming arena uses this to AOT-lower chunk-resume executables
        and the sweep service to rebuild a checkpointed carry's ``like``
        tree without executing an evaluation."""
        out = jax.eval_shape(self.eval_fn, params_example,
                             (self.x, self.y))
        return {name: jax.ShapeDtypeStruct((s,) + tuple(v.shape), v.dtype)
                for name, v in out.items()}

    def aot_warm(self, s: int, params_example: PyTree) -> bool:
        """AOT-compile the stacked evaluator for an ``[s, ...]`` params
        stack from shape structs alone (no execution) — the EvalBank
        half of ``Arena.warmup(aot=True)``.  Only populates the jit call
        cache where ``repro.sim.arena.aot_cache_warmup_supported`` says
        this jax does; returns whether the lowering itself succeeded."""
        structs = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct((s,) + tuple(a.shape),
                                           a.dtype), params_example)
        try:
            self._stacked.lower(structs, (self.x, self.y)).compile()
            return True
        except Exception:       # pragma: no cover - AOT API missing
            return False
