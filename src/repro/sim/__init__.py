"""repro.sim — the ScenarioArena sweep engine: struct-of-arrays scenario
grids (controller-as-data via traced ``lax.switch`` ids), whole evaluation
grids vmapped over the fused rollout scan in one jitted program (optionally
scenario-sharded over a mesh ``data`` axis), shape-adaptive dispatch
planning (cost-model lane bucketing over the ``(K, tier-footprint)``
signatures — ``k_mode='auto'``), streaming chunked execution
(``Arena.run(chunk_size=...)`` — carry-donated scan segments, host
reduction overlapped with device dispatch) behind a long-lived
``SweepService`` (queued/coalesced submissions, crash-safe chunk
checkpoints), and structured RolloutReports with the paper's Sec. VII
trade-off reducers."""

from repro.sim.arena import (Arena, ScenarioGrid, aot_cache_warmup_supported,
                             derive_hyperparams, scenario_keys)
from repro.sim.cost_model import CostModel
from repro.sim.dispatch import (DispatchBucket, DispatchPlan,
                                lane_footprints, plan_dispatch)
from repro.sim.eval import EvalBank
from repro.sim.report import RolloutReport, concat_chunk_metrics
from repro.sim.service import NpzChunkStore, SweepService
