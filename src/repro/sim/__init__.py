"""repro.sim — the ScenarioArena sweep engine: struct-of-arrays scenario
grids (controller-as-data via traced ``lax.switch`` ids), whole evaluation
grids vmapped over the fused rollout scan in one jitted program (optionally
scenario-sharded over a mesh ``data`` axis), and structured RolloutReports
with the paper's Sec. VII trade-off reducers."""

from repro.sim.arena import (Arena, ScenarioGrid, derive_hyperparams,
                             scenario_keys)
from repro.sim.eval import EvalBank
from repro.sim.report import RolloutReport
