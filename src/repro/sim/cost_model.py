"""Dispatch cost model — the seconds-denominated price list the arena's
shape-adaptive planner (``repro.sim.dispatch``) optimises over.

The ScenarioArena can execute a grid as anywhere between ONE padded
executable (every lane trains ``K_max`` slots over every tier body —
minimal compile, maximal steady-state padding waste) and one executable
per distinct lane shape (zero padding waste, one compile chain per
shape).  Neither extreme is right in general; the tracked bench record
(``BENCH_round_engine.json``, ``arena.mixed_k``) measures the padded
program at ~0.56x the grouped steady-state throughput but ~2.9x its
cold-workflow throughput at the recorded K-skewed operating point.  The
planner therefore needs prices, not heuristics:

* **training work** — a lane in a bucket pays
  ``T * K_pad * sum_t(steps_t * batch_rows_t)`` row-units per rollout:
  every one of the bucket's padded slots runs every tier body in the
  bucket's static tier subset, ``steps_t * batch_rows_t`` (= the tier's
  bucket rows processed per epoch) each.  ``unit_cost`` converts
  row-units to seconds.
* **compile** — each executable the plan needs that is NOT already in
  the arena's cache costs ``compile_cost`` seconds, paid once and
  amortised over the planning horizon (``runs``).
* **dispatch** — each bucket adds one dispatch chain per run
  (``dispatch_cost`` seconds): the term that breaks ties toward fewer
  executables when padding waste is negligible.

The defaults are calibrated against the tracked CPU record;
:meth:`CostModel.from_bench_json` re-derives them from any
``BENCH_round_engine.json``, and :meth:`CostModel.calibrate` measures
them with one timed probe (a cold + warm ``run_scan`` pair) on the
actual engine/bank.  Only the RATIOS matter for plan shape — the
planner compares alternatives, it never promises wall-clock.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Optional

__all__ = ["CostModel"]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Prices (seconds) for the dispatch planner's three cost terms."""

    #: seconds per training row-unit (one padded slot x one bucket row
    #: processed, see the module docstring); the steady-state price
    unit_cost: float = 8e-6
    #: seconds to compile one fresh rollout executable
    compile_cost: float = 5.0
    #: seconds of per-run launch overhead each extra bucket adds
    dispatch_cost: float = 2e-3

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if not getattr(self, f.name) >= 0.0:
                raise ValueError(f"CostModel.{f.name} must be >= 0, got "
                                 f"{getattr(self, f.name)!r}")

    # -- cost terms ---------------------------------------------------------

    def lane_seconds(self, rounds: int, k_pad: int, tier_work: float
                     ) -> float:
        """Steady-state seconds one lane costs per rollout in a bucket of
        ``k_pad`` slots whose tier subset processes ``tier_work`` bucket
        rows per slot per round (``sum_t steps_t * batch_rows_t``)."""
        return self.unit_cost * float(rounds) * float(k_pad) * tier_work

    def bucket_seconds(self, num_lanes: int, rounds: int, k_pad: int,
                       tier_work: float, *, cached: bool,
                       runs: float) -> float:
        """Amortised per-run seconds of one bucket: dispatch + training
        work, plus its compile (if the executable is not cached) spread
        over the ``runs`` planning horizon (``math.inf`` = steady state,
        ``1`` = a one-shot cold grid)."""
        compile_s = 0.0 if cached else self.compile_cost
        runs = max(float(runs), 1.0)
        amortised = 0.0 if math.isinf(runs) else compile_s / runs
        return (amortised + self.dispatch_cost +
                num_lanes * self.lane_seconds(rounds, k_pad, tier_work))

    # -- calibration --------------------------------------------------------

    @classmethod
    def from_bench_json(cls, path: str = "BENCH_round_engine.json"
                        ) -> "CostModel":
        """Derive (unit_cost, compile_cost) from a tracked bench record's
        ``arena.mixed_k`` section — the grouped rows are the cleanest
        probe: per-K executables with zero padding waste, so steady-state
        seconds / total row-units is the unit price and (cold - steady)
        seconds / executables the compile price.  Missing or unusable
        records fall back to the defaults (the planner must stay usable
        on a fresh checkout)."""
        try:
            with open(path) as f:
                rec = json.load(f)
            cfg = rec["config"]
            mk = rec["arena"]["mixed_k"]
            rows = int(cfg["examples_per_client"])
            s, t = int(mk["S"]), int(mk["rounds"])
            ks = [int(k) for k in mk["K_values"]]
            lanes_per_k = s // len(ks)
            row_units = t * rows * lanes_per_k * sum(ks)
            steady_s = s * t / float(mk["grouped_rounds_per_sec"])
            unit = steady_s / row_units
            compile_s = max(
                (float(mk["grouped_cold_seconds"]) - steady_s) /
                int(mk["grouped_executables"]), 1e-3)
            if unit <= 0.0 or not math.isfinite(unit):
                raise ValueError(f"non-positive unit cost {unit!r}")
            return cls(unit_cost=unit, compile_cost=compile_s)
        except (OSError, ValueError, KeyError, ZeroDivisionError, TypeError):
            return cls()

    @classmethod
    def calibrate(cls, engine, sp, bank, *, rounds: int = 3,
                  seed: int = 0, policy: str = "uni_d",
                  dispatch_cost: Optional[float] = None) -> "CostModel":
        """ONE timed probe on the actual engine/bank: a cold
        ``run_scan`` (compile + execute) followed by a warm replay.  The
        warm seconds divided by the rollout's row-units give
        ``unit_cost``; cold minus warm gives ``compile_cost``.  Cheap by
        construction (``rounds`` defaults to a pilot length) and exact
        where it matters — the probe compiles the very scan body the
        arena's bucket executables are built from."""
        import jax
        import numpy as np

        from repro.fl.environment import sample_gains

        n = sp.num_devices
        key = jax.random.PRNGKey(seed)
        h_seq = sample_gains(key, rounds, n, 0.1, 0.01, 0.5)
        lr_seq = np.zeros(rounds, np.float32)
        params0 = engine.task.init(key)

        def once():
            p, _, _ = engine.run_scan(params0, sp, bank, h_seq, lr_seq,
                                      key, policy=policy)
            jax.block_until_ready(jax.tree_util.tree_leaves(p))

        t0 = time.perf_counter()
        once()
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        once()
        warm = time.perf_counter() - t0
        banks = bank.tiers if hasattr(bank, "tiers") else [bank]
        tier_work = sum(b.steps_per_epoch * b.batch_size for b in banks)
        row_units = rounds * sp.sample_count * tier_work
        kw = {} if dispatch_cost is None else dict(
            dispatch_cost=dispatch_cost)
        return cls(unit_cost=max(warm / max(row_units, 1), 1e-12),
                   compile_cost=max(cold - warm, 1e-3), **kw)
