"""Shape-adaptive dispatch planning for the ScenarioArena.

``Arena.run`` used to offer exactly two executions of an S-lane grid:
``k_mode='pad'`` (ONE executable, every lane padded to ``K_max`` slots
and compiled against EVERY bank tier) and ``k_mode='group'`` (one
executable per distinct K, each lane at its native width).  Both are
cost-blind extremes: pad wastes steady-state FLOPs on padded slots and
never-hit tier bodies (the tiered scan-skip win evaporates under vmap,
where ``lax.cond`` lowers to ``select``), group pays one compile chain
per shape on every cold workflow.  This module is the TieredClientBank
trick applied to the SCENARIO axis: bucket the lanes by shape signature
``(K, tier footprint)`` into a small ladder of executables, sized by the
:class:`~repro.sim.cost_model.CostModel` under a ``max_executables``
knob.

The planner's contract, relied on by the arena and the tests:

* **Degenerate extremes are reachable** — :meth:`DispatchPlan.padded`
  is the single-bucket pad program, :meth:`DispatchPlan.grouped` the
  per-K ladder; ``plan_dispatch(..., max_executables=1)`` always
  collapses to the padded plan.
* **Deterministic** — buckets are ordered by ``(k_pad, tiers)`` and
  lane order inside a bucket preserves grid order, so the lane
  permutation (and therefore every stitched ``RolloutReport`` array) is
  a pure function of the grid + plan inputs.
* **Bitwise-safe merges** — a merge only ever RAISES a lane's ``k_pad``
  (padded slots are provably inert: see ``test_arena``'s pad-vs-group
  equivalence) and only ever WIDENS its tier subset (a tier a lane
  never hits contributes exactly-zero masked updates).  Any plan the
  optimiser emits therefore reproduces the per-lane ``run_scan``
  trajectory; the cost model decides speed, never results.

Planning is host-side numpy over at most a handful of signatures —
microseconds against the seconds-scale executables it arranges.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace as obs

from .cost_model import CostModel

__all__ = ["DispatchBucket", "DispatchPlan", "plan_dispatch",
           "lane_footprints"]


@dataclasses.dataclass(frozen=True)
class DispatchBucket:
    """One executable's worth of lanes: the lanes it serves (grid
    order), the K they are all padded to, and the static tier subset its
    scan body is compiled against (``None`` = all bank tiers)."""

    lanes: Tuple[int, ...]
    k_pad: int
    tiers: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if not self.lanes:
            raise ValueError("DispatchBucket needs at least one lane")
        if self.k_pad < 1:
            raise ValueError(f"k_pad must be >= 1, got {self.k_pad}")
        if self.tiers is not None and len(self.tiers) == 0:
            raise ValueError("tier subset cannot be empty — a lane always "
                             "hits at least one tier")

    @property
    def num_lanes(self) -> int:
        return len(self.lanes)


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Lane → bucket assignment for one arena grid.

    ``buckets`` partition ``range(num_lanes)``; :meth:`permutation` is
    the bucket-concatenation order (the order lanes leave the device)
    and :meth:`inverse_permutation` restores grid order, so
    ``stitched[inverse_permutation()] == grid order`` for any per-lane
    stacked array.
    """

    buckets: Tuple[DispatchBucket, ...]
    num_lanes: int

    def __post_init__(self):
        seen = sorted(i for b in self.buckets for i in b.lanes)
        if seen != list(range(self.num_lanes)):
            raise ValueError(
                f"buckets must partition the {self.num_lanes} lanes; "
                f"got lane multiset {seen}")

    # -- construction -------------------------------------------------------

    @classmethod
    def padded(cls, sample_counts: Sequence[int],
               tiers: Optional[Tuple[int, ...]] = None) -> "DispatchPlan":
        """The ``k_mode='pad'`` degenerate case: one bucket, all lanes,
        ``k_pad = max(K)``, full tier set."""
        ks = np.asarray(sample_counts, dtype=np.int64)
        return cls(buckets=(DispatchBucket(
            lanes=tuple(range(ks.size)), k_pad=int(ks.max()), tiers=tiers),),
            num_lanes=int(ks.size))

    @classmethod
    def grouped(cls, sample_counts: Sequence[int],
                tiers: Optional[Tuple[int, ...]] = None) -> "DispatchPlan":
        """The ``k_mode='group'`` degenerate case: one bucket per
        distinct K (ascending, matching ``np.unique``), full tier set."""
        ks = np.asarray(sample_counts, dtype=np.int64)
        buckets = tuple(
            DispatchBucket(lanes=tuple(int(i) for i in
                                       np.flatnonzero(ks == k)),
                           k_pad=int(k), tiers=tiers)
            for k in np.unique(ks))
        return cls(buckets=buckets, num_lanes=int(ks.size))

    # -- lane bookkeeping ---------------------------------------------------

    def permutation(self) -> np.ndarray:
        """Grid-order lane ids in device (bucket-concatenation) order."""
        return np.asarray([i for b in self.buckets for i in b.lanes],
                          dtype=np.int64)

    def inverse_permutation(self) -> np.ndarray:
        """Device-order → grid-order gather indices: for grid lane ``s``,
        ``inv[s]`` is its row in the concatenated bucket outputs."""
        perm = self.permutation()
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.size, dtype=np.int64)
        return inv

    def bucket_of(self) -> np.ndarray:
        """Bucket index per grid lane, ``[S]``."""
        out = np.empty(self.num_lanes, dtype=np.int64)
        for j, b in enumerate(self.buckets):
            out[list(b.lanes)] = j
        return out

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def k_max(self) -> int:
        return max(b.k_pad for b in self.buckets)

    def describe(self) -> List[dict]:
        """JSON-serialisable plan summary (lands in report meta and the
        bench record)."""
        return [dict(lanes=list(b.lanes), k_pad=b.k_pad,
                     tiers=None if b.tiers is None else list(b.tiers))
                for b in self.buckets]


# -- footprints --------------------------------------------------------------

def lane_footprints(selected: np.ndarray,
                    tier_of: np.ndarray) -> List[Tuple[int, ...]]:
    """Per-lane tier footprints from a ``[S, T, K]`` selection replay.

    ``selected`` is the control-plane probe's selection trace (padding
    slots hold -1 or repeats of slot 0 — both map to real clients, which
    is fine: padded slots gather real rows, so their tiers are genuinely
    touched by the padded executable) and ``tier_of`` the bank's host
    client → tier map.  Returns a sorted tier tuple per lane.
    """
    sel = np.asarray(selected)
    tier_of = np.asarray(tier_of)
    out: List[Tuple[int, ...]] = []
    for s in range(sel.shape[0]):
        ids = sel[s][sel[s] >= 0]
        out.append(tuple(sorted(np.unique(tier_of[ids]).tolist())))
    return out


# -- the planner -------------------------------------------------------------

def _merge(a: DispatchBucket, b: DispatchBucket) -> DispatchBucket:
    lanes = tuple(sorted(a.lanes + b.lanes))
    if a.tiers is None or b.tiers is None:
        tiers = None
    else:
        tiers = tuple(sorted(set(a.tiers) | set(b.tiers)))
    return DispatchBucket(lanes=lanes, k_pad=max(a.k_pad, b.k_pad),
                          tiers=tiers)


def plan_dispatch(sample_counts: Sequence[int], *, rounds: int,
                  tier_work: Optional[Dict[int, float]] = None,
                  footprints: Optional[Sequence[Tuple[int, ...]]] = None,
                  cost_model: Optional[CostModel] = None,
                  max_executables: int = 4,
                  is_cached: Optional[Callable[[DispatchBucket],
                                               bool]] = None,
                  runs: float = 1.0) -> DispatchPlan:
    """Choose a :class:`DispatchPlan` for one arena grid.

    Parameters
    ----------
    sample_counts:
        Per-lane K, grid order (``grid.sample_count``).
    rounds:
        Rollout length T (scales the work term against compile).
    tier_work:
        ``{tier id: bucket rows per slot per round}`` (the bank's
        ``steps_per_epoch * batch_size`` per tier, times local epochs).
        ``None`` = single-tier bank with unit work: plans then reduce to
        pure K-bucketing.
    footprints:
        Per-lane sorted tier tuples (see :func:`lane_footprints`).
        ``None`` = every lane hits every tier.
    cost_model:
        Prices; defaults to the tracked-record calibration.
    max_executables:
        Hard cap on buckets; ``1`` always yields the padded plan.
    is_cached:
        Predicate telling the planner a bucket's executable is already
        compiled (the arena passes a probe of its executable cache);
        cached buckets pay no amortised compile.
    runs:
        Planning horizon — how many times this plan's executables will
        be reused.  ``1.0`` (a one-shot cold run) makes compile dominate
        and plans collapse toward pad; ``math.inf`` (``Arena.warmup``'s
        steady-state horizon) makes padding waste dominate and plans
        split by signature.

    The optimiser is exact where it can be and greedy where it must:
    start from one bucket per distinct ``(K, footprint)`` signature
    (the finest bitwise-safe partition), then greedily apply the
    cheapest pairwise merge while over ``max_executables``, and keep
    merging while the best merge strictly lowers the modelled cost.
    With a handful of signatures this explores the whole merge lattice
    that matters; it is deterministic for fixed inputs.
    """
    ks = np.asarray(sample_counts, dtype=np.int64)
    if ks.ndim != 1 or ks.size == 0:
        raise ValueError(f"sample_counts must be a non-empty 1-D sequence, "
                         f"got shape {ks.shape}")
    if max_executables < 1:
        raise ValueError(f"max_executables must be >= 1, "
                         f"got {max_executables}")
    if footprints is not None and len(footprints) != ks.size:
        raise ValueError(f"footprints has {len(footprints)} entries for "
                         f"{ks.size} lanes")
    cm = cost_model if cost_model is not None else CostModel()
    all_tiers = (None if tier_work is None
                 else tuple(sorted(tier_work)))

    def norm_fp(fp) -> Optional[Tuple[int, ...]]:
        if tier_work is None:
            return None
        fp = tuple(sorted(fp))
        if not fp:
            raise ValueError("a lane's tier footprint cannot be empty")
        unknown = set(fp) - set(all_tiers)
        if unknown:
            raise ValueError(f"footprint names unknown tiers {unknown}; "
                             f"tier_work covers {all_tiers}")
        return fp

    # finest bitwise-safe partition: one bucket per (K, footprint)
    sig_lanes: Dict[Hashable, List[int]] = {}
    for s in range(ks.size):
        fp = norm_fp(footprints[s]) if footprints is not None else all_tiers
        sig_lanes.setdefault((int(ks[s]), fp), []).append(s)
    buckets = [DispatchBucket(lanes=tuple(lanes), k_pad=k, tiers=fp)
               for (k, fp), lanes in sorted(
                   sig_lanes.items(),
                   key=lambda kv: (kv[0][0], kv[0][1] or ()))]

    def work(b: DispatchBucket) -> float:
        if tier_work is None:
            return 1.0
        tiers = b.tiers if b.tiers is not None else all_tiers
        return float(sum(tier_work[t] for t in tiers))

    def cost(b: DispatchBucket) -> float:
        cached = bool(is_cached(b)) if is_cached is not None else False
        return cm.bucket_seconds(b.num_lanes, rounds, b.k_pad, work(b),
                                 cached=cached, runs=runs)

    def best_merge(bs: List[DispatchBucket]
                   ) -> Tuple[float, int, int, DispatchBucket]:
        best = None
        for i in range(len(bs)):
            for j in range(i + 1, len(bs)):
                m = _merge(bs[i], bs[j])
                delta = cost(m) - cost(bs[i]) - cost(bs[j])
                # deterministic tie-break: lowest delta, then smallest
                # merged signature
                key = (delta, m.k_pad, m.tiers or ())
                if best is None or key < best[0]:
                    best = (key, i, j, m)
        assert best is not None
        return (best[0][0], best[1], best[2], best[3])

    # phase 1: enforce the executable cap
    while len(buckets) > max_executables:
        _, i, j, m = best_merge(buckets)
        buckets = [b for idx, b in enumerate(buckets)
                   if idx not in (i, j)] + [m]
    # phase 2: keep merging while it strictly pays
    while len(buckets) > 1:
        delta, i, j, m = best_merge(buckets)
        if not delta < 0.0:
            break
        buckets = [b for idx, b in enumerate(buckets)
                   if idx not in (i, j)] + [m]

    buckets.sort(key=lambda b: (b.k_pad, b.tiers or ()))
    plan = DispatchPlan(buckets=tuple(buckets), num_lanes=int(ks.size))
    # flight-recorder breadcrumb: the planner's verdict with the inputs
    # that shaped it (no-op without a sink) — regressions in bucketing
    # show up in the span log next to the dispatches they caused
    obs.event("plan.decision", lanes=int(ks.size), rounds=int(rounds),
              runs=(-1.0 if math.isinf(runs) else float(runs)),
              buckets=plan.num_buckets,
              k_pads=[int(b.k_pad) for b in plan.buckets])
    return plan
