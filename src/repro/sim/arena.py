"""ScenarioArena — device-batched multi-rollout sweeps over the fused scan.

The paper's entire evaluation (Sec. VII) is a grid of rollouts: LROA vs
Uni-D / Uni-S across seeds, Lyapunov weights (mu, nu), energy budgets,
and channel statistics.  Host-looping ``RoundEngine.run_scan`` pays one
dispatch chain per grid point; the arena instead stacks the S scenarios
struct-of-arrays (:class:`ScenarioGrid`) and lays the engine's scan body
out over the scenario axis — ``jax.vmap`` lanes by default, or
``lax.map`` lanes (``batch='map'``, the CPU/strong-scaling mode; see
:class:`Arena`) — so ONE jitted program executes every rollout, sharing
the read-only (never-donated) ClientBank across all lanes:

* **Controller-as-data.**  Each lane carries a traced ``controller_id``;
  the scan body dispatches ``repro.core.policy.decide_by_id``
  (``lax.switch``), so a single executable runs a mixed LROA/Uni-D/Uni-S
  grid.  DivFL is host-stateful and rejected at grid construction.
* **Bit-identical model rollouts.**  Lane ``s`` of ``Arena.run``
  reproduces ``engine.run_scan`` on scenario ``s``'s (seed, channels, V,
  lam, budget): the model trajectory — final params, per-round losses,
  selections, realised latency — is bit-for-bit identical on the
  leaf-chunked aggregation path (CPU/GPU), because the scan body, data
  plane, and PRNG stream are shared code and the eq.-(4) reduction is
  written vmap-stably (see ``server.aggregate_stacked``).  The
  control-plane diagnostics (queue/energy scalars from Algorithm 2's
  bisection solver) agree to float32 resolution (~1e-6 relative) rather
  than bitwise: XLA fuses those elementwise chains shape-dependently,
  so the batched and unbatched programs may round a final ulp apart.
  Tiered banks relax the model half to f32 resolution too — the tier
  loop's per-tier ``lax.cond`` lowers as a real branch unbatched but as
  a both-branches select under vmap.  The contract is regression-tested
  in ``tests/test_arena.py``.
* **Channels pregenerated on device.**  Per-scenario (mean, clip) channel
  statistics feed a vmapped ``environment.sample_gains`` — the whole
  ``[S, T, N]`` gain tensor is drawn in one jit from the scenario seeds.
* **Scenario-axis sharding.**  Pass ``mesh=`` (e.g. ``launch.mesh.
  make_fl_mesh()``) and the scenario axis is ``shard_map``ped over the
  ``data`` axis: whole rollouts per shard, zero cross-shard collectives
  (embarrassingly parallel — the strong-scaling axis for sweep grids).
  The engine itself must then be mesh-free: client-axis and
  scenario-axis sharding compose by running the arena on the ``data``
  axis of a larger mesh, not by nesting shard_maps.
* **Padded-K dispatch fusion.**  K is per-scenario DATA, not shape: the
  scan body is built at the grid's static ``K_max`` and each lane's
  true K rides in as traced ``k_act``/``kvec`` (slots beyond ``k_act``
  are inert — row-0 gather, zeroed eq.-(4) coefficients and
  loss/latency/energy contributions — so padded lanes stay bitwise
  equal on the model trajectory to the per-K groups they replace).  A
  mixed-K grid is ONE compiled executable and ONE dispatch
  (``k_mode='pad'``, the default; ``'group'`` keeps the legacy
  one-program-per-K path for comparison).
* **On-device evaluation.**  Pass an :class:`repro.sim.eval.EvalBank`
  and the final ``[S, ...]`` params are evaluated in one vmapped
  ``task.metrics`` dispatch (``RolloutReport.final_metrics``);
  ``eval_every=E`` also evaluates inside the rollout executable every E
  rounds behind an unbatched ``lax.cond`` (``test_*`` per-round
  columns) — no host-side per-lane eval loop.
* **Warmup / executable cache.**  Executables are cached per (bank
  layout, K_max, shards, eval config); :meth:`Arena.warmup` compiles
  them eagerly so same-shape ``run`` calls (the iterate-on-V workflow)
  never retrace — ``Arena.traces`` counts scan-body traces for
  asserting exactly that.
* **Streaming chunked pipeline.**  ``chunk_size=T_c`` splits a T-round
  rollout into ``ceil(T / T_c)`` scan segments over the SAME body:
  chunk 0 runs the monolithic start executable at segment length, later
  chunks a resume executable whose (params, queues, rng, last-eval)
  carry arrives per-lane and is donated between segments.  Host
  reduction of chunk c's metric columns overlaps chunk c+1's device
  execution (async dispatch with a bounded in-flight window — no
  ``block_until_ready`` between chunks), and the chunked trajectory is
  bitwise-identical to the one-shot scan.  A ``chunk_store`` persists
  the carry at chunk boundaries (atomic npz via ``repro.checkpoint``)
  so interrupted runs resume bit-identically —
  ``repro.sim.service.SweepService`` builds the continuous warmed
  sweep-service loop on top.

Outputs land in a :class:`repro.sim.report.RolloutReport` (``[S, T]``
metric arrays + stacked final params/queues + ``meta`` execution-shape
counters) whose reducers emit the paper's latency / accuracy / loss /
energy trade-off curves.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import policy as pol
from repro.core import system_model as sm
from repro.core.controller import estimate_hyperparams_arrays
from repro.fl.environment import (CHANNEL_MODE_IDS, CHANNEL_MODES,
                                  sample_channel_sequence,
                                  sample_dropout_mask)
from repro.fl.round_engine import bank_layout_key
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.sim.cost_model import CostModel
from repro.sim.dispatch import DispatchPlan, lane_footprints, plan_dispatch
from repro.sim.report import RolloutReport, concat_chunk_metrics

PyTree = Any

_AOT_WARMUP_SUPPORTED: Optional[bool] = None


def aot_cache_warmup_supported() -> bool:
    """Does THIS jax populate the jit call cache from AOT
    ``jit(f).lower(...).compile()``?  Probed once per process with a
    trace-counting scalar function: lower+compile it, then call it — if
    the call re-traces, AOT warming buys nothing and ``Arena.warmup``
    must fall back to executing a real run.  (jax 0.4.x re-traces; the
    probe keeps the warmup honest across jax upgrades instead of
    hard-coding a version check.)"""
    global _AOT_WARMUP_SUPPORTED
    if _AOT_WARMUP_SUPPORTED is None:
        traces: List[int] = []

        def probe(x):
            traces.append(1)
            return x + 1.0

        fn = jax.jit(probe)
        x = jnp.zeros(())
        try:
            fn.lower(x).compile()
            jax.block_until_ready(fn(x))
            _AOT_WARMUP_SUPPORTED = len(traces) == 1
        except Exception:       # pragma: no cover - AOT API missing
            _AOT_WARMUP_SUPPORTED = False
    return _AOT_WARMUP_SUPPORTED

def _as_f32(value, s: int) -> np.ndarray:
    return np.broadcast_to(np.asarray(value, np.float32), (s,)).copy()


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """Struct-of-arrays stack of S scenarios (all fields shape ``[S]``).

    ``controller`` holds ``repro.core.policy.POLICY_IDS`` ids;
    ``energy_scale`` multiplies the base ``SystemParams.energy_budget``;
    (``mean_gain``, ``min_gain``, ``max_gain``) are the per-scenario
    truncated-exponential channel statistics; ``sample_count`` is K.
    ``chan_mode`` selects the channel process per lane
    (``repro.fl.environment.CHANNEL_MODE_IDS`` — 'iid' or 'markov'),
    with (``bad_gain``, ``p_gb``, ``p_bg``) the Gilbert-Elliott
    bad-state mean and transition probabilities (ignored by 'iid'
    lanes); ``dropout`` is the per-client per-round dropout probability
    (0.0 = the historical always-alive trace).  Build with
    :meth:`create` (broadcasting scalars) or :meth:`product` (cartesian
    sweep axes).
    """

    controller: np.ndarray
    seed: np.ndarray
    V: np.ndarray
    lam: np.ndarray
    energy_scale: np.ndarray
    mean_gain: np.ndarray
    min_gain: np.ndarray
    max_gain: np.ndarray
    sample_count: np.ndarray
    # non-stationary axes; None defaults keep pre-zoo constructions
    # (and their digests' field iteration order) valid
    chan_mode: Optional[np.ndarray] = None
    bad_gain: Optional[np.ndarray] = None
    p_gb: Optional[np.ndarray] = None
    p_bg: Optional[np.ndarray] = None
    dropout: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return int(self.controller.shape[0])

    def __post_init__(self):
        s = len(self)
        defaults = dict(chan_mode=np.zeros((s,), np.int32),
                        bad_gain=np.full((s,), 0.02, np.float32),
                        p_gb=np.zeros((s,), np.float32),
                        p_bg=np.zeros((s,), np.float32),
                        dropout=np.zeros((s,), np.float32))
        for name, default in defaults.items():
            if getattr(self, name) is None:
                object.__setattr__(self, name, default)
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            if arr.shape != (s,):
                raise ValueError(f"ScenarioGrid.{f.name} must have shape "
                                 f"({s},), got {arr.shape}")
        if s == 0:
            raise ValueError("empty ScenarioGrid")
        if np.any((self.chan_mode < 0) |
                  (self.chan_mode >= len(CHANNEL_MODES))):
            raise ValueError(f"chan_mode ids must index {CHANNEL_MODES}")
        for name in ("p_gb", "p_bg"):
            vals = getattr(self, name)
            if np.any((vals < 0.0) | (vals > 1.0)):
                raise ValueError(f"ScenarioGrid.{name} must lie in [0, 1]")
        if np.any((self.dropout < 0.0) | (self.dropout >= 1.0)):
            raise ValueError("ScenarioGrid.dropout must lie in [0, 1)")
        # jax.random.PRNGKey truncates seeds to 32 bits under the default
        # x64-disabled runtime, so seeds differing only above bit 31 would
        # silently run IDENTICAL lanes — reject them instead
        if np.any(self.seed < 0) or np.any(self.seed >= 2 ** 32):
            raise ValueError("ScenarioGrid seeds must fit in uint32 "
                             "(PRNGKey truncates wider seeds, which would "
                             "silently alias scenarios)")
        if np.any(self.sample_count < 1):
            raise ValueError(
                f"ScenarioGrid sample_count values must be >= 1, got "
                f"{self.sample_count.tolist()}")

    @staticmethod
    def _check_sample_counts(sample_count, num_devices) -> None:
        """Reject K > N at construction — the paper's sampling draws K of
        N devices, and an oversized K would otherwise surface only as a
        shape/semantics failure deep inside the rollout trace."""
        if num_devices is None:
            return
        ks = np.atleast_1d(np.asarray(sample_count, np.int64))
        if np.any(ks > int(num_devices)):
            bad = sorted(int(v) for v in np.unique(ks[ks > num_devices]))
            raise ValueError(
                f"sample_count values {bad} exceed num_devices="
                f"{int(num_devices)} (K must satisfy K <= N)")

    @staticmethod
    def _controller_ids(controllers) -> np.ndarray:
        ids = []
        for c in np.atleast_1d(np.asarray(controllers, object)):
            if isinstance(c, (int, np.integer)):
                cid = int(c)
                if not 0 <= cid < len(pol.POLICIES):
                    raise ValueError(f"controller id {cid} out of range "
                                     f"for {pol.POLICIES}")
            else:
                name = str(c)
                if name not in pol.POLICY_IDS:
                    raise ValueError(f"unknown controller {name!r} "
                                     f"(scan-traceable: {pol.POLICIES})")
                cid = pol.POLICY_IDS[name]
            ids.append(cid)
        return np.asarray(ids, np.int32)

    @staticmethod
    def _channel_mode_ids(modes) -> np.ndarray:
        ids = []
        for m in np.atleast_1d(np.asarray(modes, object)):
            if isinstance(m, (int, np.integer)):
                mid = int(m)
                if not 0 <= mid < len(CHANNEL_MODES):
                    raise ValueError(f"channel mode id {mid} out of range "
                                     f"for {CHANNEL_MODES}")
            else:
                name = str(m)
                if name not in CHANNEL_MODE_IDS:
                    raise ValueError(f"unknown channel mode {name!r} "
                                     f"(known: {CHANNEL_MODES})")
                mid = CHANNEL_MODE_IDS[name]
            ids.append(mid)
        return np.asarray(ids, np.int32)

    @classmethod
    def create(cls, controllers, seeds, V, lam, *, energy_scale=1.0,
               mean_gain=0.1, min_gain=0.01, max_gain=0.5,
               sample_count=2, chan_mode="iid", bad_gain=0.02, p_gb=0.0,
               p_bg=0.0, dropout=0.0,
               num_devices=None) -> "ScenarioGrid":
        """Element-wise grid: every argument broadcasts to the common
        scenario count S (controllers by name or id, channel modes by
        name or id).  ``num_devices`` (optional) validates every K
        against N up front."""
        cls._check_sample_counts(sample_count, num_devices)
        ids = cls._controller_ids(controllers)
        modes = cls._channel_mode_ids(chan_mode)
        seeds = np.atleast_1d(np.asarray(seeds, np.int64))
        s = max(ids.shape[0], seeds.shape[0], modes.shape[0],
                *(np.atleast_1d(np.asarray(v)).shape[0]
                  for v in (V, lam, energy_scale, mean_gain, min_gain,
                            max_gain, sample_count, bad_gain, p_gb, p_bg,
                            dropout)))
        return cls(
            controller=np.broadcast_to(ids, (s,)).copy(),
            seed=np.broadcast_to(seeds, (s,)).copy(),
            V=_as_f32(V, s), lam=_as_f32(lam, s),
            energy_scale=_as_f32(energy_scale, s),
            mean_gain=_as_f32(mean_gain, s),
            min_gain=_as_f32(min_gain, s),
            max_gain=_as_f32(max_gain, s),
            sample_count=np.broadcast_to(
                np.asarray(sample_count, np.int32), (s,)).copy(),
            chan_mode=np.broadcast_to(modes, (s,)).copy(),
            bad_gain=_as_f32(bad_gain, s),
            p_gb=_as_f32(p_gb, s), p_bg=_as_f32(p_bg, s),
            dropout=_as_f32(dropout, s),
        )

    @classmethod
    def product(cls, controllers, seeds, V, lam, *, energy_scale=(1.0,),
                mean_gain=(0.1,), min_gain=(0.01,), max_gain=(0.5,),
                sample_count=(2,), chan_mode=("iid",), bad_gain=(0.02,),
                p_gb=(0.0,), p_bg=(0.0,), dropout=(0.0,),
                num_devices=None) -> "ScenarioGrid":
        """Cartesian sweep: one scenario per element of the cross product
        of the given axes (the Sec. VII comparison grid: controllers x
        seeds x hyper-parameters x budgets x channels x K x channel
        modes x dropout).  The Gilbert-Elliott shape axes (``bad_gain``,
        ``p_gb``, ``p_bg``) cross like any other axis — sweep them only
        with a markov ``chan_mode`` in play, or they multiply lanes that
        ignore them.  ``num_devices`` (optional) validates every K
        against N up front — a clear construction-time error instead of
        a failure inside the rollout trace."""
        cls._check_sample_counts(sample_count, num_devices)
        ids = cls._controller_ids(controllers)
        modes = cls._channel_mode_ids(chan_mode)
        axes = [ids.tolist(), np.atleast_1d(seeds).tolist(),
                np.atleast_1d(V).tolist(), np.atleast_1d(lam).tolist(),
                np.atleast_1d(energy_scale).tolist(),
                np.atleast_1d(mean_gain).tolist(),
                np.atleast_1d(min_gain).tolist(),
                np.atleast_1d(max_gain).tolist(),
                np.atleast_1d(sample_count).tolist(),
                modes.tolist(),
                np.atleast_1d(bad_gain).tolist(),
                np.atleast_1d(p_gb).tolist(),
                np.atleast_1d(p_bg).tolist(),
                np.atleast_1d(dropout).tolist()]
        rows = list(itertools.product(*axes))
        cols = list(zip(*rows))
        return cls(
            controller=np.asarray(cols[0], np.int32),
            seed=np.asarray(cols[1], np.int64),
            V=np.asarray(cols[2], np.float32),
            lam=np.asarray(cols[3], np.float32),
            energy_scale=np.asarray(cols[4], np.float32),
            mean_gain=np.asarray(cols[5], np.float32),
            min_gain=np.asarray(cols[6], np.float32),
            max_gain=np.asarray(cols[7], np.float32),
            sample_count=np.asarray(cols[8], np.int32),
            chan_mode=np.asarray(cols[9], np.int32),
            bad_gain=np.asarray(cols[10], np.float32),
            p_gb=np.asarray(cols[11], np.float32),
            p_bg=np.asarray(cols[12], np.float32),
            dropout=np.asarray(cols[13], np.float32),
        )

    def take(self, idx: np.ndarray) -> "ScenarioGrid":
        """Sub-grid of the given scenario indices (grid order kept)."""
        return ScenarioGrid(**{f.name: getattr(self, f.name)[idx]
                               for f in dataclasses.fields(self)})

    @classmethod
    def concat(cls, grids: "List[ScenarioGrid]") -> "ScenarioGrid":
        """Stack several grids into one (lane order = submission order)
        — the sweep service's coalescing primitive: compatible pending
        submissions concatenate into a single batched grid, execute as
        one arena program, and split back per submission with
        ``RolloutReport.take``."""
        if not grids:
            raise ValueError("no grids to concatenate")
        return cls(**{f.name: np.concatenate(
            [getattr(g, f.name) for g in grids])
            for f in dataclasses.fields(grids[0])})

    def controller_names(self) -> list:
        return [pol.POLICIES[c] for c in self.controller]

    def channel_mode_names(self) -> list:
        return [CHANNEL_MODES[m] for m in self.chan_mode]

    def channel_config(self, s: int):
        """Scenario ``s``'s channel statistics as a ``ChannelConfig`` —
        the exact process an individual host replay of lane ``s`` must
        sample from."""
        from repro.fl.environment import ChannelConfig
        return ChannelConfig(
            mean_gain=float(self.mean_gain[s]),
            min_gain=float(self.min_gain[s]),
            max_gain=float(self.max_gain[s]),
            seed=int(self.seed[s]),
            mode=CHANNEL_MODES[int(self.chan_mode[s])],
            bad_gain=float(self.bad_gain[s]),
            p_gb=float(self.p_gb[s]), p_bg=float(self.p_bg[s]),
            dropout=float(self.dropout[s]))

    def scenario_system_params(self, sp: sm.SystemParams, s: int
                               ) -> sm.SystemParams:
        """Scenario ``s``'s SystemParams — the exact parameters an
        individual ``run_scan`` reproduction of lane ``s`` must use."""
        eb = np.asarray(sp.energy_budget, np.float32) * self.energy_scale[s]
        return dataclasses.replace(sp, sample_count=int(
            self.sample_count[s]), energy_budget=jnp.asarray(eb))


# module-level jits: a jit wrapper built inside a method would retrace
# and recompile on every call (jax caches on callable identity)
_sample_channels = jax.jit(
    jax.vmap(sample_channel_sequence,
             in_axes=(0, None, None, 0, 0, 0, 0, 0, 0, 0)),
    static_argnums=(1, 2))

_sample_dropout = jax.jit(
    jax.vmap(sample_dropout_mask, in_axes=(0, None, None, 0)),
    static_argnums=(1, 2))


@jax.jit
def _scenario_keys(seeds: jax.Array) -> Tuple[jax.Array, jax.Array]:
    # vmapped PRNGKey/split are bitwise identical to the per-seed host
    # loop (threefry init and split are elementwise on the key words) —
    # regression-tested — and one fused dispatch instead of S tiny ones.
    roots = jax.vmap(jax.random.PRNGKey)(seeds)
    return jax.vmap(lambda k: tuple(jax.random.split(k)))(roots)


def scenario_keys(grid: ScenarioGrid) -> Tuple[jax.Array, jax.Array]:
    """Per-scenario PRNG streams: ``(channel_keys [S, 2], rollout_keys
    [S, 2])``, both split from ``PRNGKey(seed)``.  This split IS the
    reproducibility contract — an individual ``run_scan`` with
    ``rng=rollout_keys[s]`` over ``h_all[s]`` replays arena lane ``s``.
    """
    return _scenario_keys(jnp.asarray(grid.seed, jnp.uint32))


@jax.jit
def _grid_hyperparams(sp_k, gains, scales, mus, nus, scale_f0):
    def one(gain, escale, m, n, f0):
        sp_s = dataclasses.replace(
            sp_k, energy_budget=sp_k.energy_budget * escale)
        lam_s, v_s, _, _ = estimate_hyperparams_arrays(
            sp_s, gain, loss_scale=f0, mu=m, nu=n)
        return lam_s, v_s
    return jax.vmap(one)(gains, scales, mus, nus, scale_f0)


def derive_hyperparams(sp: sm.SystemParams, grid: ScenarioGrid, mu, nu,
                       loss_scale=1.0) -> ScenarioGrid:
    """Fill the grid's (lam, V) from per-scenario (mu, nu) via the
    Sec. VII-B estimates — computed INSIDE one jit per K group
    (``estimate_hyperparams_arrays`` is pure jax), using each scenario's
    own mean channel gain and scaled energy budget."""
    s = len(grid)
    mu = _as_f32(mu, s)
    nu = _as_f32(nu, s)
    loss_scale = _as_f32(loss_scale, s)
    lam = np.zeros(s, np.float32)
    v = np.zeros(s, np.float32)
    for k in np.unique(grid.sample_count):
        idx = np.flatnonzero(grid.sample_count == k)
        sp_k = dataclasses.replace(sp, sample_count=int(k))
        lam_k, v_k = _grid_hyperparams(
            sp_k, jnp.asarray(grid.mean_gain[idx]),
            jnp.asarray(grid.energy_scale[idx]),
            jnp.asarray(mu[idx]), jnp.asarray(nu[idx]),
            jnp.asarray(loss_scale[idx]))
        lam[idx] = np.asarray(lam_k)
        v[idx] = np.asarray(v_k)
    return dataclasses.replace(grid, lam=lam, V=v)


class Arena:
    """Runs a :class:`ScenarioGrid` as one batched program over one engine.

    ``engine``: a mesh-free :class:`repro.fl.round_engine.RoundEngine`
    (the arena owns the parallel axis — see the module docstring).
    ``mesh``: optional 1-D mesh whose ``mesh_axis`` shards the scenario
    axis, whole rollouts per shard.  ``batch`` picks how lanes are laid
    out inside each (per-shard) program:

    * ``'vmap'`` (default) — lanes batched into wide ops.  The
      accelerator-friendly mode: S tiny rollouts become one set of
      S-wide kernels.  Algorithm 2's ``while_loop``s run in cross-lane
      lockstep (every lane pays the slowest lane's trip count).
    * ``'map'`` — lanes laid out sequentially (``lax.map``), each
      executing the exact unbatched rollout trace with its own solver
      trip counts.  The CPU-friendly mode: combined with scenario
      sharding it strong-scales near-linearly in local devices, with no
      lockstep amplification.

    ``k_mode`` picks how a mixed-K grid is executed:

    * ``'pad'`` (default) — ONE padded-K executable for the whole grid:
      the program is shaped by ``K_max = max(grid.sample_count)`` and
      each lane carries its true K as traced data (``k_act``/``kvec``,
      see ``RoundEngine._build_scan``); padded slots are inert (row-0
      gather, zeroed coefficients), so every lane stays bit-identical on
      the model trajectory to the per-K group it replaces — at one
      compile and one dispatch instead of one per distinct K.
    * ``'group'`` — the legacy path: one jitted program per distinct K,
      lanes scattered back into grid order on the host.  Kept for the
      bench baseline and for grids so K-skewed that padding waste
      (every lane trains ``K_max`` slots) beats compile/dispatch savings.
    * ``'auto'`` — shape-adaptive dispatch: a
      :func:`repro.sim.dispatch.plan_dispatch` cost model buckets the
      lanes by ``(K, tier footprint)`` signature into a small ladder of
      executables under ``max_executables``, with pad and group as
      reachable degenerate plans.  ``run`` plans for a ONE-run horizon
      (cold grids collapse toward the single padded executable — the
      workflow win), :meth:`warmup` for a steady-state horizon (buckets
      split by signature — the throughput win) and compiles every bucket
      in that plan; post-warmup ``run`` calls see the warmed buckets via
      the cache-aware cost model and re-pick them.  Multi-tier banks
      additionally get per-bucket STATIC tier subsets: lane footprints
      are replayed by a control-plane probe (selections depend only on
      the control plane, never on training — the same determinism the
      lane-equivalence tests pin down), so a bucket whose lanes never
      draw tier ``t`` compiles a scan body without it, recovering the
      skewed-ladder scan-skip that ``vmap`` otherwise erases.  Results
      are stitched back to grid order (device-side ``concatenate`` +
      ``take`` per params leaf); per-bucket lanes stay bitwise-equal on
      the model trajectory to their pad/group counterparts.

    Compiled executables are cached per (bank layout, K_max, shard
    count, eval config) — :meth:`warmup` populates the cache eagerly so
    repeated same-shape ``run`` calls (the iterate-on-V workflow) never
    trace or compile again; ``self.traces`` counts scan-body traces for
    asserting that.  The bank and the initial params are never donated,
    so one arena serves any number of grids; the per-lane queue carry IS
    donated off-CPU (the arena allocates it per run).

    Memory audit (padded-K vs per-K groups): the executable's live state
    is the per-lane scan carry — params ``[S, ...]`` + queues ``[S, N]``
    (+ the last-eval carry with ``eval_every``) — plus one ``K_max``-wide
    training buffer per lane.  Grouped execution holds the same ``[S]``
    stacked outputs anyway (all groups' results are concatenated on the
    host), so padding adds only the ``(K_max - K_s)`` inert training
    slots per lane, bounded by ``S * (K_max - K_min) * B`` bucket rows —
    and removes the host-side per-lane params re-stack the grouped
    scatter pays.  Queue-carry donation keeps the padded program's peak
    at parity with the per-K programs'.
    """

    def __init__(self, engine, mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "data", batch: str = "vmap",
                 k_mode: str = "pad",
                 cost_model: Optional[CostModel] = None,
                 max_executables: int = 4,
                 chunk_size: Optional[int] = None):
        if engine.mesh is not None:
            raise ValueError(
                "ScenarioArena shards the scenario axis; build the "
                "RoundEngine without a mesh (client-axis shard_map does "
                "not nest under the arena's vmap/shard_map)")
        if batch not in ("vmap", "map"):
            raise ValueError(f"unknown batch mode {batch!r} "
                             "(expected 'vmap' or 'map')")
        if k_mode not in ("pad", "group", "auto"):
            raise ValueError(f"unknown k_mode {k_mode!r} "
                             "(expected 'pad', 'group' or 'auto')")
        if max_executables < 1:
            raise ValueError(f"max_executables must be >= 1, "
                             f"got {max_executables}")
        self.engine = engine
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.batch = batch
        self.k_mode = k_mode
        #: prices for ``k_mode='auto'`` planning (``None`` = the tracked
        #: calibration defaults; see ``repro.sim.cost_model``)
        self.cost_model = (cost_model if cost_model is not None
                           else CostModel())
        #: hard cap on buckets an ``'auto'`` plan may emit
        self.max_executables = max_executables
        #: default rollout chunk length for the streaming path: ``None``
        #: runs the classic monolithic scan; an int T_c splits every
        #: rollout into ``ceil(T / T_c)`` pipelined scan segments whose
        #: carry is donated between chunks (``run``'s ``chunk_size=``
        #: overrides per call)
        self.chunk_size = chunk_size
        #: dispatched-but-unreduced chunk window of the streaming path —
        #: chunk c+1 is dispatched while chunk c's columns convert to
        #: host arrays, and the pipeline never runs more than this many
        #: chunks ahead of the reduction
        self.in_flight = 2
        self._fns: Dict[tuple, Any] = {}
        # control-plane probe executables / replayed footprints, kept
        # OUT of self._fns so executables_cached keeps counting rollout
        # programs only
        self._probe_fns: Dict[tuple, Any] = {}
        self._footprint_cache: Dict[bytes, list] = {}
        #: the flight recorder's metrics registry — ONE namespace for
        #: every runtime tally of this arena and anything built on it
        #: (the sweep service and chunk store share it).  The historical
        #: counter attributes (``traces``, ``input_cache_hits`` /
        #: ``_misses``) are read-only views over it.
        self.metrics = MetricsRegistry()
        #: optional :class:`repro.obs.watchdog.Watchdog` — armed by
        #: :meth:`warmup`, notified after every :meth:`run`
        self.watchdog = None
        # device-input caches (bounded, insertion-order eviction): lane
        # constants keyed by grid content, lr sequences by value, channel
        # tensors by (grid, T, N) — steady-state service submissions of a
        # known grid re-use the device arrays and transfer nothing
        self._input_cache_cap = 16
        self._lane_cache: Dict[bytes, dict] = {}
        self._lr_cache: Dict[bytes, jax.Array] = {}
        self._chan_cache: Dict[bytes, jax.Array] = {}

    # -- registry views (the pre-obs counter attributes) ---------------------

    @property
    def traces(self) -> int:
        """Scan-body trace count — every jit (re)trace of a group
        executable runs the counted wrapper once, so a warmed arena
        must keep this constant across same-shape ``run`` calls.  A
        view over ``metrics['arena.traces']``."""
        return self.metrics.counter("arena.traces").value

    @property
    def input_cache_hits(self) -> int:
        """Device-input cache hits (lane constants + lr + channels) — a
        view over ``metrics['arena.input_cache.hits']``."""
        return self.metrics.counter("arena.input_cache.hits").value

    @property
    def input_cache_misses(self) -> int:
        """Device-input cache misses — a view over
        ``metrics['arena.input_cache.misses']``."""
        return self.metrics.counter("arena.input_cache.misses").value

    def _shards(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.mesh_axis])

    # -- channel pregeneration / device-input caches -------------------------

    @staticmethod
    def _grid_digest(grid: ScenarioGrid, extra: tuple = ()) -> bytes:
        """Content hash of every grid column (+ ``extra`` context) — the
        key of the device-input caches and the chunk-checkpoint tag, so
        it must be a pure function of values, never of Python object
        identity (checkpoint tags survive process restarts)."""
        hasher = hashlib.sha1()
        for f in dataclasses.fields(grid):
            hasher.update(np.ascontiguousarray(
                getattr(grid, f.name)).tobytes())
        hasher.update(repr(extra).encode())
        return hasher.digest()

    def _cache_put(self, cache: dict, key, value):
        if len(cache) >= self._input_cache_cap:
            cache.pop(next(iter(cache)))
        cache[key] = value
        return value

    def sample_channels(self, grid: ScenarioGrid, num_rounds: int,
                        num_devices: int) -> jax.Array:
        """Every scenario's channel sequence, ``[S, T, N]``, drawn on
        device in one jit from the per-scenario (seed, mode, mean, clip,
        chain) columns (vmapped ``environment.sample_channel_sequence``
        — stationary lanes consume the raw channel key exactly as the
        pre-zoo ``sample_gains`` did, markov lanes the ``fold_in(key,
        1)`` stream).  Cached by (grid content, T, N): the draw is a
        pure function of those, so repeated sweeps of a known grid (the
        service steady state) reuse the device tensor instead of
        re-sampling it."""
        key = self._grid_digest(grid, ("chan", num_rounds, num_devices))
        hit = self._chan_cache.get(key)
        if hit is not None:
            self.metrics.counter("arena.input_cache.hits").inc()
            return hit
        self.metrics.counter("arena.input_cache.misses").inc()
        with obs.span("arena.upload", what="channels", lanes=len(grid),
                      rounds=num_rounds):
            chan_keys, _ = scenario_keys(grid)
            h_all = _sample_channels(chan_keys, num_rounds,
                                     num_devices,
                                     jnp.asarray(grid.chan_mode),
                                     jnp.asarray(grid.mean_gain),
                                     jnp.asarray(grid.bad_gain),
                                     jnp.asarray(grid.min_gain),
                                     jnp.asarray(grid.max_gain),
                                     jnp.asarray(grid.p_gb),
                                     jnp.asarray(grid.p_bg))
        return self._cache_put(self._chan_cache, key, h_all)

    def sample_dropout(self, grid: ScenarioGrid, num_rounds: int,
                       num_devices: int) -> jax.Array:
        """Every scenario's alive mask, ``[S, T, N]`` float32 (1.0 =
        alive), from the DEDICATED ``fold_in(chan_key, 2)`` stream of
        the same per-scenario channel keys — so enabling the axis never
        perturbs the gains (the stream-separation regression contract).
        Cached like :meth:`sample_channels`."""
        key = self._grid_digest(grid, ("drop", num_rounds, num_devices))
        hit = self._chan_cache.get(key)
        if hit is not None:
            self.metrics.counter("arena.input_cache.hits").inc()
            return hit
        self.metrics.counter("arena.input_cache.misses").inc()
        with obs.span("arena.upload", what="dropout", lanes=len(grid),
                      rounds=num_rounds):
            chan_keys, _ = scenario_keys(grid)
            drop_all = _sample_dropout(chan_keys, num_rounds, num_devices,
                                       jnp.asarray(grid.dropout))
        return self._cache_put(self._chan_cache, key, drop_all)

    def _lane_inputs(self, grid: ScenarioGrid, sp: sm.SystemParams) -> dict:
        """The per-lane device constants a group executable consumes —
        energy budgets, V/lam/kvec materialized ``[S, N]``, controller
        ids, active-slot counts, rollout keys — cached by grid content
        so steady-state re-runs upload nothing.  Entries are read-only:
        none of these ever flow into a donated argnum (queues and the
        chunk carry are allocated or produced per run)."""
        key = self._grid_digest(
            grid, ("lane", sp.num_devices,
                   np.asarray(sp.energy_budget, np.float32).tobytes()))
        hit = self._lane_cache.get(key)
        if hit is not None:
            self.metrics.counter("arena.input_cache.hits").inc()
            return hit
        self.metrics.counter("arena.input_cache.misses").inc()
        upload = obs.span("arena.upload", what="lane_constants",
                          lanes=len(grid))
        upload.__enter__()
        s, n = len(grid), sp.num_devices
        _, roll_keys = scenario_keys(grid)
        eb = (np.asarray(sp.energy_budget, np.float32)[None, :] *
              grid.energy_scale[:, None])
        vals = dict(
            eb=jnp.asarray(eb),
            V=jnp.asarray(np.broadcast_to(grid.V[:, None], (s, n))),
            lam=jnp.asarray(np.broadcast_to(grid.lam[:, None], (s, n))),
            cid=jnp.asarray(grid.controller),
            kvec=jnp.asarray(np.broadcast_to(
                grid.sample_count[:, None].astype(np.float32), (s, n))),
            k_act=jnp.asarray(grid.sample_count, jnp.int32),
            roll_keys=roll_keys)
        upload.__exit__(None, None, None)
        return self._cache_put(self._lane_cache, key, vals)

    def _lr_device(self, lr_seq) -> jax.Array:
        """Device copy of the ``[T]`` learning-rate sequence, cached by
        value — one upload per distinct schedule."""
        lr_np = np.asarray(lr_seq, np.float32)
        key = lr_np.tobytes()
        hit = self._lr_cache.get(key)
        if hit is not None:
            self.metrics.counter("arena.input_cache.hits").inc()
            return hit
        self.metrics.counter("arena.input_cache.misses").inc()
        with obs.span("arena.upload", what="lr", rounds=int(lr_np.shape[0])):
            lr_dev = jnp.asarray(lr_np)
        return self._cache_put(self._lr_cache, key, lr_dev)

    # -- the batched rollout ------------------------------------------------

    def _eval_key(self, eval_bank, eval_every):
        if eval_bank is None or not eval_every:
            return None
        return (id(eval_bank.task), int(eval_every))

    def _build_group_fn(self, key: tuple, k: int, round_fn, eval_bank,
                        eval_every, resume: bool = False,
                        use_dropout: bool = False):
        """jit( [shard_map(] vmap(scan body) [)] ) for one K group,
        stored in ``self._fns`` under the caller's ``key`` — (bank
        layout, K_max, shard count, eval config), built ONCE in
        ``_run_group`` so lookup and insertion cannot drift apart.
        ``round_fn`` closes over only static layout captured in the
        bank-layout key component (the device buffers arrive via the
        ``data`` argument) and the eval data arrives traced too, so the
        cache key is sound — same contract as the engine's
        ``_scan_fns``.

        ``resume=False`` builds the rollout-START executable: params
        broadcast across lanes (``in_axes=None``), rng/last-eval derived
        inside (last-eval ``None`` — the initial evaluation runs
        UNBATCHED under vmap, exactly the monolithic program, which is
        why chunk 0 of a chunked rollout reuses this very executable).
        ``resume=True`` builds the chunk-CONTINUATION executable: the
        (params, queues, rng, last-eval) carry arrives per-lane
        (``in_axes=0``) and every carry leaf is donated — chunk c's
        output buffers become chunk c+1's carry in place.  Because
        rounds >= 1 of the monolithic vmapped scan already compute on a
        batched params carry, continuing with batched params is the
        identical per-round graph — the chunked == monolithic bitwise
        contract."""
        def decide(sp, h, queues, V, lam, cid, kvec):
            return pol.decide_by_id(cid, sp, h, queues, V, lam, k=kvec)

        def select(sp, t, h, queues, q, skey, slots, kvec, cid):
            return pol.select_by_id(cid, sp, t, h, queues, q, skey,
                                    slots, kvec)

        ek = self._eval_key(eval_bank, eval_every)
        # make_eval_fn closes over the TASK, not the bank: the cached
        # executable lives for the arena's lifetime, and capturing a
        # bank-bound callable would pin the test-set device buffers with
        # it (the data itself arrives as traced arguments)
        eval_fn = (None if ek is None
                   else eval_bank.make_eval_fn(eval_bank.task))
        inner = self.engine._build_scan(k, decide, round_fn,
                                        select_fn=select,
                                        eval_fn=eval_fn,
                                        eval_every=eval_every or 0,
                                        use_dropout=use_dropout)

        def scan_fn(*args):
            # runs at TRACE time only (the executable replays without
            # re-entering Python) — the zero-retrace warmup assertion
            self.metrics.counter("arena.traces").inc()
            return inner(*args)

        # the carry trio (params, rng-continuation via the rng argument,
        # last-eval) is per-lane on the resume executable, broadcast /
        # absent on the start executable; t0 (the global round offset) is
        # always a shared traced scalar so equal-length chunks share one
        # executable
        p_ax = 0 if resume else None
        ev_ax = 0 if resume else None
        d_ax = 0 if use_dropout else None
        if self.batch == "vmap":
            batched = jax.vmap(scan_fn,
                               in_axes=(p_ax, 0, None, 0, None, 0, d_ax,
                                        None, 0, 0, 0, 0, 0, 0, None,
                                        None, ev_ax))
        else:
            def batched(params, queues, sp, eb, data, h_seq, drop_seq,
                        lr_seq, rng, V, lam, cid, kvec, k_act, eval_data,
                        t0, last_ev):
                if resume:
                    def one(lane):
                        (p_s, q0, eb_s, h_s, d_s, rng_s, V_s, lam_s,
                         cid_s, kv_s, ka_s, ev_s) = lane
                        return scan_fn(p_s, q0, sp, eb_s, data, h_s,
                                       d_s, lr_seq, rng_s, V_s, lam_s,
                                       cid_s, kv_s, ka_s, eval_data, t0,
                                       ev_s)
                    return jax.lax.map(one, (params, queues, eb, h_seq,
                                             drop_seq, rng, V, lam, cid,
                                             kvec, k_act, last_ev))

                def one(lane):
                    (q0, eb_s, h_s, d_s, rng_s, V_s, lam_s, cid_s, kv_s,
                     ka_s) = lane
                    return scan_fn(params, q0, sp, eb_s, data, h_s, d_s,
                                   lr_seq, rng_s, V_s, lam_s, cid_s,
                                   kv_s, ka_s, eval_data, t0, last_ev)
                return jax.lax.map(one, (queues, eb, h_seq, drop_seq,
                                         rng, V, lam, cid, kvec, k_act))
        if self.mesh is not None:
            ax = self.mesh_axis
            p_spec = P(ax) if resume else P()
            d_spec = P(ax) if use_dropout else P()
            batched = shard_map(
                batched, mesh=self.mesh,
                in_specs=(p_spec, P(ax), P(), P(ax), P(), P(ax), d_spec,
                          P(), P(ax), P(ax), P(ax), P(ax), P(ax), P(ax),
                          P(), P(), p_spec),
                out_specs=P(ax), check_rep=False)
        # the queue carry (argnum 1) is donated off-CPU: the arena
        # allocates it per run, so the padded program reuses that buffer
        # for the [S, N] carry instead of holding both — part of the
        # padded-vs-grouped peak-memory parity audit (class docstring).
        # On the start executable params (argnum 0) are shared across
        # lanes and never donated; the resume executable's whole carry —
        # params (0), queues (1), rng (8), last-eval (16) — is arena-
        # owned chunk output and donates between segments.
        if resume:
            donate = (0, 1, 8, 16) if self.engine.donate else ()
        else:
            donate = (1,) if self.engine.donate else ()
        fn = jax.jit(batched, donate_argnums=donate)
        self._fns[key] = fn
        return fn

    @staticmethod
    def _carry_tree(carry: tuple) -> dict:
        """(params, queues, extras) chunk carry as a named flat-ish dict
        — the checkpoint wire format (stable names, so a restored file's
        structure is reconstructable from the service's own config)."""
        params, queues, extras = carry
        tree = {"params": params, "queues": queues, "rng": extras[0]}
        if len(extras) > 1:
            tree["last_ev"] = extras[1]
        return tree

    @staticmethod
    def _carry_from_tree(tree: dict) -> tuple:
        extras = ((tree["rng"], tree["last_ev"]) if "last_ev" in tree
                  else (tree["rng"],))
        return tree["params"], tree["queues"], extras

    def _chunk_tag(self, grid: ScenarioGrid, sp, k_max, tier_subset,
                   eval_every, num_rounds, chunk, h_digest) -> str:
        """Filename-safe content tag of one group's chunked execution —
        a pure function of everything that shapes the trajectory, so a
        restarted process resuming the same submission recomputes the
        same tag (and a different grid/chunking can never collide)."""
        hasher = hashlib.sha1()
        hasher.update(self._grid_digest(grid, (
            "chunk", k_max, tier_subset, int(eval_every or 0),
            num_rounds, chunk, self.batch, self._shards(),
            np.asarray(sp.energy_budget, np.float32).tobytes(),
            h_digest)))
        return "chunk_" + hasher.hexdigest()[:20]

    def _run_group(self, global_params: PyTree, sp: sm.SystemParams,
                   bank, grid: ScenarioGrid, h_all, lr_seq,
                   k_max: Optional[int] = None, eval_bank=None,
                   eval_every=None, tier_subset=None,
                   warm_aot: bool = False,
                   chunk_size: Optional[int] = None, chunk_store=None,
                   h_digest=None, drop_all=None):
        """One K group (uniform K, or a padded mixed-K grid when
        ``k_max`` is given) as one jitted program — or, with
        ``chunk_size``, as a pipeline of carry-donated scan segments.
        Returns ``(params, queues, metrics, executables_built,
        dispatches)`` with metrics as HOST arrays in the group's grid
        order.  ``tier_subset`` builds (and caches) the executable
        against a static subset of a tiered bank's ladder — the dispatch
        planner's scan-skip lever; the caller guarantees the group's
        lanes never select outside it.  ``warm_aot=True`` AOT-lowers and
        compiles the executable(s) instead of running (results come back
        None) — only useful where :func:`aot_cache_warmup_supported`
        says the jit call cache is populated by it.

        The chunked pipeline: chunk 0 runs the START executable (the
        monolithic program at segment length — the initial in-scan eval
        stays unbatched, see ``_build_group_fn``), later chunks run the
        RESUME executable with the previous segment's (params, queues,
        rng, last-eval) carry donated in and the global round offset
        ``t0`` traced.  Host reduction of chunk c's metric columns
        overlaps chunk c+1's device execution: jax dispatch is async, so
        the only blocking point is the ``np.asarray`` on a chunk that
        has had a full segment of device time to finish — bounded by the
        ``self.in_flight`` dispatched-but-unreduced window, never a
        ``block_until_ready`` between chunks.  ``chunk_store`` (the
        sweep service's checkpoint protocol: ``.load(tag)``,
        ``.save(tag, t_next, carry, metrics)``, ``.finish(tag)``,
        ``.every``) persists the carry + reduced columns at chunk
        boundaries and resumes a half-finished group bit-identically."""
        if k_max is None:
            k_max = int(grid.sample_count[0])
        sp_k = dataclasses.replace(sp, sample_count=k_max)
        use_dropout = drop_all is not None
        if use_dropout:
            drop_all = jnp.asarray(drop_all, jnp.float32)
        round_fn, data, bank_key = self.engine._scan_plan(bank,
                                                          tier_subset)
        ek = self._eval_key(eval_bank, eval_every)
        key = (bank_key, k_max, self._shards(), ek, use_dropout)
        built = 0
        fn = self._fns.get(key)
        if fn is None:
            with obs.span("arena.compile", stage="build", resume=False,
                          k_max=int(k_max), key=repr(key)):
                fn = self._build_group_fn(key, k_max, round_fn,
                                          eval_bank, eval_every,
                                          use_dropout=use_dropout)
            built += 1
        s = len(grid)
        if s % self._shards():
            raise ValueError(
                f"scenario count {s} not divisible by mesh axis "
                f"{self.mesh_axis!r} size {self._shards()} (per-K group "
                f"sizes must split evenly across shards)")
        lane = self._lane_inputs(grid, sp)
        n = sp.num_devices
        eval_data = None if ek is None else eval_bank.device_args()
        h_all = jnp.asarray(h_all, jnp.float32)
        lr_dev = self._lr_device(lr_seq)
        num_rounds = int(h_all.shape[1])

        def start_args(h_seg, d_seg, lr_seg, q0):
            # V/lam — and each lane's true K — are the materialized
            # [S, N] cached device constants (_build_scan's bitwise
            # contract); the queue carry is donated, so it is allocated
            # per run and no cached buffer ever flows into argnum 1
            return (global_params, q0, sp_k, lane["eb"], data, h_seg,
                    d_seg, lr_seg, lane["roll_keys"], lane["V"],
                    lane["lam"], lane["cid"], lane["kvec"],
                    lane["k_act"], eval_data, jnp.int32(0), None)

        if chunk_size is None and chunk_store is None:
            # classic monolithic scan: one executable, one dispatch
            args = start_args(h_all, drop_all, lr_dev,
                              jnp.zeros((s, n), jnp.float32))
            if warm_aot:
                with obs.span("arena.compile", stage="aot",
                              k_max=int(k_max), lanes=s,
                              rounds=num_rounds):
                    fn.lower(*args).compile()
                return None, None, None, built, 0
            with obs.span("arena.dispatch", k_max=int(k_max), lanes=s,
                          rounds=num_rounds, cold=bool(built)):
                params, queues, _, outs = fn(*args)
            with obs.span("arena.reduce", k_max=int(k_max), lanes=s,
                          rounds=num_rounds):
                metrics = {name: np.asarray(v)
                           for name, v in outs.items()}
            return params, queues, metrics, built, 1

        chunk = (num_rounds if chunk_size is None
                 else max(1, int(chunk_size)))
        resume_key = key + ("resume",)
        tag, t_start, carry, reduced = None, 0, None, []
        if chunk_store is not None:
            tag = self._chunk_tag(grid, sp, k_max, tier_subset,
                                  eval_every, num_rounds, chunk,
                                  h_digest)
            hit = chunk_store.load(tag)
            if hit is not None:
                t_start, carry_np, prefix = hit
                carry = self._carry_from_tree(jax.tree_util.tree_map(
                    jnp.asarray, carry_np))
                reduced.append(dict(prefix))
        segments = [(t0, min(chunk, num_rounds - t0))
                    for t0 in range(t_start, num_rounds, chunk)]
        rfn = self._fns.get(resume_key)
        need_resume = len(segments) > (1 if carry is None else 0)
        if need_resume and rfn is None:
            with obs.span("arena.compile", stage="build", resume=True,
                          k_max=int(k_max), key=repr(resume_key)):
                rfn = self._build_group_fn(resume_key, k_max, round_fn,
                                           eval_bank, eval_every,
                                           resume=True,
                                           use_dropout=use_dropout)
            built += 1

        def drop_seg(t0, ln):
            return (None if drop_all is None
                    else drop_all[:, t0:t0 + ln])

        def resume_args(c, h_seg, d_seg, lr_seg, t0):
            c_params, c_queues, c_extras = c
            c_ev = c_extras[1] if len(c_extras) > 1 else None
            return (c_params, c_queues, sp_k, lane["eb"], data, h_seg,
                    d_seg, lr_seg, c_extras[0], lane["V"], lane["lam"],
                    lane["cid"], lane["kvec"], lane["k_act"], eval_data,
                    jnp.int32(t0), c_ev)

        if warm_aot:
            # compile every segment shape the chunked run will hit: the
            # start executable at the first segment length, the resume
            # executable at each distinct later length (the ragged tail
            # is a second shape) — carry shapes come from structs, no
            # execution
            seen = set()
            p_struct = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(
                    (s,) + tuple(np.shape(a)), np.asarray(a).dtype
                    if not hasattr(a, "dtype") else a.dtype),
                global_params)
            q_struct = jax.ShapeDtypeStruct((s, n), jnp.float32)
            rng_struct = jax.ShapeDtypeStruct((s, 2), jnp.uint32)
            extras_struct = ((rng_struct,) if ek is None else
                             (rng_struct, eval_bank.carry_struct(
                                 global_params, s)))
            for i, (t0, ln) in enumerate(segments):
                h_seg, lr_seg = h_all[:, t0:t0 + ln], lr_dev[t0:t0 + ln]
                first = i == 0 and carry is None and t_start == 0
                which = ("start" if first else "resume", ln)
                if which in seen:
                    continue
                seen.add(which)
                if first:
                    with obs.span("arena.compile", stage="aot",
                                  which="start", k_max=int(k_max),
                                  lanes=s, rounds=int(ln)):
                        fn.lower(*start_args(
                            h_seg, drop_seg(t0, ln), lr_seg,
                            q_struct)).compile()
                else:
                    with obs.span("arena.compile", stage="aot",
                                  which="resume", k_max=int(k_max),
                                  lanes=s, rounds=int(ln)):
                        rfn.lower(*resume_args(
                            (p_struct, q_struct, extras_struct), h_seg,
                            drop_seg(t0, ln), lr_seg, t0)).compile()
            return None, None, None, built, 0

        # -- the pipeline: dispatch ahead, reduce behind -------------------
        # (device outs, segment length, chunk index)
        pending: List[Tuple[Any, int, int]] = []

        def reduce_oldest():
            outs_d, ln_r, idx = pending.pop(0)
            # np.asarray blocks only on THIS chunk's output buffers —
            # later chunks keep executing asynchronously (the span /
            # latency histogram therefore measure the honest stall: how
            # long the host waited for device work to catch up)
            t_red = time.perf_counter()
            with obs.span("arena.reduce", chunk=idx, rounds=int(ln_r),
                          k_max=int(k_max), lanes=s):
                reduced.append({name: np.asarray(v)
                                for name, v in outs_d.items()})
            self.metrics.histogram("arena.chunk.reduce_s").observe(
                time.perf_counter() - t_red)

        dispatches = 0
        for i, (t0, ln) in enumerate(segments):
            while len(pending) >= self.in_flight:
                reduce_oldest()
            h_seg, lr_seg = h_all[:, t0:t0 + ln], lr_dev[t0:t0 + ln]
            t_disp = time.perf_counter()
            with obs.span("arena.dispatch", chunk=i, t0=int(t0),
                          rounds=int(ln), k_max=int(k_max), lanes=s):
                if carry is None and i == 0 and t_start == 0:
                    q0 = jnp.zeros((s, n), jnp.float32)
                    params, queues, extras, outs = fn(
                        *start_args(h_seg, drop_seg(t0, ln), lr_seg,
                                    q0))
                else:
                    params, queues, extras, outs = rfn(
                        *resume_args(carry, h_seg, drop_seg(t0, ln),
                                     lr_seg, t0))
            self.metrics.histogram("arena.chunk.dispatch_s").observe(
                time.perf_counter() - t_disp)
            dispatches += 1
            carry = (params, queues, extras)
            pending.append((outs, ln, i))
            last = i == len(segments) - 1
            if (chunk_store is not None and not last and
                    (i + 1) % max(1, getattr(chunk_store, "every", 1))
                    == 0):
                # checkpoint: drain the pipeline (metrics must cover
                # exactly [0, t0+ln)), snapshot the carry to host, and
                # hand both to the store BEFORE the next dispatch can
                # donate the carry buffers away
                while pending:
                    reduce_oldest()
                carry_np = jax.tree_util.tree_map(np.asarray, carry)
                chunk_store.save(
                    tag, t0 + ln, self._carry_tree(carry_np),
                    concat_chunk_metrics(reduced))
        while pending:
            reduce_oldest()
        metrics = concat_chunk_metrics(reduced)
        if chunk_store is not None:
            chunk_store.finish(tag)
        params, queues, _ = carry
        return params, queues, metrics, built, dispatches

    # -- shape-adaptive dispatch planning -----------------------------------

    def _tier_work(self, bank) -> Dict[int, float]:
        """``{tier id: bucket rows per padded slot per round}`` — the
        cost model's work weights: local epochs x steps/epoch x batch
        rows, per tier of the ladder (a single bank is tier 0)."""
        banks = (bank.tiers if hasattr(bank, "tiers") else [bank])
        epochs = float(self.engine.cfg.local_epochs)
        return {t: epochs * b.steps_per_epoch * b.batch_size
                for t, b in enumerate(banks)}

    def _probe_footprints(self, sp, bank, grid: ScenarioGrid, h_all,
                          num_rounds: int) -> list:
        """Per-lane tier footprints, replayed WITHOUT training: the
        scan's selections depend only on the control plane — queues
        evolve from the decide outputs, the rng carry evolves by
        ``split`` alone, slot draws are prefix-stable ``fold_in`` —
        never on the model, so a probe scan whose round_fn is a no-op
        reproduces every lane's exact selection trace at control-plane
        cost (the same determinism the lane-equivalence tests pin
        down).  Probe executables are cached per (K_max, batch mode);
        probe RESULTS are cached by content hash of the inputs that
        shape selections, so steady-state re-runs of one grid replan
        from memory."""
        s, n = len(grid), sp.num_devices
        k_max = int(grid.sample_count.max())
        eb_base = np.asarray(sp.energy_budget, np.float32)
        h_np = np.asarray(h_all, np.float32)
        hasher = hashlib.sha1()
        for part in (h_np, grid.controller, grid.seed, grid.V, grid.lam,
                     grid.energy_scale, grid.sample_count, eb_base):
            hasher.update(np.ascontiguousarray(part).tobytes())
        hasher.update(str((k_max, n, num_rounds)).encode())
        cache_key = hasher.digest()
        hit = self._footprint_cache.get(cache_key)
        if hit is not None:
            return hit

        pk = (k_max, self.batch)
        fn = self._probe_fns.get(pk)
        if fn is None:
            def decide(sp_run, h, queues, V, lam, cid, kvec):
                return pol.decide_by_id(cid, sp_run, h, queues, V, lam,
                                        k=kvec)

            def select(sp_run, t, h, queues, q, skey, slots, kvec, cid):
                return pol.select_by_id(cid, sp_run, t, h, queues, q,
                                        skey, slots, kvec)

            def noop_round(params, data, selected, coeffs, lr, rngs):
                return params, jnp.zeros(selected.shape, jnp.float32)

            inner = self.engine._build_scan(k_max, decide, noop_round,
                                            select_fn=select)
            if self.batch == "vmap":
                batched = jax.vmap(inner,
                                   in_axes=(None, 0, None, 0, None, 0,
                                            None, None, 0, 0, 0, 0, 0,
                                            0, None, None, None))
            else:
                def batched(params, queues, sp_run, eb, data, h_seq,
                            drop_seq, lr_seq, rng, V, lam, cid, kvec,
                            k_act, eval_data, t0, last_ev):
                    def one(lane):
                        (q0, eb_s, h_s, rng_s, V_s, lam_s, cid_s, kv_s,
                         ka_s) = lane
                        return inner(params, q0, sp_run, eb_s, data,
                                     h_s, drop_seq, lr_seq, rng_s, V_s,
                                     lam_s, cid_s, kv_s, ka_s,
                                     eval_data, t0, last_ev)
                    return jax.lax.map(one, (queues, eb, h_seq, rng, V,
                                             lam, cid, kvec, k_act))
            fn = self._probe_fns[pk] = jax.jit(batched)
        _, roll_keys = scenario_keys(grid)
        eb = eb_base[None, :] * grid.energy_scale[:, None]
        sp_k = dataclasses.replace(sp, sample_count=k_max)
        probe_span = obs.span("arena.probe", lanes=s, k_max=k_max,
                              rounds=num_rounds)
        probe_span.__enter__()
        _, _, _, outs = fn(
            jnp.zeros((1,)), jnp.zeros((s, n), jnp.float32), sp_k,
            jnp.asarray(eb), None, jnp.asarray(h_np), None,
            jnp.zeros((num_rounds,), jnp.float32), roll_keys,
            jnp.asarray(np.broadcast_to(grid.V[:, None], (s, n))),
            jnp.asarray(np.broadcast_to(grid.lam[:, None], (s, n))),
            jnp.asarray(grid.controller),
            jnp.asarray(np.broadcast_to(
                grid.sample_count[:, None].astype(np.float32), (s, n))),
            jnp.asarray(grid.sample_count, jnp.int32), None,
            jnp.int32(0), None)
        fps = lane_footprints(np.asarray(outs["selected"]),
                              np.asarray(bank.tier_of))
        probe_span.__exit__(None, None, None)
        self._footprint_cache[cache_key] = fps
        return fps

    def _plan(self, sp, bank, grid: ScenarioGrid, num_rounds: int,
              h_all, *, runs: float, eval_key,
              use_dropout: bool = False) -> DispatchPlan:
        """The ``k_mode='auto'`` plan for this grid at the given reuse
        horizon (``runs=1`` for a cold :meth:`run`, ``math.inf`` for
        :meth:`warmup`'s steady state).  The cost model sees the arena's
        executable cache through ``is_cached``, so a warmed arena's
        plans snap to the already-compiled buckets."""
        multi_tier = hasattr(bank, "tiers") and bank.num_tiers > 1
        footprints = (self._probe_footprints(sp, bank, grid, h_all,
                                             num_rounds)
                      if multi_tier else None)

        def is_cached(bucket) -> bool:
            bk = bank_layout_key(bank, bucket.tiers)
            return (bk, bucket.k_pad, self._shards(),
                    eval_key, use_dropout) in self._fns

        return plan_dispatch(
            grid.sample_count, rounds=num_rounds,
            tier_work=self._tier_work(bank), footprints=footprints,
            cost_model=self.cost_model,
            max_executables=self.max_executables, is_cached=is_cached,
            runs=runs)

    def _run_plan(self, global_params: PyTree, sp, bank,
                  grid: ScenarioGrid, h_all, lr_seq,
                  plan: DispatchPlan, eval_bank=None, eval_every=None,
                  warm_aot: bool = False,
                  chunk_size: Optional[int] = None, chunk_store=None,
                  h_digest=None, drop_all=None):
        """Execute (or, with ``warm_aot``, AOT-compile) every bucket of
        ``plan`` and stitch the lanes back to grid order.  Params are
        stitched on DEVICE — one ``concatenate`` + one ``take`` per
        leaf — instead of the legacy grouped path's per-lane slice/
        re-stack (O(S x leaves) dispatches); metrics/queues are host
        arrays and concatenate there.  ``chunk_size``/``chunk_store``
        run each bucket through the chunked pipeline (each bucket
        checkpoints under its own content tag, so multi-bucket plans
        resume per bucket).  Returns ``(params, queues, metrics,
        built_total, bucket_meta)`` with everything but ``bucket_meta``
        None under ``warm_aot``."""
        k_max = int(grid.sample_count.max())
        chunks = []
        built_total = 0
        bucket_meta = []
        for b in plan.buckets:
            idx = np.asarray(b.lanes, np.int64)
            params_g, queues_g, outs_g, built, nd = self._run_group(
                global_params, sp, bank, grid.take(idx),
                h_all[jnp.asarray(idx)], lr_seq, k_max=b.k_pad,
                eval_bank=eval_bank, eval_every=eval_every,
                tier_subset=b.tiers, warm_aot=warm_aot,
                chunk_size=chunk_size, chunk_store=chunk_store,
                h_digest=h_digest,
                drop_all=(None if drop_all is None
                          else drop_all[jnp.asarray(idx)]))
            built_total += int(built)
            bucket_meta.append(dict(
                lanes=[int(i) for i in b.lanes], k_pad=int(b.k_pad),
                tiers=None if b.tiers is None else list(b.tiers),
                dispatches=int(nd),
                executables_built=int(built)))
            chunks.append((params_g, queues_g, outs_g))
        if warm_aot:
            return None, None, None, built_total, bucket_meta
        if plan.num_buckets == 1:
            # single bucket = the padded fast path: lanes already in
            # grid order, no permutation or concatenation needed
            params_g, queues_g, outs_g = chunks[0]
            return (params_g, np.asarray(queues_g), dict(outs_g),
                    built_total, bucket_meta)
        inv = plan.inverse_permutation()
        inv_dev = jnp.asarray(inv)
        params = jax.tree_util.tree_map(
            lambda *ls: jnp.take(jnp.concatenate(ls, axis=0), inv_dev,
                                 axis=0), *[c[0] for c in chunks])
        queues = np.concatenate([np.asarray(c[1]) for c in chunks],
                                axis=0)[inv]
        metrics: Dict[str, np.ndarray] = {}
        for name in chunks[0][2]:
            parts = []
            for _, _, outs_g in chunks:
                v = np.asarray(outs_g[name])
                if name == "selected" and v.shape[-1] < k_max:
                    pad = np.full(v.shape[:-1] + (k_max - v.shape[-1],),
                                  -1, v.dtype)
                    v = np.concatenate([v, pad], axis=-1)
                parts.append(v)
            metrics[name] = np.concatenate(parts, axis=0)[inv]
        return params, queues, metrics, built_total, bucket_meta

    def run(self, global_params: PyTree, sp: sm.SystemParams, bank,
            grid: ScenarioGrid, num_rounds: int, lr_seq,
            *, h_all: Optional[jax.Array] = None,
            drop_all: Optional[jax.Array] = None, eval_bank=None,
            eval_every: Optional[int] = None,
            chunk_size: Optional[int] = None,
            chunk_store=None) -> RolloutReport:
        """Instrumented entry point — see :meth:`_run_impl` for the
        full execution contract.  Opens the top-level ``arena.run``
        span, folds the run's meta into the shared metrics registry
        (``arena.runs`` / ``arena.dispatches`` /
        ``arena.executables_built`` cumulative counters — the per-run
        deltas stay in ``RolloutReport.meta``), and reports to the
        attached :class:`~repro.obs.watchdog.Watchdog` (which, post-
        warmup, turns any new trace or executable into a violation)."""
        run_span = obs.span("arena.run", k_mode=self.k_mode,
                            lanes=len(grid), rounds=int(num_rounds))
        with run_span:
            report = self._run_impl(
                global_params, sp, bank, grid, num_rounds, lr_seq,
                h_all=h_all, drop_all=drop_all, eval_bank=eval_bank,
                eval_every=eval_every, chunk_size=chunk_size,
                chunk_store=chunk_store)
            run_span.set(
                dispatches=int(report.meta.get("dispatches", 0)),
                executables_built=int(
                    report.meta.get("executables_built", 0)))
        self._record_run_meta(report.meta)
        if self.watchdog is not None:
            self.watchdog.observe_run(self, report.meta)
        return report

    def _record_run_meta(self, meta: dict) -> None:
        """Fold one run's meta deltas into the cumulative registry (the
        additive per-bucket contract itself stays cross-checked by
        ``RolloutReport.dispatch_accounting``)."""
        m = self.metrics
        m.counter("arena.runs").inc()
        m.counter("arena.dispatches").inc(int(meta.get("dispatches", 0)))
        m.counter("arena.executables_built").inc(
            int(meta.get("executables_built", 0)))
        m.gauge("arena.executables_cached").set(len(self._fns))

    def _run_impl(self, global_params: PyTree, sp: sm.SystemParams, bank,
            grid: ScenarioGrid, num_rounds: int, lr_seq,
            *, h_all: Optional[jax.Array] = None,
            drop_all: Optional[jax.Array] = None, eval_bank=None,
            eval_every: Optional[int] = None,
            chunk_size: Optional[int] = None,
            chunk_store=None) -> RolloutReport:
        """(The uninstrumented body of :meth:`run`.)  Execute every scenario of ``grid`` for ``num_rounds`` rounds.

        ``global_params``: the shared initial model (broadcast to every
        lane, never donated).  ``sp``: base SystemParams — each lane
        overrides ``energy_budget`` (scaled) and ``sample_count`` from
        the grid.  ``bank``: the shared read-only ClientBank (single or
        tiered).  ``lr_seq``: ``[T]`` learning rates shared across
        scenarios.  ``h_all``: optional precomputed ``[S, T, N]`` channel
        tensor (defaults to :meth:`sample_channels` from the grid's
        seeds/statistics — stationary or Gilbert-Elliott per the grid's
        ``chan_mode`` column).  ``drop_all``: optional precomputed
        ``[S, T, N]`` alive mask (defaults to :meth:`sample_dropout`
        when any lane has ``dropout > 0``; an all-zero dropout column
        builds the exact historical no-dropout executable).

        ``eval_bank``: optional :class:`repro.sim.eval.EvalBank` — the
        final ``[S, ...]`` params are evaluated in ONE vmapped dispatch
        and land as ``test_*`` columns in ``RolloutReport.final_metrics``
        (closing the accuracy half of the Sec.-VII trade-off on device).
        ``eval_every``: additionally evaluate INSIDE the rollout
        executable every that many rounds (``test_*`` per-round columns
        in ``metrics`` — a step curve holding the latest evaluation; the
        model trajectory is unchanged).

        ``chunk_size`` (defaulting to the arena's ``chunk_size``)
        switches every group onto the streaming pipeline: the T-round
        scan becomes ``ceil(T / chunk_size)`` segments whose (params,
        queues, rng, last-eval) carry is donated between chunks, with
        host reduction of each chunk's metric columns overlapped with
        the next chunk's device execution (a bounded ``in_flight``
        dispatch-ahead window — never a ``block_until_ready`` between
        chunks).  The chunked trajectory is bitwise-identical to the
        monolithic scan in every ``k_mode`` (the carry — including the
        per-round PRNG split chain and the EvalBank last-eval — threads
        across boundaries unchanged, and the traced global round offset
        keeps ``eval_every`` firing on the same rounds).  ``chunk_store``
        (see ``repro.sim.service``) additionally persists the carry +
        reduced columns at chunk boundaries so an interrupted run
        resumes bit-identically.

        A mixed-K grid runs as ONE padded-``K_max`` executable by
        default (``k_mode='pad'``; ``'group'`` restores one program per
        distinct K).  ``RolloutReport.meta`` records the execution shape
        — ``k_groups``, per-run ``dispatches``, ``executables_built``
        (compiles triggered by this call) and ``executables_cached`` —
        so callers can assert "one executable" instead of inferring it
        from timing.  Returns a :class:`RolloutReport`; lane ``s``
        reproduces — bit-identically for the model trajectory
        (params/loss/selected/wall_time, leaf-chunked aggregation path),
        to f32 resolution for the queue/energy diagnostics —::

            engine.run_scan(global_params,
                            grid.scenario_system_params(sp, s), bank,
                            h_all[s], lr_seq, rng=scenario_keys(grid)[1][s],
                            policy=grid.controller_names()[s],
                            V=grid.V[s], lam=grid.lam[s],
                            drop_seq=drop_all[s])  # when dropout is on
        """
        s = len(grid)
        # same invariant (and message) as construction-time validation —
        # one source of truth for K <= N
        ScenarioGrid._check_sample_counts(grid.sample_count,
                                          sp.num_devices)
        if eval_every is not None and eval_bank is None:
            raise ValueError("eval_every requires an eval_bank")
        lr_seq = np.asarray(lr_seq, np.float32)
        if lr_seq.shape != (num_rounds,):
            raise ValueError(f"lr_seq must have shape ({num_rounds},), "
                             f"got {lr_seq.shape}")
        h_derived = h_all is None
        if h_derived:
            h_all = self.sample_channels(grid, num_rounds, sp.num_devices)
        h_all = jnp.asarray(h_all)
        if h_all.shape != (s, num_rounds, sp.num_devices):
            raise ValueError(
                f"h_all must have shape {(s, num_rounds, sp.num_devices)},"
                f" got {h_all.shape}")
        if chunk_size is None:
            chunk_size = self.chunk_size
        h_digest = None
        if chunk_store is not None:
            # checkpoint tags must identify the trajectory across
            # processes: an arena-derived channel tensor is a pure
            # function of (grid, T, N) already in the tag; a caller-
            # provided one is hashed by content (one host transfer, paid
            # only when checkpointing)
            h_digest = ("auto" if h_derived else hashlib.sha1(
                np.ascontiguousarray(np.asarray(h_all, np.float32))
                .tobytes()).hexdigest())

        if drop_all is None and np.any(np.asarray(grid.dropout) > 0.0):
            drop_all = self.sample_dropout(grid, num_rounds,
                                           sp.num_devices)
        if drop_all is not None:
            drop_all = jnp.asarray(drop_all, jnp.float32)
            if drop_all.shape != (s, num_rounds, sp.num_devices):
                raise ValueError(
                    "drop_all must have shape "
                    f"{(s, num_rounds, sp.num_devices)}, "
                    f"got {drop_all.shape}")

        ks = np.unique(grid.sample_count)
        k_max = int(ks.max())
        bank_nbytes = getattr(bank, "nbytes", None)
        meta = dict(k_mode=self.k_mode, k_groups=[int(k) for k in ks],
                    k_max=k_max, batch=self.batch, shards=self._shards(),
                    chunk_size=(None if chunk_size is None
                                else int(chunk_size)),
                    in_flight=self.in_flight,
                    # scale-plane accounting: the memory claim as a
                    # tracked number on every report (None for duck-typed
                    # banks predating it)
                    bank_storage=getattr(bank, "storage", "fp32"),
                    bank_nbytes=(None if bank_nbytes is None
                                 else int(bank_nbytes)),
                    bank_bytes_per_client=getattr(bank, "bytes_per_client",
                                                  None))
        if self.k_mode == "auto":
            # shape-adaptive dispatch: plan at the ONE-run horizon — a
            # cold arena collapses toward the padded single bucket, a
            # warmed arena's cached steady buckets win through is_cached
            with obs.span("arena.plan", k_mode="auto", lanes=s,
                          k_max=k_max):
                plan = self._plan(sp, bank, grid, num_rounds, h_all,
                                  runs=1.0,
                                  eval_key=self._eval_key(eval_bank,
                                                          eval_every),
                                  use_dropout=drop_all is not None)
            params, queues, metrics, built, bucket_meta = self._run_plan(
                global_params, sp, bank, grid, h_all, lr_seq, plan,
                eval_bank=eval_bank, eval_every=eval_every,
                chunk_size=chunk_size, chunk_store=chunk_store,
                h_digest=h_digest, drop_all=drop_all)
            meta.update(dispatches=sum(b["dispatches"]
                                       for b in bucket_meta),
                        executables_built=built,
                        executables_cached=len(self._fns),
                        plan=plan.describe(), buckets=bucket_meta)
            return RolloutReport(
                grid=grid, num_rounds=num_rounds, params=params,
                queues=queues, metrics=metrics, meta=meta,
                final_metrics=self._final_eval(eval_bank, params))
        if self.k_mode == "pad" or ks.size == 1:
            # padded-K fusion: the whole grid — mixed K included — is ONE
            # executable (K_max slots per lane, each lane's true K traced
            # as data) and one dispatch per rollout chunk
            with obs.span("arena.plan", k_mode="pad", lanes=s,
                          k_max=k_max):
                plan = DispatchPlan.padded(grid.sample_count)
            params, queues, metrics, built, nd = self._run_group(
                global_params, sp, bank, grid, h_all, lr_seq,
                k_max=k_max, eval_bank=eval_bank, eval_every=eval_every,
                chunk_size=chunk_size, chunk_store=chunk_store,
                h_digest=h_digest, drop_all=drop_all)
            meta.update(dispatches=int(nd), executables_built=int(built),
                        executables_cached=len(self._fns),
                        plan=plan.describe(),
                        buckets=[dict(lanes=list(range(s)), k_pad=k_max,
                                      tiers=None, dispatches=int(nd),
                                      executables_built=int(built))])
            return RolloutReport(
                grid=grid, num_rounds=num_rounds, params=params,
                queues=np.asarray(queues), metrics=metrics, meta=meta,
                final_metrics=self._final_eval(eval_bank, params))
        # Legacy mixed-K grouping: K shapes the per-round selection, so
        # each distinct K runs as its own jitted group and the lanes are
        # scattered back into grid order ("selected" right-pads to max K).
        with obs.span("arena.plan", k_mode="group", lanes=s,
                      k_max=k_max):
            plan = DispatchPlan.grouped(grid.sample_count)
        lane_params = [None] * s
        queues_all = np.zeros((s, sp.num_devices), np.float32)
        metrics: Dict[str, np.ndarray] = {}
        built_total = 0
        nd_total = 0
        bucket_meta = []
        for k in ks:
            idx = np.flatnonzero(grid.sample_count == k)
            sub = grid.take(idx)
            params_g, queues_g, outs_g, built, nd = self._run_group(
                global_params, sp, bank, sub, h_all[jnp.asarray(idx)],
                lr_seq, eval_bank=eval_bank, eval_every=eval_every,
                chunk_size=chunk_size, chunk_store=chunk_store,
                h_digest=h_digest,
                drop_all=(None if drop_all is None
                          else drop_all[jnp.asarray(idx)]))
            built_total += int(built)
            nd_total += int(nd)
            bucket_meta.append(dict(
                lanes=[int(i) for i in idx], k_pad=int(k), tiers=None,
                dispatches=int(nd), executables_built=int(built)))
            queues_all[idx] = np.asarray(queues_g)
            for j, lane in enumerate(idx):
                lane_params[lane] = jax.tree_util.tree_map(
                    lambda a, j=j: a[j], params_g)
            for name, v in outs_g.items():
                v = np.asarray(v)
                if name == "selected" and v.shape[-1] < k_max:
                    pad = np.full(v.shape[:-1] + (k_max - v.shape[-1],),
                                  -1, v.dtype)
                    v = np.concatenate([v, pad], axis=-1)
                if name not in metrics:
                    metrics[name] = np.zeros((s,) + v.shape[1:], v.dtype)
                metrics[name][idx] = v
        params = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                        *lane_params)
        meta.update(dispatches=nd_total,
                    executables_built=built_total,
                    executables_cached=len(self._fns),
                    plan=plan.describe(),
                    buckets=bucket_meta)
        return RolloutReport(grid=grid, num_rounds=num_rounds,
                             params=params, queues=queues_all,
                             metrics=metrics, meta=meta,
                             final_metrics=self._final_eval(eval_bank,
                                                            params))

    def _final_eval(self, eval_bank, params_stacked) -> Dict[str, Any]:
        """One vmapped ``task.metrics`` dispatch over the final ``[S,
        ...]`` params — the batched replacement for the host-side
        per-lane evaluation loop."""
        if eval_bank is None:
            return {}
        with obs.span("arena.eval", what="final"):
            return {"test_" + name: v for name, v in
                    eval_bank.evaluate_stacked(params_stacked).items()}

    def warmup(self, global_params: PyTree, sp: sm.SystemParams, bank,
               grid: ScenarioGrid, num_rounds: int,
               lr_seq=None, *, h_all: Optional[jax.Array] = None,
               eval_bank=None, eval_every: Optional[int] = None,
               aot: Optional[bool] = None,
               chunk_size: Optional[int] = None) -> dict:
        """Compile EVERY executable a same-shape :meth:`run` will hit,
        so iterating on grid VALUES (the V/lam/seed/channel sweep
        workflow — shapes fixed, data varying) never traces or compiles
        again.  The warmed set is a full :class:`DispatchPlan` per the
        arena's ``k_mode`` — the padded single bucket, every per-K
        group, or (``'auto'``) the STEADY-STATE plan (``runs=inf``
        horizon: the signature-split buckets a warmed arena's runs
        snap to via the cache-aware cost model), each bucket warmed
        individually.

        ``aot`` picks how: ``True`` forces AOT
        ``jit(...).lower(...).compile()`` per bucket (no paid real
        execution), ``False`` forces one real discarded run, ``None``
        (default) asks :func:`aot_cache_warmup_supported` whether this
        jax populates the jit call cache from AOT — falling back
        cleanly to the executed path otherwise (jax 0.4.x re-traces on
        call, so AOT warming there would compile everything twice).
        Nothing observable changes — the arena holds no rollout state,
        the bank is read-only, params are never donated.  Returns
        ``{'executables_built', 'executables_cached', 'traces', 'aot',
        'plan'}`` for the zero-retrace assertion; subsequent same-shape
        runs keep ``self.traces`` constant.

        ``chunk_size`` (defaulting to the arena's) additionally warms
        the streaming pipeline's executables: the start program at the
        first segment length plus the resume program at every distinct
        later segment length (a ragged tail is a second shape) — so a
        warmed chunked ``run`` keeps ``self.traces`` constant too.

        Warmup is also the :class:`~repro.obs.watchdog.Watchdog` arming
        point: an attached watchdog snapshots the trace counter and the
        executable-cache keys when warmup finishes, and every later
        :meth:`run` is checked against that baseline.
        """
        warm_span = obs.span("arena.warmup", k_mode=self.k_mode,
                             lanes=len(grid), rounds=int(num_rounds))
        warm_span.__enter__()
        before = self.traces
        if lr_seq is None:
            lr_seq = np.zeros(num_rounds, np.float32)
        if h_all is None:
            h_all = self.sample_channels(grid, num_rounds,
                                         sp.num_devices)
        h_all = jnp.asarray(h_all)
        drop_all = None
        if np.any(np.asarray(grid.dropout) > 0.0):
            drop_all = self.sample_dropout(grid, num_rounds,
                                           sp.num_devices)
        if chunk_size is None:
            chunk_size = self.chunk_size
        ek = self._eval_key(eval_bank, eval_every)
        if self.k_mode == "auto":
            plan = self._plan(sp, bank, grid, num_rounds, h_all,
                              runs=math.inf, eval_key=ek,
                              use_dropout=drop_all is not None)
        elif self.k_mode == "group":
            plan = DispatchPlan.grouped(grid.sample_count)
        else:
            plan = DispatchPlan.padded(grid.sample_count)
        use_aot = (bool(aot) if aot is not None
                   else aot_cache_warmup_supported())
        params, _, _, built, _ = self._run_plan(
            global_params, sp, bank, grid, h_all, lr_seq, plan,
            eval_bank=eval_bank, eval_every=eval_every,
            warm_aot=use_aot, chunk_size=chunk_size, drop_all=drop_all)
        if use_aot:
            if eval_bank is not None:
                eval_bank.aot_warm(len(grid), global_params)
        else:
            # executed path: block on (and discard) the real results,
            # and run the final batched evaluation so the EvalBank's
            # stacked executable is warmed too
            jax.block_until_ready(jax.tree_util.tree_leaves(params))
            self._final_eval(eval_bank, params)
        result = {"executables_built": built,
                  "executables_cached": len(self._fns),
                  "traces": self.traces - before,
                  "aot": use_aot, "plan": plan.describe()}
        warm_span.set(executables_built=int(built), aot=bool(use_aot))
        warm_span.__exit__(None, None, None)
        if self.watchdog is not None:
            self.watchdog.arm(self)
        return result
