"""SweepService — a continuous warmed sweep loop over the ScenarioArena.

The paper's controller runs ONLINE: decisions arrive round by round,
forever, not as one offline batch.  The arena gives the evaluation side
the same shape — PRs 5-6 made one warmed executable serve any same-shape
grid, and the streaming chunked pipeline (``Arena.run(chunk_size=...)``)
overlaps host reduction with device execution.  The service turns those
into a long-lived loop:

* **Submission queue.**  ``submit(grid, num_rounds, lr_seq)`` enqueues a
  :class:`repro.sim.ScenarioGrid` and returns a ticket; nothing executes
  until :meth:`process_once` / :meth:`run_pending` drains the queue.
* **Coalescing.**  Compatible pending submissions — same round count and
  learning-rate schedule (channels, seeds, V/lam/K are per-lane data
  anyway) — concatenate into ONE batched grid
  (:meth:`ScenarioGrid.concat`) up to ``max_lanes`` lanes, execute as a
  single arena program under the PR-6 dispatch planner, and split back
  per submission with :meth:`RolloutReport.take`.
* **Steady-state zero-upload.**  The arena's device-input caches hold
  each known grid's lane constants / channel tensor / lr schedule, so a
  repeated submission transfers nothing but the rollout carry the
  executable allocates itself.
* **Crash-safe checkpointing.**  With ``checkpoint_dir``, every chunk
  boundary (at ``checkpoint_every`` cadence) persists the (params,
  queues, rng, last-eval) carry and the reduced metric columns through
  ``repro.checkpoint`` (atomic npz + manifest, tmp + rename).  A killed
  service that resubmits the same grid resumes mid-rollout and finishes
  BIT-IDENTICALLY to an uninterrupted run: the checkpoint tag is a pure
  content hash of the trajectory-shaping inputs, the carry round-trips
  exact (f32/int/uint dtypes preserved), and the chunked scan is
  bitwise-stable across the save/restore boundary.

The service owns no training state of its own — params0/bank are shared
read-only — so one service instance can serve any number of grids.
"""

from __future__ import annotations

import dataclasses
import itertools
import socket
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import (checkpoint_exists, delete_checkpoint,
                              restore_arrays, restore_checkpoint,
                              save_checkpoint)
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.sim.arena import ScenarioGrid
from repro.sim.report import RolloutReport

PyTree = Any

#: carry-manifest wire-format version.  Bump when the chunk-carry tree
#: structure, dtypes, or the metrics-first/carry-second commit protocol
#: change incompatibly — a store then REFUSES to resume from the stale
#: file instead of mis-restoring it.
CHUNK_STORE_SCHEMA_VERSION = 1


class NpzChunkStore:
    """The arena's chunk-checkpoint protocol over ``repro.checkpoint``.

    One checkpoint pair per in-flight group tag: ``<tag>_metrics`` (the
    reduced ``[S, t, ...]`` columns so far — a flat dict, restored
    structure-free via ``restore_arrays``) and ``<tag>_carry`` (the
    chunk carry as the arena's named tree, restored through a ``like``
    tree the ``carry_like`` callback rebuilds from service config).
    Metrics save FIRST, carry second: the carry manifest's ``t`` is the
    commit point, and a crash between the two leaves a carry at an older
    ``t`` whose metrics prefix is simply trimmed — never a torn resume.
    ``every`` is the arena-side cadence: persist at every ``every``-th
    chunk boundary (1 = each boundary)."""

    def __init__(self, directory: str, carry_like, every: int = 1,
                 metrics: Optional[MetricsRegistry] = None):
        self.directory = directory
        self.carry_like = carry_like
        self.every = max(1, int(every))
        #: shared metrics registry (the owning service passes the
        #: arena's, so ``store.saves``/``store.loads`` land in the same
        #: namespace as everything else); standalone stores get their
        #: own
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    @property
    def saves(self) -> int:
        """Completed :meth:`save` calls (view over ``store.saves``)."""
        return self.metrics.counter("store.saves").value

    @property
    def loads(self) -> int:
        """Successful :meth:`load` hits (view over ``store.loads``)."""
        return self.metrics.counter("store.loads").value

    def load(self, tag: str):
        if not checkpoint_exists(self.directory, f"{tag}_carry"):
            return None
        with obs.span("store.load", tag=tag):
            _, md = restore_arrays(self.directory, f"{tag}_carry")
            found = int(md.get("schema_version", 0))
            if found != CHUNK_STORE_SCHEMA_VERSION:
                raise ValueError(
                    f"chunk checkpoint {tag!r} in {self.directory!r} "
                    f"was written with carry schema_version {found} "
                    f"(written by host {md.get('host', '?')!r}, jax "
                    f"{md.get('jax_version', '?')} at "
                    f"{md.get('saved_at', '?')}); this build expects "
                    f"schema_version {CHUNK_STORE_SCHEMA_VERSION} and "
                    f"refuses to resume from an incompatible carry — "
                    f"delete the stale checkpoint (or finish it with a "
                    f"matching build) and resubmit")
            carry, meta = restore_checkpoint(
                self.directory, f"{tag}_carry",
                like=self.carry_like(int(md["s"])))
            t = int(meta["t"])
            metrics, _ = restore_arrays(self.directory, f"{tag}_metrics")
            # a crash after the metrics save but before the carry save
            # leaves metrics AHEAD of the committed t — trim to the
            # carry's horizon (axis 1 is the round axis on every column)
            metrics = {k: v[:, :t] for k, v in metrics.items()}
        self.metrics.counter("store.loads").inc()
        return t, carry, metrics

    def save(self, tag: str, t_next: int, carry: dict,
             metrics: Dict[str, np.ndarray]) -> None:
        s = int(carry["queues"].shape[0])
        # the carry manifest doubles as provenance: which wire format,
        # which host/jax wrote it, when, and which trajectory (the tag
        # IS the content digest of everything that shapes it) — enough
        # to explain a refused resume without opening the npz
        md = {"t": int(t_next), "s": s,
              "schema_version": CHUNK_STORE_SCHEMA_VERSION,
              "host": socket.gethostname(),
              "jax_version": jax.__version__,
              "saved_at": time.strftime("%Y-%m-%dT%H:%M:%S",
                                        time.gmtime()) + "Z",
              "grid_digest": tag}
        with obs.span("store.save", tag=tag, t=int(t_next), lanes=s):
            save_checkpoint(self.directory, f"{tag}_metrics",
                            dict(metrics), metadata=md)
            save_checkpoint(self.directory, f"{tag}_carry", carry,
                            metadata=md)
        self.metrics.counter("store.saves").inc()

    def finish(self, tag: str) -> None:
        delete_checkpoint(self.directory, f"{tag}_carry")
        delete_checkpoint(self.directory, f"{tag}_metrics")


@dataclasses.dataclass
class _Submission:
    ticket: int
    grid: ScenarioGrid
    num_rounds: int
    lr_seq: np.ndarray


class SweepService:
    """A long-lived sweep loop owning a warmed :class:`repro.sim.Arena`.

    ``arena``/``params0``/``sp``/``bank`` are the shared execution
    substrate every submission runs on (``eval_bank``/``eval_every``
    optionally add the on-device evaluation plane).  ``chunk_size``
    selects the streaming pipeline for every execution (None = the
    arena's default); ``max_lanes`` caps how many lanes one coalesced
    batch may hold; ``checkpoint_dir`` + ``checkpoint_every`` enable the
    crash-safe chunk store (exposed as ``self.store`` — tests wrap its
    ``save`` to simulate kills).

    ``stats`` accumulates the throughput counters the streaming bench
    records: completed scenarios, batches, coalesced lane counts, and
    busy seconds (submit-to-drain wall time of :meth:`run_pending`).
    """

    def __init__(self, arena, params0: PyTree, sp, bank, *,
                 eval_bank=None, eval_every: Optional[int] = None,
                 chunk_size: Optional[int] = None, max_lanes: int = 16,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1):
        if eval_every is not None and eval_bank is None:
            raise ValueError("eval_every requires an eval_bank")
        self.arena = arena
        self.params0 = params0
        self.sp = sp
        self.bank = bank
        self.eval_bank = eval_bank
        self.eval_every = eval_every
        self.chunk_size = (chunk_size if chunk_size is not None
                           else arena.chunk_size)
        self.max_lanes = int(max_lanes)
        #: the arena's registry, shared — the service (and its chunk
        #: store) write ``service.*`` / ``store.*`` metrics into the
        #: same namespace as the arena's ``arena.*``, so ONE
        #: ``metrics.snapshot()`` captures the whole stack
        self.metrics = arena.metrics
        self.store = None
        if checkpoint_dir is not None:
            self.store = NpzChunkStore(checkpoint_dir, self._carry_like,
                                       every=checkpoint_every,
                                       metrics=self.metrics)
        self._queue: List[_Submission] = []
        self._results: Dict[int, RolloutReport] = {}
        self._tickets = itertools.count()

    @property
    def stats(self) -> Dict[str, Any]:
        """Throughput counters as a plain dict — now a VIEW over the
        shared metrics registry (``service.*`` names), kept for the
        streaming bench and tests: completed ``batches`` /
        ``scenarios``, the per-batch ``coalesced_lanes`` list, and busy
        ``seconds`` (submit-to-drain wall time of
        :meth:`run_pending`)."""
        m = self.metrics
        return {
            "batches": m.counter("service.batches").value,
            "scenarios": m.counter("service.scenarios").value,
            "coalesced_lanes": [
                int(v) for v in
                m.histogram("service.coalesced_lanes").values],
            "seconds": m.gauge("service.seconds").value,
        }

    # -- checkpoint structure -----------------------------------------------

    def _carry_like(self, s: int) -> dict:
        """The ``like`` tree a checkpointed chunk carry restores into —
        rebuilt from service config alone (params0 shapes, N, and the
        EvalBank's carry struct), so a FRESH process can restore a file
        it never wrote."""
        like = {
            "params": jax.tree_util.tree_map(
                lambda a: np.zeros((s,) + tuple(np.shape(a)),
                                   np.asarray(a).dtype), self.params0),
            "queues": np.zeros((s, self.sp.num_devices), np.float32),
            "rng": np.zeros((s, 2), np.uint32),
        }
        if self.eval_bank is not None and self.eval_every:
            like["last_ev"] = {
                name: np.zeros(st.shape, st.dtype)
                for name, st in self.eval_bank.carry_struct(
                    self.params0, s).items()}
        return like

    # -- the queue ----------------------------------------------------------

    def submit(self, grid: ScenarioGrid, num_rounds: int,
               lr_seq=None) -> int:
        """Enqueue a grid; returns a ticket for :meth:`result`."""
        ScenarioGrid._check_sample_counts(grid.sample_count,
                                          self.sp.num_devices)
        if lr_seq is None:
            lr_seq = np.zeros(num_rounds, np.float32)
        lr_seq = np.asarray(lr_seq, np.float32)
        if lr_seq.shape != (num_rounds,):
            raise ValueError(f"lr_seq must have shape ({num_rounds},), "
                             f"got {lr_seq.shape}")
        if len(grid) > self.max_lanes:
            raise ValueError(f"submission of {len(grid)} lanes exceeds "
                             f"max_lanes={self.max_lanes}")
        ticket = next(self._tickets)
        self._queue.append(_Submission(ticket, grid, num_rounds, lr_seq))
        self.metrics.gauge("service.queue_depth").set(len(self._queue))
        return ticket

    def pending(self) -> int:
        return len(self._queue)

    def _coalesce(self) -> List[_Submission]:
        """Pop the queue head plus every later submission compatible
        with it (same T and lr schedule) that still fits ``max_lanes``
        — FIFO order kept, incompatible submissions left queued."""
        head = self._queue.pop(0)
        batch = [head]
        lanes = len(head.grid)
        rest: List[_Submission] = []
        for sub in self._queue:
            if (sub.num_rounds == head.num_rounds and
                    np.array_equal(sub.lr_seq, head.lr_seq) and
                    lanes + len(sub.grid) <= self.max_lanes):
                batch.append(sub)
                lanes += len(sub.grid)
            else:
                rest.append(sub)
        self._queue = rest
        return batch

    # -- execution ----------------------------------------------------------

    def warmup(self, grid: ScenarioGrid, num_rounds: int,
               lr_seq=None) -> dict:
        """Warm the arena for this submission shape (chunked segment
        shapes included) — steady-state submissions then never trace."""
        return self.arena.warmup(self.params0, self.sp, self.bank, grid,
                                 num_rounds, lr_seq,
                                 eval_bank=self.eval_bank,
                                 eval_every=self.eval_every,
                                 chunk_size=self.chunk_size)

    def process_once(self) -> List[int]:
        """Execute ONE coalesced batch through the chunked pipeline;
        returns the completed tickets (empty when the queue is idle).
        Does not block on the batch's device work beyond what the
        pipeline's own reduction needs — the next batch's chunks can
        dispatch behind the previous batch's in-flight params."""
        if not self._queue:
            return []
        batch = self._coalesce()
        grid = (batch[0].grid if len(batch) == 1
                else ScenarioGrid.concat([b.grid for b in batch]))
        self.metrics.gauge("service.queue_depth").set(len(self._queue))
        t_start = time.perf_counter()
        with obs.span("service.batch", tickets=len(batch),
                      lanes=len(grid), rounds=int(batch[0].num_rounds),
                      queue_depth=len(self._queue)):
            rep = self.arena.run(
                self.params0, self.sp, self.bank, grid,
                batch[0].num_rounds, batch[0].lr_seq,
                eval_bank=self.eval_bank, eval_every=self.eval_every,
                chunk_size=self.chunk_size, chunk_store=self.store)
            offset = 0
            for sub in batch:
                n = len(sub.grid)
                self._results[sub.ticket] = (
                    rep if len(batch) == 1
                    else rep.take(np.arange(offset, offset + n)))
                offset += n
        m = self.metrics
        m.counter("service.batches").inc()
        m.counter("service.scenarios").inc(len(grid))
        m.histogram("service.coalesced_lanes").observe(len(grid))
        m.gauge("service.seconds").add(time.perf_counter() - t_start)
        return [b.ticket for b in batch]

    def run_pending(self) -> List[int]:
        """Drain the whole queue; returns every completed ticket.  The
        final block waits for the last batch's params so the service's
        throughput stats measure finished work, not queued dispatches."""
        done: List[int] = []
        while self._queue:
            done.extend(self.process_once())
        if done:
            t_block = time.perf_counter()
            with obs.span("service.reduce", tickets=len(done)):
                last = self._results[done[-1]]
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(last.params))
            self.metrics.gauge("service.seconds").add(
                time.perf_counter() - t_block)
        return done

    def result(self, ticket: int) -> RolloutReport:
        """The completed report for ``ticket`` (popped — each result is
        handed out once)."""
        if ticket not in self._results:
            raise KeyError(f"ticket {ticket} has no completed result "
                           f"(pending submissions: {self.pending()})")
        return self._results.pop(ticket)
