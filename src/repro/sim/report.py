"""RolloutReport — the structured result of a ScenarioArena sweep, plus
host-side reducers for the paper's Sec. VII trade-off figures.

The arena returns every scenario's rollout stacked on a leading scenario
axis: params ``[S, ...]``, final queues ``[S, N]``, and per-round metric
arrays ``[S, T]`` (``selected`` is ``[S, T, K]``, right-padded with -1
when the grid mixes sampling counts — padded-K lanes emit the -1s on
device).  With an ``EvalBank``, on-device test metrics land here too:
``final_metrics`` holds one batched-evaluation scalar per lane
(``test_accuracy`` / ``test_loss``, ``[S]``), and ``eval_every`` adds
``test_*`` per-round columns to ``metrics`` (a step curve holding the
latest in-scan evaluation).  ``meta`` records the execution shape —
``k_mode``, ``k_groups``, ``dispatches``, ``executables_built``, the
``plan`` (the ``repro.sim.dispatch.DispatchPlan`` the run executed,
JSON-shaped) and per-bucket ``buckets`` counters — so benches and tests
can assert "one executable" instead of inferring it from timing.  The
per-bucket counters are ADDITIVE: every execution mode (pad / group /
auto) emits one ``buckets`` entry per executable dispatched, and
:meth:`RolloutReport.dispatch_accounting` cross-checks that their sums
reproduce the run totals exactly.  The reducers below turn all of it into the curves the
paper plots — cumulative latency, loss/accuracy-vs-time, time-averaged
energy against the budget, queue-norm stability — and
:meth:`tradeoff_table` aggregates seeds so a (controller, V, lam, budget,
channel, K) grid collapses to one trade-off point per configuration,
exactly the comparison methodology of Figs. 1-6.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Any, Dict, List

import jax
import numpy as np

from repro.fl.environment import CHANNEL_MODES

PyTree = Any


def concat_chunk_metrics(chunks: List[Dict[str, np.ndarray]]
                         ) -> Dict[str, np.ndarray]:
    """Assemble per-chunk metric columns into full rollout columns.

    The streaming arena reduces each scan segment's outputs to host
    arrays as the next segment executes on device; every chunk
    contributes ``[S, t_c, ...]`` slices of the same metric set, and the
    full ``[S, T, ...]`` report columns are their concatenation along
    the round axis — the incremental counterpart of the monolithic
    ``np.asarray(outs)`` conversion, byte-for-byte identical because
    concatenation only places the already-exact per-chunk values."""
    if not chunks:
        raise ValueError("no metric chunks to assemble")
    if len(chunks) == 1:
        return dict(chunks[0])
    names = set(chunks[0])
    for c in chunks[1:]:
        if set(c) != names:
            raise ValueError(
                f"metric chunks disagree on columns: {sorted(names)} vs "
                f"{sorted(c)}")
    return {name: np.concatenate([c[name] for c in chunks], axis=1)
            for name in chunks[0]}


@dataclasses.dataclass
class RolloutReport:
    """Stacked results of ``Arena.run`` over an S-scenario grid."""

    grid: Any                      # the ScenarioGrid that produced this
    num_rounds: int
    params: PyTree                 # final params, leaves [S, ...]
    queues: np.ndarray             # final virtual queues [S, N]
    metrics: Dict[str, np.ndarray]  # [S, T] per-round ([S, T, K] selected)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    final_metrics: Dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)         # [S] batched final-params eval

    @property
    def num_scenarios(self) -> int:
        return len(self.grid)

    def scenario_params(self, s: int) -> PyTree:
        """Scenario ``s``'s final model (one lane of the stacked pytree)."""
        return jax.tree_util.tree_map(lambda a: a[s], self.params)

    def take(self, idx) -> "RolloutReport":
        """Sub-report of the given scenario indices (order kept) — the
        sweep service uses this to hand each coalesced submission its
        own lanes back.  Params slice on device (one gather per leaf);
        metrics/queues/final_metrics slice on host.  ``meta`` is
        DEEP-copied (the parent's nested plan / per-bucket counter
        lists must stay immune to mutation through the child, and vice
        versa) and marked with ``split_from``.  A take of ALL lanes in
        grid order keeps the per-bucket counters — accounting still
        describes the execution exactly; a true slice clears them (the
        counters describe the coalesced execution, not the slice, so
        :meth:`dispatch_accounting` is not meaningful there)."""
        idx = np.asarray(idx, np.int64)
        idx_dev = jax.numpy.asarray(idx)
        meta = copy.deepcopy(self.meta)
        meta["split_from"] = self.num_scenarios
        if not np.array_equal(idx, np.arange(self.num_scenarios)):
            meta["buckets"] = []
        return RolloutReport(
            grid=self.grid.take(idx), num_rounds=self.num_rounds,
            params=jax.tree_util.tree_map(
                lambda a: jax.numpy.take(a, idx_dev, axis=0), self.params),
            queues=np.asarray(self.queues)[idx],
            metrics={k: v[idx] for k, v in self.metrics.items()},
            meta=meta,
            final_metrics={k: np.asarray(v)[idx]
                           for k, v in self.final_metrics.items()})

    # -- per-scenario curves ([S, T]) ---------------------------------------

    def latency_curve(self) -> np.ndarray:
        """Cumulative realised wall-clock (eq. 10) per scenario, [S, T]."""
        return np.cumsum(self.metrics["wall_time"], axis=1)

    def loss_curve(self) -> np.ndarray:
        return self.metrics["loss"]

    def queue_norm_curve(self) -> np.ndarray:
        """||Q^t||_2 per round — the stability trace behind constraint
        (16); bounded iff the time-averaged energy meets the budget."""
        return self.metrics["queue_norm"]

    def accuracy_curve(self) -> np.ndarray:
        """On-device test accuracy per round, [S, T] — a step curve
        holding the latest in-scan evaluation.  Requires the arena run
        to have been given ``eval_bank`` + ``eval_every``."""
        if "test_accuracy" not in self.metrics:
            raise KeyError(
                "no in-scan test accuracy recorded — pass eval_bank= and "
                "eval_every= to Arena.run to evaluate inside the rollout")
        return self.metrics["test_accuracy"]

    # -- per-scenario scalars ([S]) -----------------------------------------

    def total_latency(self) -> np.ndarray:
        return self.metrics["wall_time"].sum(axis=1)

    def final_loss(self) -> np.ndarray:
        return self.metrics["loss"][:, -1]

    def mean_energy(self) -> np.ndarray:
        """Time-averaged per-round mean energy of the selected sets."""
        return self.metrics["energy_mean"].mean(axis=1)

    def final_queue_norm(self) -> np.ndarray:
        return self.metrics["queue_norm"][:, -1]

    def final_accuracy(self) -> np.ndarray:
        """Final-params test accuracy per scenario, [S] (the batched
        on-device evaluation — requires ``eval_bank``)."""
        if "test_accuracy" not in self.final_metrics:
            raise KeyError(
                "no final test accuracy recorded — pass eval_bank= to "
                "Arena.run to evaluate the final params on device")
        return self.final_metrics["test_accuracy"]

    def dispatch_accounting(self) -> Dict[str, int]:
        """Summed per-bucket execution counters, cross-checked against
        the run totals — the multi-executable accounting contract:
        ``meta['buckets']`` entries are per-executable and ADDITIVE, so
        ``sum(bucket dispatches) == meta['dispatches']`` and
        ``sum(bucket executables_built) == meta['executables_built']``
        in every k_mode.  Raises ``ValueError`` when a mode breaks the
        sum (a bucket counted twice or dropped), returns the sums plus
        lane coverage otherwise."""
        buckets = self.meta.get("buckets")
        if not buckets:
            raise KeyError("meta carries no per-bucket counters — was "
                           "this report produced by Arena.run?")
        sums = dict(
            dispatches=sum(int(b["dispatches"]) for b in buckets),
            executables_built=sum(int(b["executables_built"])
                                  for b in buckets),
            buckets=len(buckets),
            lanes_covered=sum(len(b["lanes"]) for b in buckets))
        for field in ("dispatches", "executables_built"):
            if sums[field] != int(self.meta[field]):
                raise ValueError(
                    f"per-bucket {field} sum to {sums[field]} but "
                    f"meta[{field!r}] records {self.meta[field]} — the "
                    f"additive accounting contract is broken")
        lanes = sorted(i for b in buckets for i in b["lanes"])
        if lanes != list(range(self.num_scenarios)):
            raise ValueError(
                f"bucket lanes {lanes} do not partition the "
                f"{self.num_scenarios} grid lanes")
        return sums

    def selection_counts(self, num_devices: int) -> np.ndarray:
        """How often each client was drawn, [S, N] (padding ignored)."""
        sel = self.metrics["selected"]
        out = np.zeros((sel.shape[0], num_devices), np.int64)
        for s in range(sel.shape[0]):
            ids, counts = np.unique(sel[s][sel[s] >= 0], return_counts=True)
            out[s, ids.astype(np.int64)] = counts
        return out

    # -- cross-seed aggregation ---------------------------------------------

    def summary(self) -> List[dict]:
        """One plain dict per scenario (grid coordinates + reduced
        metrics) — the rows behind :meth:`tradeoff_table`."""
        g = self.grid
        names = g.controller_names()
        tot = self.total_latency()
        loss = self.final_loss()
        energy = self.mean_energy()
        qnorm = self.final_queue_norm()
        rows = [dict(controller=names[s], seed=int(g.seed[s]),
                     V=float(g.V[s]), lam=float(g.lam[s]),
                     energy_scale=float(g.energy_scale[s]),
                     mean_gain=float(g.mean_gain[s]),
                     sample_count=int(g.sample_count[s]),
                     chan_mode=CHANNEL_MODES[int(g.chan_mode[s])],
                     dropout=float(g.dropout[s]),
                     total_latency=float(tot[s]),
                     final_loss=float(loss[s]),
                     mean_energy=float(energy[s]),
                     final_queue_norm=float(qnorm[s]))
                for s in range(len(g))]
        for name, vals in self.final_metrics.items():
            for s, row in enumerate(rows):
                row[name] = float(vals[s])
        return rows

    def tradeoff_table(self) -> List[dict]:
        """Seed-aggregated trade-off points, one per distinct
        (controller, V, lam, energy_scale, mean_gain, K, channel mode,
        dropout) configuration —
        mean/std of total latency, final loss, and time-averaged energy
        across that configuration's seeds.  Sorted by (controller, V), so
        a V (resp. lambda / budget) sweep reads off as the paper's
        latency-energy (resp. latency-accuracy) trade-off curve.
        """
        rows = self.summary()
        groups: Dict[tuple, List[dict]] = {}
        for r in rows:
            key = (r["controller"], r["V"], r["lam"], r["energy_scale"],
                   r["mean_gain"], r["sample_count"], r["chan_mode"],
                   r["dropout"])
            groups.setdefault(key, []).append(r)
        fields = ["total_latency", "final_loss", "mean_energy",
                  "final_queue_norm"] + sorted(self.final_metrics)
        table = []
        for key in sorted(groups):
            rs = groups[key]
            ctrl, v, lam, escale, gain, k, mode, drop = key
            agg = dict(controller=ctrl, V=v, lam=lam, energy_scale=escale,
                       mean_gain=gain, sample_count=k, chan_mode=mode,
                       dropout=drop, num_seeds=len(rs))
            for field in fields:
                vals = np.asarray([r[field] for r in rs])
                agg[field] = float(vals.mean())
                agg[field + "_std"] = float(vals.std())
            table.append(agg)
        return table
