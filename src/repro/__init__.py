"""repro — LROA federated edge learning framework (JAX)."""

__version__ = "0.1.0"
