"""grok-1-314b — xAI Grok-1 MoE.

Assigned: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    activation="gelu",
    gated_mlp=True,
    attn_logit_softcap=30.0,      # grok uses attn logit capping
    final_logit_softcap=30.0,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="hf:xai-org/grok-1",
    long_context_ok=False,
    skip_note="full quadratic attention; long_500k skipped (DESIGN.md §4)",
)
