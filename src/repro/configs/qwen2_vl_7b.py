"""qwen2-vl-7b — VLM backbone with M-RoPE; vision encoder STUBBED.

Assigned: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
input_specs supplies ViT patch embeddings [B, P, d]; the language decoder
applies M-RoPE (t/h/w split 16/24/24 of the 64 rotary slot pairs).
[arXiv:2409.12191]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    activation="silu",
    gated_mlp=True,
    vision_patches=256,           # stub dynamic-resolution grid 16x16
    tie_embeddings=False,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2409.12191",
    long_context_ok=False,
    skip_note="full quadratic attention; long_500k skipped (DESIGN.md §4)",
)
