"""recurrentgemma-2b — RG-LRU + local attention, 1:2 pattern (Griffin).

Assigned: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
26 layers follow the (recurrent, recurrent, local) x 8 + (recurrent,
recurrent) layout of the released model: the repeat is scanned (8 groups)
and the two trailing layers live in ``block_pattern_suffix`` so the HLO
stays O(pattern) in depth. [arXiv:2402.19427]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

_PATTERN = ("recurrent", "recurrent", "local")

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,               # MQA on the local-attention layers
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=_PATTERN,              # scanned 8x
    block_pattern_suffix=("recurrent", "recurrent"),
    window_size=2048,
    rglru_width=2560,
    activation="gelu",
    gated_mlp=True,
    embedding_scale=True,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2402.19427",
    long_context_ok=True,         # RG-LRU state + windowed local attention
)
