"""yi-9b — llama-architecture GQA dense model.

Assigned: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
[arXiv:2403.04652]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    activation="silu",
    gated_mlp=True,               # SwiGLU
    rope_theta=10_000.0,
    tie_embeddings=False,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2403.04652",
    long_context_ok=False,
    skip_note="full quadratic attention; long_500k skipped (DESIGN.md §4)",
)
