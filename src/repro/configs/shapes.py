"""The four assigned input shapes and per-(arch, shape) coverage logic."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def covered_shapes(spec) -> List[InputShape]:
    """Shapes an architecture must lower (DESIGN.md §4 skip rules)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if spec.decode_ok:
        out.append(SHAPES["decode_32k"])
        if spec.long_context_ok:
            out.append(SHAPES["long_500k"])
    return out
