"""Architecture registry infrastructure: ArchSpec + smoke-variant builder.

Each ``repro/configs/<arch>.py`` defines ``CONFIG`` (the exact assigned
full-size configuration, with the source citation) and registers an
``ArchSpec`` carrying shape-coverage metadata (which input shapes lower which
step; long_500k requires a sub-quadratic mechanism — see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    citation: str
    long_context_ok: bool = False     # may lower long_500k
    decode_ok: bool = True            # decoder exists (encoder-only: False)
    skip_note: str = ""               # DESIGN.md note for skipped shapes


_SMOKE_PATTERNS = {
    # reduced block pattern per family (2 layers, d<=512, <=4 experts)
    ("recurrent", "recurrent", "local"): ("recurrent", "local"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    pattern = cfg.block_pattern
    if len(pattern) > 2:
        uniq = tuple(dict.fromkeys(pattern))       # preserve order
        pattern = uniq[:2] if len(uniq) >= 2 else uniq * 2
    if len(pattern) == 1:
        pattern = pattern
        layers = 2
    else:
        pattern = pattern[:2]
        layers = 2
    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4)
    num_kv = max(1, min(cfg.num_kv_heads, num_heads))
    while num_heads % num_kv:
        num_kv -= 1
    head_dim = 64
    ssm_heads = 4
    ssm_head_dim = (cfg.ssm_expand * d_model) // ssm_heads
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        block_pattern_suffix=(),
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        block_pattern=pattern,
        window_size=min(cfg.window_size, 64),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=(min(cfg.experts_per_token, 2)
                           if cfg.experts_per_token else 0),
        ssm_heads=ssm_heads,
        ssm_head_dim=ssm_head_dim,
        ssm_state_dim=min(cfg.ssm_state_dim, 32),
        ssm_chunk=16,
        rglru_width=min(cfg.rglru_width or d_model, 256),
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 32),
        vision_patches=min(cfg.vision_patches, 16) if cfg.vision_patches else 0,
        mrope_sections=(8, 12, 12) if cfg.rope_type == "mrope" else
        cfg.mrope_sections,
        attn_impl="naive",
    )
