"""mamba2-130m — SSD (state-space duality), attention-free.

Assigned: 24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
[arXiv:2405.21060]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,                  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssd",),
    ssm_state_dim=128,
    ssm_expand=2,
    ssm_heads=24,                 # inner 1536 / head_dim 64
    ssm_head_dim=64,
    ssm_chunk=256,
    rope_type="none",
    norm="rmsnorm",
    tie_embeddings=True,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2405.21060",
    long_context_ok=True,         # constant-state decode
)
