"""repro.configs — assigned architecture registry (``--arch <id>``).

Every entry cites its source model card / paper and is exercised by
(a) a reduced-config CPU smoke test and (b) the full-config multi-pod
dry-run over the assigned input shapes.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchSpec, smoke_config
from repro.configs.shapes import SHAPES, InputShape, covered_shapes

from repro.configs import (gemma2_27b, gemma_2b, granite_20b,
                           granite_moe_3b_a800m, grok_1_314b, mamba2_130m,
                           qwen2_vl_7b, recurrentgemma_2b, whisper_tiny,
                           yi_9b)

ARCHS: Dict[str, ArchSpec] = {
    "granite-moe-3b-a800m": granite_moe_3b_a800m.SPEC,
    "whisper-tiny": whisper_tiny.SPEC,
    "mamba2-130m": mamba2_130m.SPEC,
    "recurrentgemma-2b": recurrentgemma_2b.SPEC,
    "grok-1-314b": grok_1_314b.SPEC,
    "gemma-2b": gemma_2b.SPEC,
    "yi-9b": yi_9b.SPEC,
    "qwen2-vl-7b": qwen2_vl_7b.SPEC,
    "granite-20b": granite_20b.SPEC,
    "gemma2-27b": gemma2_27b.SPEC,
}


def get_spec(arch: str) -> ArchSpec:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_config(arch: str):
    return get_spec(arch).config


def get_smoke_config(arch: str):
    return smoke_config(get_config(arch))
