"""granite-moe-3b-a800m — IBM Granite 3.0 MoE family.

Assigned: 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155,
MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    activation="silu",
    gated_mlp=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    long_context_ok=False,
    skip_note="full quadratic attention; long_500k skipped (DESIGN.md §4)",
)
