"""granite-20b — llama-architecture MQA code model.

Assigned: 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
[arXiv:2405.04324]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,               # MQA
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    gated_mlp=False,              # GPT-BigCode style plain MLP
    tie_embeddings=False,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2405.04324",
    long_context_ok=False,
    skip_note="full quadratic attention; long_500k skipped (DESIGN.md §4)",
)
