"""whisper-tiny — enc-dec speech model, transformer backbone only.

Assigned: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865, enc-dec with a
STUBBED conv/mel frontend (input_specs supplies 1500 frame embeddings).
[arXiv:2212.04356]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,                 # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=4,
    encoder_seq_len=1500,
    norm="layernorm",
    norm_eps=1e-5,
    activation="gelu",
    gated_mlp=False,
    rope_type="none",             # whisper: learned/sinusoidal positions
    tie_embeddings=True,
    max_position=1 << 16,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2212.04356",
    long_context_ok=False,
    skip_note=("decoder context beyond model card; decode_32k lowered "
               "structurally, long_500k skipped (full attention)"),
)
