"""gemma-2b — GeGLU, head_dim=256, MQA.

Assigned: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000.
[arXiv:2403.08295]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,               # MQA on the 2b variant
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="gelu",
    gated_mlp=True,               # GeGLU
    embedding_scale=True,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2403.08295",
    long_context_ok=False,
    skip_note="full quadratic attention; long_500k skipped (DESIGN.md §4)",
)
