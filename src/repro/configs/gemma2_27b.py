"""gemma2-27b — local/global alternating attention with logit softcaps.

Assigned: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Sliding window 4096 on local layers, attn softcap 50, final softcap 30,
query scale (d_model/num_heads)^-0.5 = 144^-0.5. [arXiv:2408.00118]
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    block_pattern=("local", "global"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=144.0 ** -0.5,    # d_model / num_heads = 144
    activation="gelu",
    gated_mlp=True,
    embedding_scale=True,
    post_attn_norm=True,
    post_ffn_norm=True,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    config=CONFIG,
    citation="arXiv:2408.00118",
    # half the layers are windowed; global layers decode with flash-decode
    # over a sharded cache -> linear per-step cost: we run long_500k.
    long_context_ok=True,
)
