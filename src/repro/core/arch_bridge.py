"""Bridge between the model zoo and the LROA system model.

The paper's scheduler sees a model only through (a) the update size M in
bits and (b) the CPU cycles per sample c_n. For each assigned architecture
we derive both from the ``ModelConfig`` — M from the (active) parameter
count x wire precision, c_n from the per-sample training FLOPs (6·N_active·s
for an LM with sequence length s) scaled by a cycles-per-FLOP efficiency —
so LROA schedules realistic per-architecture workloads (§Arch-applicability
in DESIGN.md: the technique applies to every family through exactly this
interface).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import system_model as sm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class EdgeProfile:
    """How the edge fleet trains this model (paper Sec. VII defaults)."""
    num_devices: int = 120
    sample_count: int = 2
    local_epochs: int = 2
    seq_len: int = 512              # tokens per training sample on-device
    wire_bits: int = 16             # bf16 updates (paper used 32)
    cycles_per_flop: float = 0.5    # edge NPU efficiency (MACs/cycle ~ 1)
    energy_budget_j: float = 15.0
    upload_only_active: bool = True  # MoE: send only touched experts


def cycles_per_sample(cfg: ModelConfig, profile: EdgeProfile) -> float:
    """c_n = train FLOPs per sample * cycles/FLOP (6 N_active s)."""
    flops = 6.0 * cfg.active_param_count() * profile.seq_len
    return flops * profile.cycles_per_flop


def update_bits(cfg: ModelConfig, profile: EdgeProfile) -> float:
    """M — bits uploaded per round (eq. 6)."""
    n = cfg.active_param_count() if profile.upload_only_active \
        else cfg.param_count()
    return float(n) * profile.wire_bits


def system_params_for_arch(cfg: ModelConfig,
                           profile: EdgeProfile = EdgeProfile(),
                           data_sizes: Optional[np.ndarray] = None,
                           seed: int = 0) -> sm.SystemParams:
    """SystemParams whose compute/communication load matches ``cfg``."""
    n = profile.num_devices
    if data_sizes is None:
        rng = np.random.default_rng(seed)
        data_sizes = rng.integers(64, 512, n).astype(np.float32)
    ones = np.ones((n,), np.float32)
    return sm.SystemParams(
        num_devices=n,
        sample_count=profile.sample_count,
        local_epochs=profile.local_epochs,
        bandwidth_hz=1.0e6,
        noise_power=0.01,
        model_bits=update_bits(cfg, profile),
        download_rate=1.0e7,
        cycles_per_sample=float(cycles_per_sample(cfg, profile)) * ones,
        data_sizes=np.asarray(data_sizes, np.float32),
        capacitance=2.0e-28 * ones,
        energy_budget=profile.energy_budget_j * ones,
        f_min=1.0e9 * ones,
        f_max=2.0e9 * ones,
        p_min=1.0e-3 * ones,
        p_max=0.1 * ones,
    )
