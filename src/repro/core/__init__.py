"""repro.core — the paper's contribution: LROA online client scheduling and
resource allocation (Lyapunov drift-plus-penalty + Algorithm 2 solvers)."""

from repro.core.system_model import (SystemParams, paper_default_params,
                                     uplink_rate, upload_time, download_time,
                                     compute_time, round_time, round_energy,
                                     compute_energy, comm_energy,
                                     expected_round_latency,
                                     selection_probability, expected_energy)
from repro.core.solver import (ControlDecision, SolverConfig, solve_f,
                               solve_p, solve_q, solve_p2, p2_objective,
                               p22_objective)
from repro.core.queues import (init_queues, update_queues, energy_increment,
                               lyapunov, drift, lemma1_constant)
from repro.core.policy import (POLICIES, POLICY_IDS, DECIDE_FNS,
                               decide_lroa, decide_uni_d, decide_uni_s,
                               decide_by_id, static_frequency)
from repro.core.controller import (LROAController, LROAHyperParams,
                                   estimate_hyperparams,
                                   estimate_hyperparams_arrays,
                                   realized_round_time, realized_energy)
from repro.core.baselines import (UniformDynamicController,
                                  UniformStaticController, DivFLController,
                                  facility_location_greedy)
from repro.core.convergence import (BoundConstants, convergence_bound,
                                    sampling_error_term, max_learning_rate)
from repro.core.arch_bridge import (EdgeProfile, system_params_for_arch,
                                    cycles_per_sample, update_bits)
