"""Baseline controllers from the paper's evaluation (Sec. VII-A):

* **Uni-D** — uniform sampling (q = 1/N) + LROA's dynamic (f, p) from the
  P2.1 closed forms.
* **Uni-S** — uniform sampling + static resources: p mid-range, f chosen so
  the expected per-round energy exactly meets the budget (projected to the
  feasible box when the balance equation has no interior root).
* **DivFL** — diverse client selection via submodular (facility-location)
  greedy maximisation over client-update dissimilarity, with Uni-S resource
  policy (as adapted in the paper).

All controllers expose the same interface as ``LROAController``:
``decide(h) -> ControlDecision`` and ``step_queues`` (queues still tracked for
reporting, even though the baselines ignore them when deciding).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queues as vq
from repro.core import solver as slv
from repro.core import system_model as sm
from repro.core.controller import LROAHyperParams

Array = jax.Array


class UniformDynamicController:
    """Uni-D: q = 1/N; (f, p) from Theorems 2/3 under the uniform q."""

    name = "uni_d"

    def __init__(self, params: sm.SystemParams, hp: LROAHyperParams,
                 cfg: slv.SolverConfig = slv.SolverConfig()):
        self.params = params
        self.hp = hp
        self.cfg = cfg
        self.queues = vq.init_queues(params.num_devices)
        self.history: list[dict] = []

    def decide(self, h: Array) -> slv.ControlDecision:
        n = self.params.num_devices
        q = jnp.full((n,), 1.0 / n, jnp.float32)
        f = slv.solve_f(self.params, q, self.queues, self.hp.V)
        p = slv.solve_p(self.params, q, self.queues, h, self.hp.V,
                        self.cfg.bisect_iters)
        return slv.ControlDecision(f=f, p=p, q=q)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues


def static_frequency(params: sm.SystemParams, h: Array, p: Array) -> Array:
    """Solve the Uni-S energy-balance for f (projected to [f_min, f_max]).

    [E alpha c D f^2 / 2 + p M K / (B log2(1 + h p / N0))] * sel = Ebar
    with sel = 1 - (1 - 1/N)^K  =>  f^2 = 2 (Ebar/sel - E_com) / (E alpha c D).
    """
    n = params.num_devices
    sel = 1.0 - (1.0 - 1.0 / n) ** params.sample_count
    e_com = sm.comm_energy(params, h, p)
    cycles = params.local_epochs * params.capacitance * \
        params.cycles_per_sample * params.data_sizes
    f_sq = 2.0 * (params.energy_budget / sel - e_com) / jnp.maximum(cycles, 1e-30)
    f = jnp.sqrt(jnp.maximum(f_sq, 0.0))
    return jnp.clip(f, params.f_min, params.f_max)


class UniformStaticController:
    """Uni-S: q = 1/N, p mid-range, f from the energy-balance equation."""

    name = "uni_s"

    def __init__(self, params: sm.SystemParams,
                 hp: Optional[LROAHyperParams] = None, **_):
        self.params = params
        self.hp = hp
        self.queues = vq.init_queues(params.num_devices)
        self.history: list[dict] = []

    def decide(self, h: Array) -> slv.ControlDecision:
        n = self.params.num_devices
        q = jnp.full((n,), 1.0 / n, jnp.float32)
        p = 0.5 * (self.params.p_min + self.params.p_max)
        f = static_frequency(self.params, h, p)
        return slv.ControlDecision(f=f, p=p, q=q)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues


def facility_location_greedy(similarity: np.ndarray, k: int) -> np.ndarray:
    """Greedy submodular maximisation of G(S) = sum_i max_{j in S} sim[i, j].

    This is DivFL's diverse-subset selection [42]; O(N^2 k), exact 1-1/e
    approximation guarantee by submodularity of the facility-location set
    function.
    """
    n = similarity.shape[0]
    best = np.full((n,), -np.inf)
    chosen: list[int] = []
    for _ in range(k):
        # marginal gain of adding j: sum_i max(best_i, sim[i, j]) - sum_i best_i
        gains = np.maximum(best[:, None], similarity).sum(axis=0)
        gains[chosen] = -np.inf
        j = int(np.argmax(gains))
        chosen.append(j)
        best = np.maximum(best, similarity[:, j])
    return np.asarray(chosen, np.int64)


class DivFLController:
    """DivFL [42]: submodular diverse selection + Uni-S resource policy.

    Client similarity is measured on the latest available local update
    vectors (gradient proxies); until updates exist, similarity is uniform
    so the first round degenerates to an arbitrary (deterministic) subset,
    as in the reference implementation.
    """

    name = "divfl"

    def __init__(self, params: sm.SystemParams,
                 hp: Optional[LROAHyperParams] = None, **_):
        self.params = params
        self.hp = hp
        self.queues = vq.init_queues(params.num_devices)
        self._update_bank: Optional[np.ndarray] = None  # [N, proj_dim]
        self.history: list[dict] = []

    def observe_updates(self, client_ids: np.ndarray, flat_updates: np.ndarray):
        """Record (projected) local updates to drive the similarity metric."""
        if self._update_bank is None:
            self._update_bank = np.zeros(
                (self.params.num_devices, flat_updates.shape[-1]), np.float32)
        self._update_bank[np.asarray(client_ids)] = flat_updates

    def select(self) -> np.ndarray:
        k = self.params.sample_count
        n = self.params.num_devices
        if self._update_bank is None or not np.any(self._update_bank):
            return np.arange(k) % n
        g = self._update_bank
        norms = np.linalg.norm(g, axis=1, keepdims=True)
        gn = g / np.maximum(norms, 1e-12)
        similarity = gn @ gn.T
        return facility_location_greedy(similarity, k)

    def decide(self, h: Array) -> slv.ControlDecision:
        n = self.params.num_devices
        # Selection is deterministic; report the induced empirical q for the
        # common interface (uniform over the chosen subset).
        q = jnp.full((n,), 1.0 / n, jnp.float32)
        p = 0.5 * (self.params.p_min + self.params.p_max)
        f = static_frequency(self.params, h, p)
        return slv.ControlDecision(f=f, p=p, q=q)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues
