"""Baseline controllers from the paper's evaluation (Sec. VII-A):

* **Uni-D** — uniform sampling (q = 1/N) + LROA's dynamic (f, p) from the
  P2.1 closed forms.
* **Uni-S** — uniform sampling + static resources: p mid-range, f chosen so
  the expected per-round energy exactly meets the budget (projected to the
  feasible box when the balance equation has no interior root).
* **DivFL** — diverse client selection via submodular (facility-location)
  greedy maximisation over client-update dissimilarity, with Uni-S resource
  policy (as adapted in the paper).

All controllers expose the same interface as ``LROAController``:
``decide(h) -> ControlDecision`` and ``step_queues`` (queues still tracked for
reporting, even though the baselines ignore them when deciding).  The
Uni-D / Uni-S decision *rules* are the pure functions in
``repro.core.policy`` (this module's classes are thin stateful wrappers),
so ``run_scan`` and the ScenarioArena dispatch the identical math as
traced controller ids.  DivFL runs in-trace too: its facility-location
greedy over the shared ``(data_weight, gain)`` feature similarity is the
``K``-step ``lax.fori_loop`` in
``repro.core.policy.facility_location_select``, and this module's
:func:`facility_location_greedy` is the bitwise host mirror of that
loop.  The host :class:`DivFLController` additionally accepts observed
local-update sketches (``observe_updates``) — the sequential reference
path — which take precedence over the channel features when present.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as pol
from repro.core import queues as vq
from repro.core import solver as slv
from repro.core import system_model as sm
from repro.core.controller import LROAHyperParams
from repro.core.policy import static_frequency  # noqa: F401  (re-export)

Array = jax.Array


class UniformDynamicController:
    """Uni-D: q = 1/N; (f, p) from Theorems 2/3 under the uniform q."""

    name = "uni_d"

    def __init__(self, params: sm.SystemParams, hp: LROAHyperParams,
                 cfg: slv.SolverConfig = slv.SolverConfig()):
        self.params = params
        self.hp = hp
        self.cfg = cfg
        self.queues = vq.init_queues(params.num_devices)
        self.history: list[dict] = []

    def decide(self, h: Array) -> slv.ControlDecision:
        return pol.decide_uni_d(self.params, h, self.queues, self.hp.V,
                                self.hp.lam, self.cfg)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues


class UniformStaticController:
    """Uni-S: q = 1/N, p mid-range, f from the energy-balance equation."""

    name = "uni_s"

    def __init__(self, params: sm.SystemParams,
                 hp: Optional[LROAHyperParams] = None, **_):
        self.params = params
        self.hp = hp
        self.queues = vq.init_queues(params.num_devices)
        self.history: list[dict] = []

    def decide(self, h: Array) -> slv.ControlDecision:
        return pol.decide_uni_s(self.params, h, self.queues,
                                jnp.float32(0.0), jnp.float32(0.0))

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues


def facility_location_greedy(similarity: np.ndarray, k: int) -> np.ndarray:
    """Greedy submodular maximisation of G(S) = sum_i max_{j in S} sim[i, j].

    This is DivFL's diverse-subset selection [42]; O(N^2 k), exact 1-1/e
    approximation guarantee by submodularity of the facility-location set
    function.  Gains accumulate in the similarity's own dtype (not
    promoted to float64) so exact ties resolve identically to the traced
    ``repro.core.policy.facility_location_select`` — argmax breaks ties
    low-index in both.
    """
    n = similarity.shape[0]
    best = np.full((n,), -np.inf, similarity.dtype)
    chosen: list[int] = []
    for _ in range(k):
        # marginal gain of adding j: sum_i max(best_i, sim[i, j]) - sum_i best_i
        gains = np.maximum(best[:, None], similarity).sum(axis=0)
        gains[chosen] = -np.inf
        j = int(np.argmax(gains))
        chosen.append(j)
        best = np.maximum(best, similarity[:, j])
    return np.asarray(chosen, np.int64)


class DivFLController:
    """DivFL [42]: submodular diverse selection + Uni-S resource policy.

    Client similarity is measured on the latest available local update
    vectors (gradient proxies) when the sequential path has recorded any
    via :meth:`observe_updates`; otherwise selection runs on the same
    ``(data_weight, channel_gain)`` feature similarity as the in-trace
    rule (``repro.core.policy.divfl_features`` /
    ``divfl_similarity``), so the host controller and the arena's
    ``lax.fori_loop`` greedy pick identical subsets on shared channel
    draws.
    """

    name = "divfl"

    def __init__(self, params: sm.SystemParams,
                 hp: Optional[LROAHyperParams] = None, **_):
        self.params = params
        self.hp = hp
        self.queues = vq.init_queues(params.num_devices)
        self._update_bank: Optional[np.ndarray] = None  # [N, proj_dim]
        self.history: list[dict] = []

    def observe_updates(self, client_ids: np.ndarray, flat_updates: np.ndarray):
        """Record (projected) local updates to drive the similarity metric."""
        if self._update_bank is None:
            self._update_bank = np.zeros(
                (self.params.num_devices, flat_updates.shape[-1]), np.float32)
        self._update_bank[np.asarray(client_ids)] = flat_updates

    def select(self, h: Optional[Array] = None) -> np.ndarray:
        k = self.params.sample_count
        n = self.params.num_devices
        if self._update_bank is not None and np.any(self._update_bank):
            g = self._update_bank
            norms = np.linalg.norm(g, axis=1, keepdims=True)
            gn = g / np.maximum(norms, 1e-12)
            similarity = gn @ gn.T
        elif h is not None:
            # channel-feature similarity: the SAME gram the in-trace rule
            # builds (computed by the shared jax helper so the two paths
            # agree bitwise), reduced by the host greedy mirror
            similarity = np.asarray(pol.divfl_similarity(
                pol.divfl_features(self.params, jnp.asarray(h))))
        else:
            return np.arange(k) % n
        return facility_location_greedy(similarity, k)

    def decide(self, h: Array) -> slv.ControlDecision:
        n = self.params.num_devices
        # Selection is deterministic; report the induced empirical q for the
        # common interface (uniform over the chosen subset).
        q = jnp.full((n,), 1.0 / n, jnp.float32)
        p = 0.5 * (self.params.p_min + self.params.p_max)
        f = static_frequency(self.params, h, p)
        return slv.ControlDecision(f=f, p=p, q=q)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues
