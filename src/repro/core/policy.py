"""Controllers as data: pure per-round decision functions + traced dispatch.

The stateful controller classes (``LROAController``, ``UniformDynamic...``,
``UniformStatic...``) exist for the host-driven Algorithm-1 loop, but the
fused rollout paths — ``RoundEngine.run_scan`` and the ScenarioArena's
scenario-batched sweeps (``repro.sim``) — need the *decision rule itself*
to be a pure, jit/vmap-composable function of ``(params, h, queues, V,
lam)``.  This module is the single home of those rules:

* :func:`decide_lroa`  — Algorithm 2 (``solver.solve_p2``);
* :func:`decide_uni_d` — uniform q, LROA's dynamic (f, p) closed forms;
* :func:`decide_uni_s` — uniform q, mid-range p, f from the Uni-S
  energy-balance equation (:func:`static_frequency`).

``POLICIES`` fixes the id order and :func:`decide_by_id` dispatches on a
*traced* integer via ``lax.switch`` — the controller becomes per-scenario
data, so a single jitted program can run a mixed-controller grid (each
scenario lane selects its own branch; under ``vmap`` every branch runs on
the full batch and the select keeps each lane bit-identical to the pure
branch).  The stateful classes are thin wrappers over these functions, so
the host loop and the fused paths cannot diverge.

DivFL is deliberately absent: its selection is a stateful submodular
maximisation over observed client updates (host-side, data-dependent
control flow) and cannot be expressed as a pure per-round decision — it
stays on the sequential trainer path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import solver as slv
from repro.core import system_model as sm

Array = jax.Array

#: Scan-traceable policies, in controller-id order (the ``lax.switch``
#: branch index).  The names are the public contract — ``run_scan``'s
#: ``policy=`` strings and the ScenarioArena's grid both resolve through
#: ``POLICY_IDS``.
POLICIES = ("lroa", "uni_d", "uni_s")
POLICY_IDS = {name: i for i, name in enumerate(POLICIES)}


def _uniform_q(n: int) -> Array:
    return jnp.full((n,), 1.0 / n, jnp.float32)


def decide_lroa(params: sm.SystemParams, h: Array, queues: Array,
                V: Array, lam: Array,
                cfg: slv.SolverConfig = slv.SolverConfig(),
                k: Array = None) -> slv.ControlDecision:
    """LROA: the full Algorithm-2 drift-plus-penalty solve.

    ``k`` (every rule accepts it) optionally replaces the static
    ``params.sample_count`` with a traced per-rollout K — the padded-K
    rollout paths sweep K per scenario lane, so the decision math must
    read it from data, not from the executable.  ``None`` keeps the
    static host-controller path byte-identical to before.
    """
    return slv.solve_p2(params, h, queues, V, lam, cfg, k=k)


def decide_uni_d(params: sm.SystemParams, h: Array, queues: Array,
                 V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """Uni-D: q = 1/N; (f, p) from the Theorem-2/3 closed forms."""
    q = _uniform_q(params.num_devices)
    f = slv.solve_f(params, q, queues, V, k=k)
    p = slv.solve_p(params, q, queues, h, V, cfg.bisect_iters, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


def static_frequency(params: sm.SystemParams, h: Array, p: Array,
                     k: Array = None) -> Array:
    """Solve the Uni-S energy-balance for f (projected to [f_min, f_max]).

    [E alpha c D f^2 / 2 + p M K / (B log2(1 + h p / N0))] * sel = Ebar
    with sel = 1 - (1 - 1/N)^K  =>  f^2 = 2 (Ebar/sel - E_com) / (E alpha c D).
    """
    n = params.num_devices
    sel = 1.0 - (1.0 - 1.0 / n) ** sm.effective_k(params, k)
    e_com = sm.comm_energy(params, h, p, k=k)
    cycles = params.local_epochs * params.capacitance * \
        params.cycles_per_sample * params.data_sizes
    f_sq = 2.0 * (params.energy_budget / sel - e_com) / jnp.maximum(cycles,
                                                                    1e-30)
    f = jnp.sqrt(jnp.maximum(f_sq, 0.0))
    return jnp.clip(f, params.f_min, params.f_max)


def decide_uni_s(params: sm.SystemParams, h: Array, queues: Array,
                 V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """Uni-S: q = 1/N, p mid-range, f from the energy-balance equation.

    ``queues`` / ``V`` / ``lam`` are accepted (and ignored) so every
    policy shares one signature — the requirement for ``lax.switch``
    dispatch and for the scenario grid to carry (V, lam) uniformly.
    """
    q = _uniform_q(params.num_devices)
    p = jnp.broadcast_to(0.5 * (params.p_min + params.p_max),
                         (params.num_devices,))
    f = static_frequency(params, h, p, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


#: Branches in POLICY id order — ``DECIDE_FNS[POLICY_IDS[name]]`` is the
#: pure rule behind controller ``name``.
DECIDE_FNS = (decide_lroa, decide_uni_d, decide_uni_s)


def decide_by_id(controller_id: Array, params: sm.SystemParams, h: Array,
                 queues: Array, V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """Dispatch on a *traced* controller id (``lax.switch``).

    The id indexes :data:`POLICIES`; out-of-range ids clamp (lax.switch
    semantics).  Under ``vmap`` with a batched id every branch executes on
    the full batch and each lane selects its own — which is exactly what
    lets the ScenarioArena run a mixed-controller grid in ONE jitted
    program while staying bit-identical per lane to the fixed-policy
    rollout.  ``k`` (optional traced per-rollout K) is forwarded to every
    branch — the padded-K arena path, where K is per-scenario data.
    """
    if k is None:
        branches = [partial(fn, cfg=cfg) for fn in DECIDE_FNS]
        return jax.lax.switch(controller_id, branches, params, h, queues,
                              V, lam)
    branches = [
        (lambda p, hh, qq, vv, ll, kk, fn=fn: fn(p, hh, qq, vv, ll,
                                                 cfg=cfg, k=kk))
        for fn in DECIDE_FNS]
    return jax.lax.switch(controller_id, branches, params, h, queues, V,
                          lam, k)
